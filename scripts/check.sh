#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, lints.
#
#   scripts/check.sh
#
# Mirrors the ROADMAP's tier-1 gate (`cargo build --release &&
# cargo test -q`) first, then adds the examples build (the builder-based
# examples must never rot silently), the bench build (`--no-run`: the
# perf probes compile on every leg even though CI never times them),
# clippy with warnings denied, rustdoc with warnings denied, and
# rustfmt --check LAST — so a pure formatting drift never masks a real
# build/test/lint failure. If fmt is the only red step, run `cargo fmt`
# once and commit the mechanical diff.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo bench --no-run =="
cargo bench --no-run

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (deny warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "== check.sh: all green =="
