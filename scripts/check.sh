#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, lints.
#
#   scripts/check.sh
#
# Mirrors the ROADMAP's tier-1 gate (`cargo build --release &&
# cargo test -q`) and adds clippy with warnings denied so CI and local
# runs agree on what "clean" means.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (deny warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== check.sh: all green =="
