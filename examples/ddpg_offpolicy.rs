//! Further-work §6.1: off-policy DDPG with a replay buffer under the same
//! parallel experience-collection architecture — "as Off-Policy learning
//! requires much more samples than policy gradient methods, it might be an
//! advantage to adopt the parallel experience collection architecture."
//!
//!     cargo run --release --example ddpg_offpolicy -- --samplers 4
//!
//! N samplers roll the deterministic actor + exploration noise; the
//! learner fills a ring replay buffer and runs TD/DPG updates with Polyak
//! target networks, publishing fresh actor parameters through the same
//! policy store.

use walle::config::{Algo, Backend, InferenceMode, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator;
use walle::runtime::make_factory;
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;

    let mut cfg = TrainConfig::preset(&args.str_or("env", "pendulum"));
    cfg.algo = Algo::Ddpg;
    cfg.backend = Backend::parse(&args.str_or("backend", "native"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?;
    cfg.samplers = args.usize_or("samplers", 4)?;
    cfg.envs_per_sampler = args.usize_or("envs-per-sampler", 1)?;
    // the sharded inference pool serves the deterministic actor too
    cfg.inference_mode = InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?;
    cfg.iterations = args.usize_or("iterations", 60)?;
    cfg.samples_per_iter = args.usize_or("samples-per-iter", 1_000)?;
    cfg.chunk_steps = 100;
    cfg.seed = args.u64_or("seed", 0)?;
    cfg.ddpg.warmup_steps = args.usize_or("warmup", 2_000)?;
    cfg.ddpg.updates_per_iter = args.usize_or("updates-per-iter", 250)?;
    cfg.reward_scale = 0.1;

    println!(
        "WALL-E DDPG (further-work §6.1): {} with N={} samplers, replay {} transitions",
        cfg.env, cfg.samplers, cfg.ddpg.replay_capacity
    );

    let factory = make_factory(&cfg)?;
    let mut log = MetricsLog::new();
    let result = orchestrator::run(&cfg, factory.as_ref(), &mut log)?;

    let first = result
        .metrics
        .iter()
        .find(|m| m.episodes > 0)
        .map(|m| m.mean_return)
        .unwrap_or(f32::NAN);
    let best = result
        .metrics
        .iter()
        .filter(|m| m.episodes > 0)
        .map(|m| m.mean_return)
        .fold(f32::NEG_INFINITY, f32::max);
    println!("\nDDPG return: first {first:.0} -> best {best:.0}");
    println!(
        "(off-policy reuse: {} gradient updates per {} fresh samples)",
        cfg.ddpg.updates_per_iter, cfg.samples_per_iter
    );
    Ok(())
}
