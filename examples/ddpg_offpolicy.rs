//! Further-work §6.1: off-policy DDPG with a replay buffer under the same
//! parallel experience-collection architecture — "as Off-Policy learning
//! requires much more samples than policy gradient methods, it might be an
//! advantage to adopt the parallel experience collection architecture."
//!
//!     cargo run --release --example ddpg_offpolicy -- --samplers 4
//!
//! N samplers roll the deterministic actor + exploration noise; the
//! learner fills a ring replay buffer and runs TD/DPG updates with Polyak
//! target networks, publishing fresh actor parameters through the same
//! policy store. Built through `Session::builder()` with a configured
//! `Ddpg` algorithm instance — swap in `Td3::default()` (see the
//! `td3_pendulum` example) and nothing else changes.

use walle::algo::ddpg::Ddpg;
use walle::config::{Backend, DdpgCfg, InferShards, InferenceMode};
use walle::session::{Infer, Session};
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;

    let backend = Backend::parse(&args.str_or("backend", "native"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?;
    // the sharded inference pool serves the deterministic actor too
    let infer = match InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?
    {
        InferenceMode::Local => Infer::Local,
        InferenceMode::Shared => Infer::Shared {
            shards: InferShards::Auto,
        },
    };
    let algo = Ddpg {
        cfg: DdpgCfg {
            warmup_steps: args.usize_or("warmup", 2_000)?,
            updates_per_iter: args.usize_or("updates-per-iter", 250)?,
            ..Default::default()
        },
    };

    let session = Session::builder()
        .env(&args.str_or("env", "pendulum"))
        .algo(algo)
        .backend(backend)
        .samplers(args.usize_or("samplers", 4)?)
        .envs_per_sampler(args.usize_or("envs-per-sampler", 1)?)
        .infer(infer)
        .iterations(args.usize_or("iterations", 60)?)
        .samples_per_iter(args.usize_or("samples-per-iter", 1_000)?)
        .chunk_steps(100)
        .reward_scale(0.1)
        .seed(args.u64_or("seed", 0)?)
        .build()?;

    println!(
        "WALL-E DDPG (further-work §6.1):\n{}",
        session.spec().render()
    );

    let result = session.run()?;

    let first = result
        .metrics
        .iter()
        .find(|m| m.episodes > 0)
        .map(|m| m.mean_return)
        .unwrap_or(f32::NAN);
    let best = result
        .metrics
        .iter()
        .filter(|m| m.episodes > 0)
        .map(|m| m.mean_return)
        .fold(f32::NEG_INFINITY, f32::max);
    println!("\nDDPG return: first {first:.0} -> best {best:.0}");
    println!(
        "(off-policy reuse: {} gradient updates per {} fresh samples)",
        session.config().ddpg.updates_per_iter,
        session.config().samples_per_iter
    );
    Ok(())
}
