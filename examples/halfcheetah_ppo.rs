//! The paper's headline experiment (Fig 3): PPO on HalfCheetah with
//! N parallel samplers vs the single-process baseline, 20,000 samples per
//! iteration — the end-to-end validation driver recorded in
//! EXPERIMENTS.md.
//!
//!     cargo run --release --example halfcheetah_ppo -- \
//!         --ns 1,10 --iterations 150 --out-dir results
//!
//! For each N this runs the full coordinator (N sampler threads, async
//! learner), logs the return curve, and writes `fig3_return.csv`. The
//! base run is described once through `Session::builder()` (validated
//! there); the figure harness sweeps the sampler count over it. The
//! paper's claim reproduces as: N=10 reaches a given return in a
//! fraction of the wall-clock of N=1 (same per-iteration sample
//! budget), with final returns in the same band.

use walle::algo::ppo::Ppo;
use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;
use walle::session::Session;
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ns = args.usize_list_or("ns", &[1, 10])?;
    let out_dir = args.str_or("out-dir", "results");

    let session = Session::builder()
        .env("halfcheetah")
        .algo(Ppo::default())
        .backend(
            Backend::parse(&args.str_or("backend", "native"))
                .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?,
        )
        .iterations(args.usize_or("iterations", 150)?)
        .samples_per_iter(args.usize_or("samples-per-iter", 20_000)?)
        .envs_per_sampler(args.usize_or("envs-per-sampler", 1)?)
        .seed(args.u64_or("seed", 0)?)
        .build()?;
    let cfg = session.config().clone();

    println!(
        "WALL-E Fig 3 driver: halfcheetah PPO, {} samples/iter, {} iters, N in {:?}",
        cfg.samples_per_iter, cfg.iterations, ns
    );

    let factory_for = |c: &TrainConfig| make_factory(c);
    let curves = figures::fig3_return_curves(&cfg, &factory_for, &ns)?;
    figures::write_fig3_csv(&curves, &out_dir)?;

    println!("\n=== Fig 3 summary (return vs wall-clock) ===");
    for (n, ms) in &curves {
        let final_ret = ms.last().map(|m| m.mean_return).unwrap_or(f32::NAN);
        let wall = ms.last().map(|m| m.wall_secs).unwrap_or(f64::NAN);
        let collect = walle::util::stats::mean(
            &ms.iter().skip(1).map(|m| m.collect_secs).collect::<Vec<_>>(),
        );
        println!(
            "N={n:>2}: final return {final_ret:>9.1} | total wall {wall:>8.1}s | \
             mean rollout time/iter {collect:>7.2}s"
        );
    }
    if let (Some((_, m1)), Some((_, mn))) = (
        curves.iter().find(|(n, _)| *n == 1),
        curves.iter().find(|(n, _)| *n != 1),
    ) {
        let w1 = m1.last().map(|m| m.wall_secs).unwrap_or(f64::NAN);
        let wn = mn.last().map(|m| m.wall_secs).unwrap_or(f64::NAN);
        println!("\nwall-clock speedup at equal sample budget: {:.2}x", w1 / wn);
    }
    println!("wrote {out_dir}/fig3_return.csv");
    Ok(())
}
