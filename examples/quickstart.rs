//! Quickstart: train a pendulum swing-up policy with 4 parallel samplers
//! in under a minute, then evaluate it deterministically — all through
//! the `Session` builder, the library's single entry point.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native backend so it works before `make artifacts`; pass
//! `--backend xla` (after building artifacts) to run the AOT/PJRT path —
//! the learning curves are statistically identical (see
//! rust/tests/runtime_roundtrip.rs for the numeric parity proof).

use walle::algo::ppo::Ppo;
use walle::config::{Backend, InferEpoch, InferShards, InferWait, InferenceMode};
use walle::session::{Infer, Session};
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;

    let backend = Backend::parse(&args.str_or("backend", "native"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?;
    // try `--inference-mode shared`: the inference pool batches all
    // samplers' rows into fleet-wide forwards (shard it with
    // `--infer-shards`, tune the straggler cut with `--infer-wait`)
    let infer = match InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?
    {
        InferenceMode::Local => Infer::Local,
        InferenceMode::Shared => Infer::Shared {
            shards: InferShards::parse(&args.str_or("infer-shards", "auto"))
                .ok_or_else(|| anyhow::anyhow!("--infer-shards must be auto or a count >= 1"))?,
        },
    };
    let wait = InferWait::parse(&args.str_or("infer-wait", "adaptive"))
        .ok_or_else(|| anyhow::anyhow!("--infer-wait must be adaptive or fixed:<us>"))?;
    // `--infer-epoch pool` (default) flips every shard to a new policy
    // version on one dispatch boundary; `shard` restores independent
    // per-shard store observation
    let epoch = InferEpoch::parse(&args.str_or("infer-epoch", "pool"))
        .ok_or_else(|| anyhow::anyhow!("--infer-epoch must be pool or shard"))?;

    let session = Session::builder()
        .env("pendulum")
        .algo(Ppo::default())
        .backend(backend)
        .samplers(args.usize_or("samplers", 4)?)
        .envs_per_sampler(args.usize_or("envs-per-sampler", 1)?)
        .infer(infer)
        .infer_wait(wait)
        .infer_epoch(epoch)
        .iterations(args.usize_or("iterations", 40)?)
        .seed(args.u64_or("seed", 0)?)
        .build()?;

    println!("WALL-E quickstart:\n{}", session.spec().render());

    let result = session.run()?;

    // Evaluate the trained policy with the mean action (no noise) —
    // through the SAME trait-constructed actor AND the same normalizer
    // snapshot the training path used.
    let eval_result = session.evaluate_with_norm(&result.final_params, &result.final_norm, 10)?;

    let first = result.metrics.first().map(|m| m.mean_return).unwrap_or(0.0);
    let last = result.metrics.last().map(|m| m.mean_return).unwrap_or(0.0);
    println!("\ntraining return: {first:.0} -> {last:.0}");
    if let Some(rep) = &result.infer {
        println!("{}", rep.render());
    }
    println!(
        "deterministic eval: {:.0} ± {:.0} over 10 episodes",
        eval_result.mean_return, eval_result.std_return
    );
    println!("(pendulum is 'solved' around -200; random policy scores ≈ -1300)");
    Ok(())
}
