//! Quickstart: train a pendulum swing-up policy with 4 parallel samplers
//! in under a minute, then evaluate it deterministically.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native backend so it works before `make artifacts`; pass
//! `--backend xla` (after building artifacts) to run the AOT/PJRT path —
//! the learning curves are statistically identical (see
//! rust/tests/runtime_roundtrip.rs for the numeric parity proof).

use walle::config::{Backend, InferEpoch, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::{eval, orchestrator};
use walle::env::registry::make_env;
use walle::runtime::make_factory;
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;

    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = Backend::parse(&args.str_or("backend", "native"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?;
    cfg.samplers = args.usize_or("samplers", 4)?;
    cfg.envs_per_sampler = args.usize_or("envs-per-sampler", 1)?;
    // try `--inference-mode shared`: the inference pool batches all
    // samplers' rows into fleet-wide forwards (shard it with
    // `--infer-shards`, tune the straggler cut with `--infer-wait`)
    cfg.inference_mode = InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?;
    cfg.infer_shards = InferShards::parse(&args.str_or("infer-shards", "auto"))
        .ok_or_else(|| anyhow::anyhow!("--infer-shards must be auto or a count >= 1"))?;
    cfg.infer_wait = InferWait::parse(&args.str_or("infer-wait", "adaptive"))
        .ok_or_else(|| anyhow::anyhow!("--infer-wait must be adaptive or fixed:<us>"))?;
    // `--infer-epoch pool` (default) flips every shard to a new policy
    // version on one dispatch boundary; `shard` restores independent
    // per-shard store observation
    cfg.infer_epoch = InferEpoch::parse(&args.str_or("infer-epoch", "pool"))
        .ok_or_else(|| anyhow::anyhow!("--infer-epoch must be pool or shard"))?;
    cfg.iterations = args.usize_or("iterations", 40)?;
    cfg.seed = args.u64_or("seed", 0)?;

    println!(
        "WALL-E quickstart: PPO on pendulum, N={} samplers x {} envs, {} backend, {} inference",
        cfg.samplers,
        cfg.envs_per_sampler,
        cfg.backend.name(),
        cfg.inference_mode.name()
    );

    let factory = make_factory(&cfg)?;
    let mut log = MetricsLog::new();
    let result = orchestrator::run(&cfg, factory.as_ref(), &mut log)?;

    // Evaluate the trained policy with the mean action (no noise).
    let mut env = make_env("pendulum").unwrap();
    let mut actor = factory.make_actor()?;
    let norm = walle::algo::normalizer::NormSnapshot::identity(3);
    let eval_result = eval::evaluate(
        env.as_mut(),
        actor.as_mut(),
        &result.final_params,
        &norm,
        10,
        123,
    )?;

    let first = result.metrics.first().map(|m| m.mean_return).unwrap_or(0.0);
    let last = result.metrics.last().map(|m| m.mean_return).unwrap_or(0.0);
    println!("\ntraining return: {first:.0} -> {last:.0}");
    if let Some(rep) = &result.infer {
        println!("{}", rep.render());
    }
    println!(
        "deterministic eval: {:.0} ± {:.0} over 10 episodes",
        eval_result.mean_return, eval_result.std_return
    );
    println!("(pendulum is 'solved' around -200; random policy scores ≈ -1300)");
    Ok(())
}
