//! TD3 on pendulum through `Session::builder()` — the proof that the
//! `Algorithm` trait carries its weight: TD3 (twin critics, delayed
//! policy updates, target-policy smoothing) landed with ZERO edits to
//! the sampler loop, the orchestrator, or the inference pool, and this
//! driver differs from the DDPG example only in the `.algo(...)` call.
//!
//!     cargo run --release --example td3_pendulum -- --samplers 4
//!
//! Works with `--inference-mode shared` too: the pool serves TD3's
//! deterministic actor exactly like DDPG's.

use walle::algo::td3::Td3;
use walle::config::{InferShards, InferenceMode, Td3Cfg};
use walle::session::{Infer, Session};
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;

    let infer = match InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?
    {
        InferenceMode::Local => Infer::Local,
        InferenceMode::Shared => Infer::Shared {
            shards: InferShards::Auto,
        },
    };
    let algo = Td3 {
        cfg: Td3Cfg {
            warmup_steps: args.usize_or("warmup", 2_000)?,
            updates_per_iter: args.usize_or("updates-per-iter", 250)?,
            policy_delay: args.usize_or("policy-delay", 2)?,
            ..Default::default()
        },
    };

    let session = Session::builder()
        .env("pendulum")
        .algo(algo)
        .samplers(args.usize_or("samplers", 4)?)
        .envs_per_sampler(args.usize_or("envs-per-sampler", 1)?)
        .infer(infer)
        .iterations(args.usize_or("iterations", 60)?)
        .samples_per_iter(args.usize_or("samples-per-iter", 1_000)?)
        .chunk_steps(100)
        .reward_scale(0.1)
        .seed(args.u64_or("seed", 0)?)
        .build()?;

    println!("WALL-E TD3:\n{}", session.spec().render());

    let result = session.run()?;

    // deterministic eval through the same trait-constructed actor and
    // the same normalizer snapshot training used
    let eval = session.evaluate_with_norm(&result.final_params, &result.final_norm, 10)?;
    let best = result
        .metrics
        .iter()
        .filter(|m| m.episodes > 0)
        .map(|m| m.mean_return)
        .fold(f32::NEG_INFINITY, f32::max);
    println!(
        "\nTD3 best training return {best:.0}; deterministic eval {:.0} ± {:.0}",
        eval.mean_return, eval.std_return
    );
    println!("(pendulum is 'solved' around -200; random policy scores ≈ -1300)");
    Ok(())
}
