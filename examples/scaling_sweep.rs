//! The paper's scaling study (Figs 4–7): sweep the sampler count N at a
//! fixed 20,000-samples-per-iteration budget and measure rollout
//! (collection) time, speedup, learn time, and the collect/learn time
//! split per iteration.
//!
//!     cargo run --release --example scaling_sweep -- \
//!         --ns 1,2,4,6,8,10 --iterations 6 --out-dir results
//!
//! Expected shapes (the reproduction targets, cf. DESIGN.md §6):
//!   Fig 4: rollout time monotonically decreasing in N
//!   Fig 5: near-linear speedup, at or below the ideal line
//!   Fig 6: learn-time *fraction* grows with N (collection stops being
//!          the bottleneck — the paper's closing observation)
//!   Fig 7: learn time per iteration roughly constant in N

use walle::bench::figures;
use walle::config::{Backend, InferEpoch, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::runtime::make_factory;
use walle::session::Session;
use walle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ns = args.usize_list_or("ns", &[1, 2, 4, 6, 8, 10])?;
    let out_dir = args.str_or("out-dir", "results");

    let mut cfg = TrainConfig::preset(&args.str_or("env", "halfcheetah"));
    cfg.backend = Backend::parse(&args.str_or("backend", "native"))
        .ok_or_else(|| anyhow::anyhow!("--backend must be native|xla"))?;
    cfg.iterations = args.usize_or("iterations", 6)?;
    cfg.samples_per_iter = args.usize_or("samples-per-iter", 20_000)?;
    cfg.envs_per_sampler = args.usize_or("envs-per-sampler", 1)?;
    // `--inference-mode shared` batches workers' rows into fleet-wide
    // forwards through the sharded inference pool; size it with
    // `--infer-shards` and tune the straggler cut with `--infer-wait`
    cfg.inference_mode = InferenceMode::parse(&args.str_or("inference-mode", "local"))
        .ok_or_else(|| anyhow::anyhow!("--inference-mode must be local|shared"))?;
    cfg.infer_shards = InferShards::parse(&args.str_or("infer-shards", "auto"))
        .ok_or_else(|| anyhow::anyhow!("--infer-shards must be auto or a count >= 1"))?;
    cfg.infer_wait = InferWait::parse(&args.str_or("infer-wait", "adaptive"))
        .ok_or_else(|| anyhow::anyhow!("--infer-wait must be adaptive or fixed:<us>"))?;
    // `--infer-epoch pool` (default) flips every shard to a new policy
    // version on one dispatch boundary; `shard` restores independent
    // per-shard store observation
    cfg.infer_epoch = InferEpoch::parse(&args.str_or("infer-epoch", "pool"))
        .ok_or_else(|| anyhow::anyhow!("--infer-epoch must be pool or shard"))?;
    if args.get("infer-wait").is_none() && args.has("infer-max-wait-us") {
        // legacy PR 2 spelling still honored so old sweep commands stay
        // comparable with their recorded results
        walle::config::warn_legacy_infer_max_wait_us();
        cfg.infer_wait = InferWait::Fixed(args.u64_or("infer-max-wait-us", 200)?);
    }
    cfg.seed = args.u64_or("seed", 0)?;
    // sync mode isolates pure collection time per iteration (the paper
    // plots rollout time for a fixed 20k budget); async is the default
    // architecture — choose with --sync.
    if args.has("sync") {
        cfg.async_mode = false;
    }
    // validate the combination through the Session builder (the sweep
    // below drives the same trait pipeline per point)
    let cfg = Session::builder().config(cfg).build()?.config().clone();

    println!(
        "WALL-E scaling sweep ({}): N in {:?}, {} envs/sampler, {} inference, \
         {} samples/iter, {} iters each",
        cfg.env,
        ns,
        cfg.envs_per_sampler,
        cfg.inference_mode.name(),
        cfg.samples_per_iter,
        cfg.iterations
    );

    let factory_for = |c: &TrainConfig| make_factory(c);
    let skip = if cfg.iterations > 2 { 1 } else { 0 };
    let rows = figures::scaling_sweep(&cfg, &factory_for, &ns, skip)?;
    figures::print_sweep_table(&rows, "Figs 4-7: scaling with sampler count N");
    figures::write_sweep_csvs(&rows, &out_dir)?;

    // headline checks, printed so the run is self-interpreting
    let monotone = rows.windows(2).all(|w| w[1].collect_secs <= w[0].collect_secs * 1.15);
    println!("\nFig 4 shape (monotone decreasing rollout time): {monotone}");
    let (series, slope, r2) = figures::speedups(&rows);
    let over_linear = series.iter().any(|&(n, s)| s > n as f64 * 1.1);
    println!(
        "Fig 5 shape (near-linear, not over-linear): slope {slope:.2}, r² {r2:.3}, \
         over-linear anywhere: {over_linear}"
    );
    let frac_grows = rows.last().map(|l| l.learn_frac).unwrap_or(0.0)
        >= rows.first().map(|f| f.learn_frac).unwrap_or(0.0);
    println!("Fig 6 shape (learn fraction grows with N): {frac_grows}");
    if let (Some(first), Some(last)) = (rows.first(), rows.last()) {
        println!(
            "Fig 7 shape (learn time ~constant): {:.3}s at N={} vs {:.3}s at N={}",
            first.learn_secs, first.n, last.learn_secs, last.n
        );
    }
    println!("wrote fig4..fig7 CSVs to {out_dir}/");
    Ok(())
}
