//! Bench: paper Fig 6 — percentage of per-iteration wall-clock spent in
//! policy learning vs experience collection, as a function of N.
//! Expected shape: with near-linear collection speedup, the learn-time
//! *fraction* grows with N until learning becomes the next bottleneck
//! (the paper's closing observation, motivating its further-work §6.2).
//!
//!     cargo bench --bench fig6_time_breakdown

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false;

    let ns = [1usize, 2, 4, 6, 8, 10];
    let rows = figures::scaling_sweep(&cfg, &|c| make_factory(c), &ns, 1)?;

    println!("\n== Fig 6: time breakdown vs N ==");
    println!("{:>4} {:>10} {:>10}", "N", "%collect", "%learn");
    for r in &rows {
        println!(
            "{:>4} {:>9.1}% {:>9.1}%",
            r.n,
            100.0 * r.collect_frac,
            100.0 * r.learn_frac
        );
    }

    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\nfig6 shape check: learn fraction {:.1}% (N=1) -> {:.1}% (N=10)",
        100.0 * first.learn_frac,
        100.0 * last.learn_frac
    );
    assert!(
        last.learn_frac > first.learn_frac,
        "learn fraction must grow as collection parallelizes"
    );
    Ok(())
}
