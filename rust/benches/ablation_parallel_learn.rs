//! Ablation (further-work §6.2): data-parallel policy learning via
//! gradient sharding — split each minibatch across S shards, compute
//! per-shard gradients with the `grad_ppo` entry, weighted-average, apply
//! once with `apply_grads`.
//!
//! This bench verifies the two claims that make §6.2 viable:
//!   1. equivalence — sharded updates track the fused single-learner
//!      update numerically;
//!   2. cost accounting — measures the overhead of the split (grad
//!      staging + averaging) that any parallel execution would amortize.
//!
//!     cargo bench --bench ablation_parallel_learn

use walle::algo::gae::gae;
use walle::algo::ppo::{ppo_update, ppo_update_sharded};
use walle::algo::rollout::{ChunkEnd, ExperienceChunk, PpoDataset};
use walle::bench::harness::Bench;
use walle::config::{DdpgCfg, PpoCfg};
use walle::runtime::native_backend::NativeFactory;
use walle::runtime::{BackendFactory, PpoLearnerBackend, PpoTrainState};
use walle::util::rng::Pcg64;

fn dataset(n: usize, obs_dim: usize, act_dim: usize) -> PpoDataset {
    let mut rng = Pcg64::new(7);
    let chunk = ExperienceChunk {
        sampler_id: 0,
        env_slot: 0,
        policy_version: 0,
        obs: (0..n * obs_dim).map(|_| rng.normal()).collect(),
        act: (0..n * act_dim).map(|_| rng.normal()).collect(),
        rew: (0..n).map(|_| rng.normal()).collect(),
        logp: (0..n).map(|_| -8.0 - rng.next_f32()).collect(),
        value: (0..n).map(|_| rng.normal()).collect(),
        end: ChunkEnd::Truncated,
        bootstrap_value: 0.0,
        episode_returns: vec![],
        episode_lengths: vec![],
        obs_stats: None,
        busy_secs: 0.0,
    };
    PpoDataset::assemble(&[chunk], obs_dim, act_dim, |r, v, c| {
        Ok(gae(r, v, c, 0.99, 0.95))
    })
    .unwrap()
}

fn main() -> anyhow::Result<()> {
    let (o, a) = (17usize, 6usize);
    let f = NativeFactory::new(o, a, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let cfg = PpoCfg {
        epochs: 1,
        minibatch: 512,
        norm_adv: false,
        ..Default::default()
    };
    let n = 4096;

    println!("== §6.2 ablation: sharded vs fused PPO update (halfcheetah shapes) ==");

    // ---- 1. equivalence
    let flat = f.init_ppo_params(0);
    let mut fused_backend = f.make_ppo_learner()?;
    let mut fused_state = PpoTrainState::new(flat.clone());
    let mut ds = dataset(n, o, a);
    ppo_update(fused_backend.as_mut(), &mut fused_state, &mut ds, &cfg, 1e-3, &mut Pcg64::new(3))?;

    let mut sharded: Vec<Box<dyn PpoLearnerBackend>> =
        (0..4).map(|_| f.make_ppo_learner().unwrap()).collect();
    let mut sharded_state = PpoTrainState::new(flat);
    let mut ds2 = dataset(n, o, a);
    // shard minibatch = full/4 so the union covers the same rows per step
    let scfg = PpoCfg {
        minibatch: cfg.minibatch / 4,
        ..cfg.clone()
    };
    ppo_update_sharded(&mut sharded, &mut sharded_state, &mut ds2, &scfg, 1e-3, &mut Pcg64::new(3))?;

    let diff = fused_state
        .flat
        .iter()
        .zip(&sharded_state.flat)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("max |fused - sharded(4)| after 1 epoch: {diff:.2e}");
    assert!(diff < 2e-2, "sharded update diverged from fused: {diff}");

    // ---- 2. timing
    for shards in [1usize, 2, 4] {
        let mut backends: Vec<Box<dyn PpoLearnerBackend>> =
            (0..shards).map(|_| f.make_ppo_learner().unwrap()).collect();
        let mut state = PpoTrainState::new(f.init_ppo_params(1));
        let mut ds = dataset(n, o, a);
        let scfg = PpoCfg {
            minibatch: cfg.minibatch / shards,
            ..cfg.clone()
        };
        Bench::new(&format!("ppo_update sharded x{shards} ({n} samples)"))
            .warmup(1)
            .samples(5)
            .run(|| {
                ppo_update_sharded(&mut backends, &mut state, &mut ds, &scfg, 1e-3, &mut Pcg64::new(5))
                    .unwrap();
            });
    }
    let mut backend = f.make_ppo_learner()?;
    let mut state = PpoTrainState::new(f.init_ppo_params(1));
    let mut ds = dataset(n, o, a);
    Bench::new(&format!("ppo_update fused ({n} samples)"))
        .warmup(1)
        .samples(5)
        .run(|| {
            ppo_update(backend.as_mut(), &mut state, &mut ds, &cfg, 1e-3, &mut Pcg64::new(5))
                .unwrap();
        });

    println!("\n(shard gradients here run sequentially — the bench isolates the\n split/average overhead a threaded §6.2 learner would amortize)");
    Ok(())
}
