//! Off-policy parallel-learner ablation (PR 8): the grained DDPG/TD3
//! minibatch gradient swept over a batch x learner-threads x
//! replay-shards grid (halfcheetah shapes: 17 -> 64x64 -> 6).
//!
//! Two claims are on trial:
//!   1. determinism — within every (algo, batch, S) cell the updated
//!      parameters are BITWISE identical across L ∈ {1, 2, 4}: grains
//!      recombine under a fixed-order tree reduction, so the thread
//!      count is a pure wall-clock knob. Asserted, not eyeballed.
//!   2. throughput — per-update wall time across the grid, merged into
//!      BENCH_micro.json as the `parallel_learn` section (schema in
//!      docs/BENCHMARKS.md) so the perf trajectory is recorded across
//!      commits.
//!
//!     cargo bench --bench ablation_parallel_learn

use std::collections::BTreeMap;
use walle::algo::ddpg::ddpg_update_grained;
use walle::algo::td3::Td3Learner;
use walle::bench::harness::Bench;
use walle::config::{DdpgCfg, ReplayStrategy, Td3Cfg};
use walle::nn::adam::AdamCfg;
use walle::nn::layout::{actor_layout, critic_layout};
use walle::nn::mlp::NetShape;
use walle::replay::shard::{ReplayRng, ShardedReplay};
use walle::runtime::DdpgTrainState;
use walle::util::json::Json;
use walle::util::rng::Pcg64;

const OBS: usize = 17;
const ACT: usize = 6;
const HIDDEN: [usize; 2] = [64, 64];
/// Transitions pre-filled into every cell's replay window.
const FILL: usize = 8192;

const BATCHES: [usize; 2] = [256, 1024];
const SHARDS: [usize; 2] = [1, 4];
const THREADS: [usize; 3] = [1, 2, 4];

fn filled_replay(shards: usize) -> ShardedReplay {
    let replay = ShardedReplay::new(FILL, OBS, ACT, shards, ReplayStrategy::Uniform);
    let mut rng = Pcg64::new(5);
    let mut obs = vec![0.0f32; OBS];
    let mut next = vec![0.0f32; OBS];
    let mut act = vec![0.0f32; ACT];
    for i in 0..FILL {
        rng.fill_normal(&mut obs);
        rng.fill_normal(&mut next);
        rng.fill_normal(&mut act);
        replay.push(&obs, &act, rng.normal(), &next, i % 200 == 199);
    }
    replay
}

fn fill_td3(l: &Td3Learner) {
    let mut rng = Pcg64::new(5);
    let mut obs = vec![0.0f32; OBS];
    let mut next = vec![0.0f32; OBS];
    let mut act = vec![0.0f32; ACT];
    for i in 0..FILL {
        rng.fill_normal(&mut obs);
        rng.fill_normal(&mut next);
        rng.fill_normal(&mut act);
        l.replay().push(&obs, &act, rng.normal(), &next, i % 200 == 199);
    }
}

/// Bit pattern of the post-update DDPG nets after `updates` grained
/// rounds — the determinism witness compared across thread counts.
fn ddpg_fingerprint(batch: usize, shards: usize, threads: usize, updates: usize) -> Vec<u32> {
    let alayout = actor_layout(OBS, ACT, &HIDDEN);
    let clayout = critic_layout(OBS, ACT, &HIDDEN);
    let shape = NetShape::new(OBS, ACT, &HIDDEN);
    let mut init = Pcg64::new(11);
    let mut state =
        DdpgTrainState::new(alayout.init_flat(&mut init), clayout.init_flat(&mut init));
    let replay = filled_replay(shards);
    let mut rng = ReplayRng::new(9);
    let cfg = DdpgCfg {
        batch,
        warmup_steps: 0,
        updates_per_iter: updates,
        ..Default::default()
    };
    ddpg_update_grained(
        &mut state, &replay, &cfg, &mut rng, &alayout, &clayout, &shape,
        AdamCfg::default(), threads,
    )
    .unwrap();
    state
        .actor
        .iter()
        .chain(state.critic.iter())
        .map(|p| p.to_bits())
        .collect()
}

/// Same witness for TD3 (twin critics + delayed actor through the
/// learner's own grained update path).
fn td3_fingerprint(batch: usize, shards: usize, threads: usize, updates: usize) -> Vec<u32> {
    let mut l = Td3Learner::with_topology(
        OBS, ACT, &HIDDEN, FILL, 11, shards, ReplayStrategy::Uniform, threads,
    );
    fill_td3(&l);
    let cfg = Td3Cfg {
        batch,
        warmup_steps: 0,
        updates_per_iter: updates,
        ..Default::default()
    };
    l.update(&cfg).unwrap();
    l.state
        .actor
        .iter()
        .chain(l.state.critic1.iter())
        .chain(l.state.critic2.iter())
        .map(|p| p.to_bits())
        .collect()
}

fn time_ddpg(batch: usize, shards: usize, threads: usize) -> f64 {
    let alayout = actor_layout(OBS, ACT, &HIDDEN);
    let clayout = critic_layout(OBS, ACT, &HIDDEN);
    let shape = NetShape::new(OBS, ACT, &HIDDEN);
    let mut init = Pcg64::new(11);
    let mut state =
        DdpgTrainState::new(alayout.init_flat(&mut init), clayout.init_flat(&mut init));
    let replay = filled_replay(shards);
    let mut rng = ReplayRng::new(9);
    let cfg = DdpgCfg {
        batch,
        warmup_steps: 0,
        updates_per_iter: 1,
        ..Default::default()
    };
    let r = Bench::new(&format!("ddpg update B={batch} S={shards} L={threads}"))
        .warmup(2)
        .samples(8)
        .run(|| {
            ddpg_update_grained(
                &mut state, &replay, &cfg, &mut rng, &alayout, &clayout, &shape,
                AdamCfg::default(), threads,
            )
            .unwrap();
        });
    r.summary().mean
}

fn time_td3(batch: usize, shards: usize, threads: usize) -> f64 {
    let mut l = Td3Learner::with_topology(
        OBS, ACT, &HIDDEN, FILL, 11, shards, ReplayStrategy::Uniform, threads,
    );
    fill_td3(&l);
    let cfg = Td3Cfg {
        batch,
        warmup_steps: 0,
        updates_per_iter: 1,
        ..Default::default()
    };
    let r = Bench::new(&format!("td3  update B={batch} S={shards} L={threads}"))
        .warmup(2)
        .samples(8)
        .run(|| {
            l.update(&cfg).unwrap();
        });
    r.summary().mean
}

fn main() -> anyhow::Result<()> {
    println!("== PR 8 ablation: grained off-policy update, batch x L x S grid ==");
    let mut grid: Vec<Json> = Vec::new();

    for algo in ["ddpg", "td3"] {
        for &batch in &BATCHES {
            for &shards in &SHARDS {
                // determinism: L = 1 defines the cell's reference bits
                let reference = match algo {
                    "ddpg" => ddpg_fingerprint(batch, shards, 1, 3),
                    _ => td3_fingerprint(batch, shards, 1, 3),
                };
                let mut l1_secs = f64::NAN;
                for &threads in &THREADS {
                    let bits = match algo {
                        "ddpg" => ddpg_fingerprint(batch, shards, threads, 3),
                        _ => td3_fingerprint(batch, shards, threads, 3),
                    };
                    assert_eq!(
                        bits, reference,
                        "{algo} B={batch} S={shards}: L={threads} diverged from L=1 \
                         — the tree reduction is no longer order-fixed"
                    );
                    let secs = match algo {
                        "ddpg" => time_ddpg(batch, shards, threads),
                        _ => time_td3(batch, shards, threads),
                    };
                    if threads == 1 {
                        l1_secs = secs;
                    }
                    grid.push(Json::obj(vec![
                        ("algo", Json::Str(algo.into())),
                        ("batch", Json::Num(batch as f64)),
                        ("replay_shards", Json::Num(shards as f64)),
                        ("learner_threads", Json::Num(threads as f64)),
                        ("update_secs", Json::Num(secs)),
                        ("updates_per_sec", Json::Num(1.0 / secs)),
                        ("speedup_vs_l1", Json::Num(l1_secs / secs)),
                        ("bitwise_equal_l1", Json::Bool(true)),
                    ]));
                }
            }
        }
    }
    println!(
        "\nall {} grid cells published bitwise-identical parameters across L = {:?}",
        grid.len(),
        THREADS
    );

    // merge the section into BENCH_micro.json (preserving whatever the
    // micro bench last wrote; see docs/BENCHMARKS.md for the schema)
    let section = Json::obj(vec![
        ("obs_dim", Json::Num(OBS as f64)),
        ("act_dim", Json::Num(ACT as f64)),
        (
            "hidden",
            Json::Arr(HIDDEN.iter().map(|&h| Json::Num(h as f64)).collect()),
        ),
        ("fill", Json::Num(FILL as f64)),
        ("grid", Json::Arr(grid)),
    ]);
    let mut root = std::fs::read_to_string("BENCH_micro.json")
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| match j {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_else(|| {
            let mut m = BTreeMap::new();
            m.insert("bench".to_string(), Json::Str("micro".into()));
            m
        });
    root.insert("parallel_learn".to_string(), section);
    std::fs::write("BENCH_micro.json", Json::Obj(root).to_string())?;
    println!("merged parallel_learn section into BENCH_micro.json");
    Ok(())
}
