//! Micro-benchmarks of WALL-E's hot paths: environment stepping, policy
//! inference (native + XLA), the experience queue, GAE, and the PPO train
//! step. These are the §Perf profiling probes (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench micro

use walle::algo::gae::gae;
use walle::bench::harness::{fmt_secs, Bench};
use walle::config::{DdpgCfg, PpoCfg};
use walle::coordinator::queue::Channel;
use walle::env::registry::make_env;
use walle::runtime::native_backend::NativeFactory;
use walle::runtime::xla_backend::XlaFactory;
use walle::runtime::{BackendFactory, PpoMinibatch, PpoTrainState};
use walle::util::rng::Pcg64;

fn bench_env_steps() {
    for name in ["pendulum", "cartpole", "reacher", "halfcheetah"] {
        let mut env = make_env(name).unwrap();
        let mut rng = Pcg64::new(0);
        let mut obs = vec![0.0f32; env.obs_dim()];
        let mut act = vec![0.0f32; env.act_dim()];
        env.reset(&mut rng, &mut obs);
        let mut steps = 0u64;
        let r = Bench::new(&format!("env_step/{name}"))
            .warmup(1)
            .samples(5)
            .iters_per_sample(2000)
            .run(|| {
                for a in act.iter_mut() {
                    *a = rng.uniform(-1.0, 1.0);
                }
                let s = env.step(&act, &mut obs);
                steps += 1;
                if s.done || steps % env.max_episode_steps() as u64 == 0 {
                    env.reset(&mut rng, &mut obs);
                }
            });
        let rate = 1.0 / r.summary().mean;
        println!("    -> {rate:.0} steps/s/core");
    }
}

fn bench_queue() {
    let ch: Channel<Vec<f32>> = Channel::new(64);
    let payload = vec![0.0f32; 200 * 17];
    Bench::new("queue_push_pop (200x17 chunk)")
        .warmup(2)
        .samples(10)
        .iters_per_sample(5000)
        .run(|| {
            ch.push(payload.clone()).unwrap();
            let _ = ch.pop().unwrap();
        });

    // contended: 4 producers + 1 consumer
    let ch = std::sync::Arc::new(Channel::<u64>::new(64));
    let t0 = std::time::Instant::now();
    let total = 200_000u64;
    std::thread::scope(|s| {
        for p in 0..4 {
            let ch = ch.clone();
            s.spawn(move || {
                for i in 0..total / 4 {
                    ch.push(p * total + i).unwrap();
                }
            });
        }
        let ch2 = ch.clone();
        s.spawn(move || {
            for _ in 0..total {
                ch2.pop().unwrap();
            }
        });
    });
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "queue contended 4p1c: {:.2}M msgs/s ({} msgs in {})",
        total as f64 / dt / 1e6,
        total,
        fmt_secs(dt)
    );
}

fn bench_gae() {
    let mut rng = Pcg64::new(1);
    let t = 1000;
    let rew: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
    let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
    let cont = vec![1.0f32; t];
    Bench::new(&format!("gae_native (T={t})"))
        .warmup(2)
        .samples(10)
        .iters_per_sample(2000)
        .run(|| {
            let _ = gae(&rew, &val, &cont, 0.99, 0.95);
        });
}

/// Act-throughput sweep over batch size: the case for vectorized
/// sampling. One forward amortized over B envs should push rows/s far
/// above the B=1 rate (the `envs_per_sampler` speedup is this curve).
fn bench_act_batch_sweep() {
    let f = NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let flat = f.init_ppo_params(0);
    let mut rng = Pcg64::new(7);
    let mut base_rate = 0.0f64;
    for b in [1usize, 4, 8, 16, 32] {
        let mut actor = f.make_actor_batched(b).unwrap();
        let mut obs = vec![0.0f32; b * 17];
        let mut noise = vec![0.0f32; b * 6];
        rng.fill_normal(&mut obs);
        rng.fill_normal(&mut noise);
        let r = Bench::new(&format!("act_native batched (B={b}, 17->64x64->6)"))
            .warmup(5)
            .samples(10)
            .iters_per_sample(2000)
            .run(|| {
                let _ = actor.act(&flat, &obs, &noise).unwrap();
            });
        let rows_per_sec = b as f64 / r.summary().mean;
        if b == 1 {
            base_rate = rows_per_sec;
        }
        println!(
            "    -> {rows_per_sec:.0} env-steps-worth of inference/s/core \
             ({:.2}x the B=1 rate)",
            rows_per_sec / base_rate
        );
    }
}

fn bench_native_backend() {
    let f = NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let flat = f.init_ppo_params(0);
    let mut actor = f.make_actor().unwrap();
    let mut rng = Pcg64::new(2);
    let obs: Vec<f32> = (0..17).map(|_| rng.normal()).collect();
    let noise = vec![0.0f32; 6];
    let r = Bench::new("act_native (B=1, 17->64x64->6)")
        .warmup(5)
        .samples(10)
        .iters_per_sample(2000)
        .run(|| {
            let _ = actor.act(&flat, &obs, &noise).unwrap();
        });
    println!("    -> {:.0} inferences/s/core", 1.0 / r.summary().mean);

    let mut learner = f.make_ppo_learner().unwrap();
    let mut state = PpoTrainState::new(flat);
    let m = 512;
    let obs: Vec<f32> = (0..m * 17).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..m * 6).map(|_| rng.normal()).collect();
    let old_logp = vec![-8.0f32; m];
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret = vec![0.0f32; m];
    let mask = vec![1.0f32; m];
    Bench::new("train_step_native (M=512)")
        .warmup(2)
        .samples(10)
        .run(|| {
            let mb = PpoMinibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
                mask: &mask,
            };
            let _ = learner.train_step(&mut state, 3e-4, &mb).unwrap();
        });
}

fn bench_xla_backend() {
    if !std::path::Path::new("artifacts/index.json").exists() {
        println!("xla benches skipped: run `make artifacts` first");
        return;
    }
    let f = XlaFactory::new("artifacts", "halfcheetah").unwrap();
    let flat = f.init_ppo_params(0);
    let mut actor = f.make_actor().unwrap();
    let mut rng = Pcg64::new(3);
    let obs: Vec<f32> = (0..17).map(|_| rng.normal()).collect();
    let noise = vec![0.0f32; 6];
    let r = Bench::new("act_xla (B=1, PJRT)")
        .warmup(10)
        .samples(10)
        .iters_per_sample(500)
        .run(|| {
            let _ = actor.act(&flat, &obs, &noise).unwrap();
        });
    println!("    -> {:.0} inferences/s/core", 1.0 / r.summary().mean);

    let mut learner = f.make_ppo_learner().unwrap();
    let mut state = PpoTrainState::new(flat);
    let m = learner.minibatch_size();
    let obs: Vec<f32> = (0..m * 17).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..m * 6).map(|_| rng.normal()).collect();
    let old_logp = vec![-8.0f32; m];
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret = vec![0.0f32; m];
    let mask = vec![1.0f32; m];
    Bench::new(&format!("train_step_xla (M={m}, PJRT)"))
        .warmup(2)
        .samples(10)
        .run(|| {
            let mb = PpoMinibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
                mask: &mask,
            };
            let _ = learner.train_step(&mut state, 3e-4, &mb).unwrap();
        });

    let t = 500;
    let rew: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
    let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
    let cont = vec![1.0f32; t];
    Bench::new("gae_xla (T=500 in 1024 horizon, Pallas scan)")
        .warmup(2)
        .samples(10)
        .iters_per_sample(20)
        .run(|| {
            let _ = learner.gae(&rew, &val, &cont).unwrap();
        });
}

fn main() {
    println!("== WALL-E micro-benchmarks ==\n-- environments --");
    bench_env_steps();
    println!("-- experience queue --");
    bench_queue();
    println!("-- GAE --");
    bench_gae();
    println!("-- native backend --");
    bench_native_backend();
    println!("-- act batch sweep (vectorized sampling) --");
    bench_act_batch_sweep();
    println!("-- xla backend --");
    bench_xla_backend();
}
