//! Micro-benchmarks of WALL-E's hot paths: environment stepping, policy
//! inference (native + XLA), the experience queue, GAE, the PPO train
//! step, and the sharded inference pool vs N private backends (shard
//! sweep S=1/2/4 at N=16, including the steady-state zero-allocation
//! assertion on the slab transport). These are the §Perf profiling
//! probes (EXPERIMENTS.md §Perf). Headline rates are also written to
//! `BENCH_micro.json` so the repo records a perf trajectory across
//! commits — see docs/BENCHMARKS.md for the schema.
//!
//!     cargo bench --bench micro

use walle::algo::gae::gae;
use walle::algo::normalizer::NormSnapshot;
use walle::bench::harness::{fmt_secs, Bench};
use walle::config::{DdpgCfg, PpoCfg};
use walle::coordinator::policy_store::PolicyStore;
use walle::coordinator::queue::Channel;
use walle::env::registry::make_env;
use walle::runtime::epoch::EpochMode;
use walle::runtime::inference_server::{InferencePool, InferencePoolCfg, WaitPolicy};
use walle::nn::kernels::{self, KernelMode, Lanes};
use walle::nn::layout::ppo_layout;
use walle::nn::mlp::NetShape;
use walle::nn::quant::quantize_ppo;
use walle::runtime::native_backend::NativeFactory;
#[cfg(feature = "xla")]
use walle::runtime::xla_backend::XlaFactory;
use walle::runtime::{BackendFactory, PpoMinibatch, PpoTrainState};
use walle::util::json::Json;
use walle::util::rng::Pcg64;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// One vectorized env-stepping measurement: `engine` at width `m`.
struct EnvStepPoint {
    env: &'static str,
    m: usize,
    engine: &'static str,
    steps_per_sec: f64,
}

/// Env-step sweep: the scalar per-env loop vs the SoA batched engine at
/// M in {1,8,32,256}, for every registry env. This is the PR 9 headline
/// curve — one column-major `step_all` sweep amortizes dispatch and
/// keeps state cache-resident, so batched steps/s should pull away from
/// scalar as M grows. Both engines run the full `VecEnv` tick (episode
/// accounting, reset-on-done), so the ratio is what a sampler worker
/// actually sees.
fn bench_env_step_sweep() -> Vec<EnvStepPoint> {
    use walle::env::batch::EnvEngine;
    use walle::env::vec_env::{VecEnv, VecStepInfo};
    let mut points = Vec::new();
    for name in ["pendulum", "cartpole", "reacher", "halfcheetah"] {
        for m in [1usize, 8, 32, 256] {
            let mut scalar_rate = 0.0f64;
            for (ename, engine) in [("scalar", EnvEngine::Scalar), ("batched", EnvEngine::Batched)]
            {
                let mut venv = VecEnv::from_registry_with(name, m, 0, 1, engine).unwrap();
                venv.reset_all();
                let act_dim = venv.act_dim();
                let mut rng = Pcg64::new(4);
                let mut actions = vec![0.0f32; m * act_dim];
                let mut infos = vec![VecStepInfo::default(); m];
                // equalize total env-steps per sample across widths
                let iters = (4096 / m).max(16);
                let r = Bench::new(&format!("env_step_vec/{name} ({ename}, M={m})"))
                    .warmup(1)
                    .samples(5)
                    .iters_per_sample(iters)
                    .run(|| {
                        rng.fill_uniform(&mut actions, -1.0, 1.0);
                        venv.step_all(&actions, &mut infos);
                        for i in 0..m {
                            if infos[i].ended() {
                                venv.reset_env(i);
                            }
                        }
                    });
                let steps_per_sec = m as f64 / r.summary().mean;
                if engine == EnvEngine::Scalar {
                    scalar_rate = steps_per_sec;
                }
                println!(
                    "    -> {steps_per_sec:.0} env-steps/s/core ({:.2}x scalar)",
                    steps_per_sec / scalar_rate
                );
                points.push(EnvStepPoint {
                    env: name,
                    m,
                    engine: ename,
                    steps_per_sec,
                });
            }
        }
    }
    points
}

fn bench_env_steps() {
    for name in ["pendulum", "cartpole", "reacher", "halfcheetah"] {
        let mut env = make_env(name).unwrap();
        let mut rng = Pcg64::new(0);
        let mut obs = vec![0.0f32; env.obs_dim()];
        let mut act = vec![0.0f32; env.act_dim()];
        env.reset(&mut rng, &mut obs);
        let mut steps = 0u64;
        let r = Bench::new(&format!("env_step/{name}"))
            .warmup(1)
            .samples(5)
            .iters_per_sample(2000)
            .run(|| {
                for a in act.iter_mut() {
                    *a = rng.uniform(-1.0, 1.0);
                }
                let s = env.step(&act, &mut obs);
                steps += 1;
                if s.done || steps % env.max_episode_steps() as u64 == 0 {
                    env.reset(&mut rng, &mut obs);
                }
            });
        let rate = 1.0 / r.summary().mean;
        println!("    -> {rate:.0} steps/s/core");
    }
}

fn bench_queue() {
    let ch: Channel<Vec<f32>> = Channel::new(64);
    let payload = vec![0.0f32; 200 * 17];
    Bench::new("queue_push_pop (200x17 chunk)")
        .warmup(2)
        .samples(10)
        .iters_per_sample(5000)
        .run(|| {
            ch.push(payload.clone()).unwrap();
            let _ = ch.pop().unwrap();
        });

    // contended: 4 producers + 1 consumer
    let ch = std::sync::Arc::new(Channel::<u64>::new(64));
    let t0 = std::time::Instant::now();
    let total = 200_000u64;
    std::thread::scope(|s| {
        for p in 0..4 {
            let ch = ch.clone();
            s.spawn(move || {
                for i in 0..total / 4 {
                    ch.push(p * total + i).unwrap();
                }
            });
        }
        let ch2 = ch.clone();
        s.spawn(move || {
            for _ in 0..total {
                ch2.pop().unwrap();
            }
        });
    });
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "queue contended 4p1c: {:.2}M msgs/s ({} msgs in {})",
        total as f64 / dt / 1e6,
        total,
        fmt_secs(dt)
    );
}

fn bench_gae() {
    let mut rng = Pcg64::new(1);
    let t = 1000;
    let rew: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
    let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
    let cont = vec![1.0f32; t];
    Bench::new(&format!("gae_native (T={t})"))
        .warmup(2)
        .samples(10)
        .iters_per_sample(2000)
        .run(|| {
            let _ = gae(&rew, &val, &cont, 0.99, 0.95);
        });
}

/// Act-throughput sweep over batch size: the case for vectorized
/// sampling. One forward amortized over B envs should push rows/s far
/// above the B=1 rate (the `envs_per_sampler` speedup is this curve).
/// Returns (batch, rows_per_sec) for the JSON record.
fn bench_act_batch_sweep() -> Vec<(usize, f64)> {
    let f = NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let flat = f.init_ppo_params(0);
    let mut rng = Pcg64::new(7);
    let mut base_rate = 0.0f64;
    let mut out = Vec::new();
    for b in [1usize, 4, 8, 16, 32] {
        let mut actor = f.make_actor_batched(b).unwrap();
        let mut obs = vec![0.0f32; b * 17];
        let mut noise = vec![0.0f32; b * 6];
        rng.fill_normal(&mut obs);
        rng.fill_normal(&mut noise);
        let r = Bench::new(&format!("act_native batched (B={b}, 17->64x64->6)"))
            .warmup(5)
            .samples(10)
            .iters_per_sample(2000)
            .run(|| {
                let _ = actor.act(&flat, &obs, &noise).unwrap();
            });
        let rows_per_sec = b as f64 / r.summary().mean;
        if b == 1 {
            base_rate = rows_per_sec;
        }
        println!(
            "    -> {rows_per_sec:.0} env-steps-worth of inference/s/core \
             ({:.2}x the B=1 rate)",
            rows_per_sec / base_rate
        );
        out.push((b, rows_per_sec));
    }
    out
}

/// One GEMM throughput measurement: `variant` at `[m,k]x[k,n]`.
struct GemmPoint {
    m: usize,
    k: usize,
    n: usize,
    variant: &'static str,
    gflops: f64,
}

/// One act-path throughput measurement: `variant` at batch `batch`.
struct ActKernelPoint {
    batch: usize,
    variant: &'static str,
    rows_per_sec: f64,
}

/// The three f32 kernel variants swept by the microkernel benches: the
/// portable scalar reference, the SIMD arm under the exact (bitwise)
/// rounding contract, and the SIMD arm with FMA register tiling.
fn f32_variants(native: Lanes) -> [(&'static str, Lanes, KernelMode); 3] {
    [
        ("scalar", Lanes::Scalar, KernelMode::Exact),
        ("simd_exact", native, KernelMode::Exact),
        ("simd_fast", native, KernelMode::Fast),
    ]
}

/// Raw GEMM throughput per kernel variant via the explicit-dispatch
/// entry points (no global state touched). The int8 row includes the
/// per-call activation quantization — that is the real inference path
/// (weights are quantized once at publish time).
fn bench_kernel_gemm() -> Vec<GemmPoint> {
    let native = kernels::active();
    let mut points = Vec::new();
    for &(m, k, n) in &[(32usize, 64usize, 64usize), (128, 128, 128)] {
        let mut rng = Pcg64::new(11);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let mut out = vec![0.0f32; m * n];
        let flops = 2.0 * (m * k * n) as f64;
        for (name, lanes, mode) in f32_variants(native) {
            let r = Bench::new(&format!("gemm/{name} ({m}x{k}x{n})"))
                .warmup(3)
                .samples(8)
                .iters_per_sample(500)
                .run(|| {
                    out.iter_mut().for_each(|v| *v = 0.0);
                    kernels::matmul_via(lanes, mode, &a, &b, &mut out, m, k, n);
                });
            let gflops = flops / r.summary().mean / 1e9;
            println!("    -> {gflops:.2} GFLOP/s");
            points.push(GemmPoint { m, k, n, variant: name, gflops });
        }
        let mut bq = vec![0i8; k * n];
        let mut bscale = vec![0.0f32; n];
        kernels::quantize_cols(&b, k, n, &mut bq, &mut bscale);
        let bias = vec![0.0f32; n];
        let mut aq = vec![0i8; m * k];
        let mut ascale = vec![0.0f32; m];
        let r = Bench::new(&format!("gemm/int8 ({m}x{k}x{n})"))
            .warmup(3)
            .samples(8)
            .iters_per_sample(500)
            .run(|| {
                kernels::quantize_rows(&a, m, k, &mut aq, &mut ascale);
                kernels::matmul_q8_via(
                    native, &aq, &ascale, &bq, &bscale, &bias, &mut out, m, k, n,
                );
            });
        let gflops = flops / r.summary().mean / 1e9;
        println!("    -> {gflops:.2} GFLOP/s (incl. per-call activation quantization)");
        points.push(GemmPoint { m, k, n, variant: "int8", gflops });
    }
    points
}

/// End-to-end act-path rows/s per kernel variant at B in {1,8,16,32,64}.
/// The f32 variants steer the REAL inference path (the batched native
/// actor) through the global dispatch knobs; the int8 variant runs the
/// quantized-snapshot forward the shared pool uses under
/// `--infer-precision int8`. Globals are restored before returning —
/// this bench is single-threaded while it runs.
fn bench_kernel_act_sweep() -> Vec<ActKernelPoint> {
    let native = kernels::active();
    let f = NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let flat = f.init_ppo_params(0);
    let layout = ppo_layout(17, 6, &[64, 64]);
    let qsnap = quantize_ppo(&layout, &flat, &NetShape::new(17, 6, &[64, 64]));
    let mut rng = Pcg64::new(13);
    let mut points = Vec::new();
    for b in [1usize, 8, 16, 32, 64] {
        let mut obs = vec![0.0f32; b * 17];
        let mut noise = vec![0.0f32; b * 6];
        rng.fill_normal(&mut obs);
        rng.fill_normal(&mut noise);
        let mut scalar_rate = 0.0f64;
        for (name, lanes, mode) in f32_variants(native) {
            kernels::override_lanes(lanes);
            kernels::set_mode(mode);
            let mut actor = f.make_actor_batched(b).unwrap();
            let r = Bench::new(&format!("act_kernel/{name} (B={b}, 17->64x64->6)"))
                .warmup(5)
                .samples(8)
                .iters_per_sample(1000)
                .run(|| {
                    let _ = actor.act(&flat, &obs, &noise).unwrap();
                });
            let rows = b as f64 / r.summary().mean;
            if name == "scalar" {
                scalar_rate = rows;
            }
            println!("    -> {rows:.0} rows/s ({:.2}x scalar)", rows / scalar_rate);
            points.push(ActKernelPoint { batch: b, variant: name, rows_per_sec: rows });
        }
        kernels::override_lanes(native);
        kernels::set_mode(KernelMode::Exact);
        let r = Bench::new(&format!("act_kernel/int8 (B={b}, 17->64x64->6)"))
            .warmup(5)
            .samples(8)
            .iters_per_sample(1000)
            .run(|| {
                let _ = qsnap.forward_stochastic(&obs, &noise);
            });
        let rows = b as f64 / r.summary().mean;
        println!("    -> {rows:.0} rows/s ({:.2}x scalar)", rows / scalar_rate);
        points.push(ActKernelPoint { batch: b, variant: "int8", rows_per_sec: rows });
    }
    kernels::override_lanes(native);
    kernels::set_mode(KernelMode::Exact);
    points
}

/// One shared-pool fleet measurement at shard count `shards`.
struct FleetPoint {
    shards: usize,
    rows_per_sec: f64,
    mean_fill: f64,
    /// Hot-path allocation events observed AFTER warmup (must be 0: the
    /// steady-state slab transport is allocation-free).
    steady_allocs: u64,
}

const FLEET_N: usize = 16;
const FLEET_M: usize = 4;
const FLEET_TICKS: usize = 300;
const FLEET_WARMUP: usize = 30;

fn fleet_factory() -> NativeFactory {
    NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default())
}

/// (a) baseline: N private batched actors, each on its own thread.
/// Symmetric with [`bench_shared_fleet`]: thread spawn, factory/actor
/// construction, and warmup ticks all happen OUTSIDE the timed region
/// (barrier-fenced), so the recorded ratio compares steady states only.
fn bench_private_fleet() -> f64 {
    let (n, m, ticks) = (FLEET_N, FLEET_M, FLEET_TICKS);
    let warmed = Arc::new(Barrier::new(n + 1));
    let resume = Arc::new(Barrier::new(n + 1));
    let mut secs = 0.0f64;
    std::thread::scope(|s| {
        let mut worker_hs = Vec::new();
        for w in 0..n {
            let warmed = warmed.clone();
            let resume = resume.clone();
            worker_hs.push(s.spawn(move || {
                let fac = fleet_factory();
                let flat = fac.init_ppo_params(0);
                let mut actor = fac.make_actor_batched(m).unwrap();
                let mut rng = Pcg64::new(w as u64);
                let mut obs = vec![0.0f32; m * 17];
                let mut noise = vec![0.0f32; m * 6];
                rng.fill_normal(&mut obs);
                rng.fill_normal(&mut noise);
                for _ in 0..FLEET_WARMUP {
                    let _ = actor.act(&flat, &obs, &noise).unwrap();
                }
                warmed.wait();
                resume.wait();
                for _ in 0..ticks {
                    let _ = actor.act(&flat, &obs, &noise).unwrap();
                }
            }));
        }
        warmed.wait();
        let t0 = std::time::Instant::now();
        resume.wait();
        for h in worker_hs {
            h.join().unwrap();
        }
        secs = t0.elapsed().as_secs_f64();
    });
    let rate = (n * m * ticks) as f64 / secs;
    println!(
        "fleet inference baseline (N={n} x M={m}, 17->64x64->6, steady state): \
         private backends {rate:>9.0} rows/s ({})",
        fmt_secs(secs)
    );
    rate
}

/// (b) the sharded pool at shard count S: N clients, S serve threads.
/// All clients warm up, a barrier lets the main thread snapshot the
/// hot-path allocation counter, then the timed steady-state phase runs —
/// the counter must not move (zero allocations per tick).
fn bench_shared_fleet(shards: usize, private_rate: f64) -> FleetPoint {
    let (n, m, ticks) = (FLEET_N, FLEET_M, FLEET_TICKS);
    let fac = fleet_factory();
    let store = Arc::new(PolicyStore::new());
    store.publish(fac.init_ppo_params(0), NormSnapshot::identity(17));
    let pool = Arc::new(InferencePool::new(InferencePoolCfg {
        workers: n,
        rows_per_worker: m,
        shards,
        wait: WaitPolicy::Fixed(Duration::from_micros(200)),
        // the pool gate is on the dispatch path even without publishes:
        // bench it in its default configuration
        epoch: EpochMode::Pool,
        obs_dim: 17,
        act_dim: 6,
    }));
    let clients: Vec<_> = (0..n).map(|w| pool.client(w)).collect();
    // n workers + the main thread rendezvous twice around the snapshot
    let warmed = Arc::new(Barrier::new(n + 1));
    let resume = Arc::new(Barrier::new(n + 1));
    let mut steady_allocs = 0u64;
    let mut steady_secs = 0.0f64;
    std::thread::scope(|s| {
        for shard in pool.shards() {
            let shard = shard.clone();
            let store = store.clone();
            s.spawn(move || {
                let fac = fleet_factory();
                shard.serve_ppo(&fac, &store).unwrap();
            });
        }
        let mut worker_hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            let warmed = warmed.clone();
            let resume = resume.clone();
            worker_hs.push(s.spawn(move || {
                let mut rng = Pcg64::new(w as u64);
                let mut obs = vec![0.0f32; m * 17];
                let mut noise = vec![0.0f32; m * 6];
                rng.fill_normal(&mut obs);
                rng.fill_normal(&mut noise);
                for _ in 0..FLEET_WARMUP {
                    let _ = client.act(&obs, &noise).unwrap();
                }
                warmed.wait();
                resume.wait();
                for _ in 0..ticks {
                    let _ = client.act(&obs, &noise).unwrap();
                }
            }));
        }
        warmed.wait();
        let after_warmup = pool.report().hot_allocs;
        let t0 = std::time::Instant::now();
        resume.wait();
        for h in worker_hs {
            h.join().unwrap();
        }
        steady_secs = t0.elapsed().as_secs_f64();
        steady_allocs = pool.report().hot_allocs - after_warmup;
    });
    let rate = (n * m * ticks) as f64 / steady_secs;
    let rep = pool.report();
    println!(
        "    S={shards}: {rate:>9.0} rows/s ({}) [{} forwards, fill {:.1}%, \
         {} timeout cuts, steady-state hot-path allocs: {steady_allocs}] -> {:.2}x private",
        fmt_secs(steady_secs),
        rep.forwards,
        100.0 * rep.mean_fill(),
        rep.timeout_dispatches,
        rate / private_rate
    );
    // the acceptance criterion: the steady-state shared-mode hot path
    // performs ZERO allocations per tick (slab transport fully recycled)
    assert_eq!(
        steady_allocs, 0,
        "shared-mode hot path allocated after warmup at S={shards}"
    );
    FleetPoint {
        shards,
        rows_per_sec: rate,
        mean_fill: rep.mean_fill(),
        steady_allocs,
    }
}

fn bench_native_backend() {
    let f = NativeFactory::new(17, 6, &[64, 64], PpoCfg::default(), DdpgCfg::default());
    let flat = f.init_ppo_params(0);
    let mut actor = f.make_actor().unwrap();
    let mut rng = Pcg64::new(2);
    let obs: Vec<f32> = (0..17).map(|_| rng.normal()).collect();
    let noise = vec![0.0f32; 6];
    let r = Bench::new("act_native (B=1, 17->64x64->6)")
        .warmup(5)
        .samples(10)
        .iters_per_sample(2000)
        .run(|| {
            let _ = actor.act(&flat, &obs, &noise).unwrap();
        });
    println!("    -> {:.0} inferences/s/core", 1.0 / r.summary().mean);

    let mut learner = f.make_ppo_learner().unwrap();
    let mut state = PpoTrainState::new(flat);
    let m = 512;
    let obs: Vec<f32> = (0..m * 17).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..m * 6).map(|_| rng.normal()).collect();
    let old_logp = vec![-8.0f32; m];
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret = vec![0.0f32; m];
    let mask = vec![1.0f32; m];
    Bench::new("train_step_native (M=512)")
        .warmup(2)
        .samples(10)
        .run(|| {
            let mb = PpoMinibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
                mask: &mask,
            };
            let _ = learner.train_step(&mut state, 3e-4, &mb).unwrap();
        });
}

#[cfg(not(feature = "xla"))]
fn bench_xla_backend() {
    println!("xla benches skipped: built without the `xla` feature");
}

#[cfg(feature = "xla")]
fn bench_xla_backend() {
    if !std::path::Path::new("artifacts/index.json").exists() {
        println!("xla benches skipped: run `make artifacts` first");
        return;
    }
    let f = XlaFactory::new("artifacts", "halfcheetah").unwrap();
    let flat = f.init_ppo_params(0);
    let mut actor = f.make_actor().unwrap();
    let mut rng = Pcg64::new(3);
    let obs: Vec<f32> = (0..17).map(|_| rng.normal()).collect();
    let noise = vec![0.0f32; 6];
    let r = Bench::new("act_xla (B=1, PJRT)")
        .warmup(10)
        .samples(10)
        .iters_per_sample(500)
        .run(|| {
            let _ = actor.act(&flat, &obs, &noise).unwrap();
        });
    println!("    -> {:.0} inferences/s/core", 1.0 / r.summary().mean);

    let mut learner = f.make_ppo_learner().unwrap();
    let mut state = PpoTrainState::new(flat);
    let m = learner.minibatch_size();
    let obs: Vec<f32> = (0..m * 17).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..m * 6).map(|_| rng.normal()).collect();
    let old_logp = vec![-8.0f32; m];
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret = vec![0.0f32; m];
    let mask = vec![1.0f32; m];
    Bench::new(&format!("train_step_xla (M={m}, PJRT)"))
        .warmup(2)
        .samples(10)
        .run(|| {
            let mb = PpoMinibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
                mask: &mask,
            };
            let _ = learner.train_step(&mut state, 3e-4, &mb).unwrap();
        });

    let t = 500;
    let rew: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
    let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
    let cont = vec![1.0f32; t];
    Bench::new("gae_xla (T=500 in 1024 horizon, Pallas scan)")
        .warmup(2)
        .samples(10)
        .iters_per_sample(20)
        .run(|| {
            let _ = learner.gae(&rew, &val, &cont).unwrap();
        });
}

fn main() {
    println!("== WALL-E micro-benchmarks ==\n-- environments --");
    bench_env_steps();
    println!("-- env-step sweep (scalar vs batched engine) --");
    let envstep = bench_env_step_sweep();
    println!("-- experience queue --");
    bench_queue();
    println!("-- GAE --");
    bench_gae();
    println!("-- native backend --");
    bench_native_backend();
    println!(
        "-- kernel microbenches (arch: {}, GEMM) --",
        kernels::active().name()
    );
    let gemm = bench_kernel_gemm();
    println!("-- kernel microbenches (act path, scalar vs simd vs int8) --");
    let kact = bench_kernel_act_sweep();
    println!("-- act batch sweep (vectorized sampling) --");
    let sweep = bench_act_batch_sweep();
    println!("-- sharded vs private fleet inference (shard sweep) --");
    let private_rate = bench_private_fleet();
    let points: Vec<FleetPoint> = [1usize, 2, 4]
        .iter()
        .map(|&s| bench_shared_fleet(s, private_rate))
        .collect();
    println!("-- xla backend --");
    bench_xla_backend();

    // machine-readable record (BENCH_micro.json)
    let json = Json::obj(vec![
        ("bench", Json::Str("micro".into())),
        (
            "env_step",
            Json::Arr(
                envstep
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("env", Json::Str(p.env.into())),
                            ("m", Json::Num(p.m as f64)),
                            ("engine", Json::Str(p.engine.into())),
                            ("steps_per_sec", Json::Num(p.steps_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "kernels",
            Json::obj(vec![
                ("arch", Json::Str(kernels::active().name().into())),
                (
                    "gemm",
                    Json::Arr(
                        gemm.iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("m", Json::Num(p.m as f64)),
                                    ("k", Json::Num(p.k as f64)),
                                    ("n", Json::Num(p.n as f64)),
                                    ("variant", Json::Str(p.variant.into())),
                                    ("gflops", Json::Num(p.gflops)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "act_sweep",
                    Json::Arr(
                        kact.iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("batch", Json::Num(p.batch as f64)),
                                    ("variant", Json::Str(p.variant.into())),
                                    ("rows_per_sec", Json::Num(p.rows_per_sec)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "act_batch_sweep",
            Json::Arr(
                sweep
                    .iter()
                    .map(|&(b, rate)| {
                        Json::obj(vec![
                            ("batch", Json::Num(b as f64)),
                            ("rows_per_sec", Json::Num(rate)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fleet_inference",
            Json::obj(vec![
                ("workers", Json::Num(FLEET_N as f64)),
                ("rows_per_worker", Json::Num(FLEET_M as f64)),
                ("private_rows_per_sec", Json::Num(private_rate)),
                (
                    "shard_sweep",
                    Json::Arr(
                        points
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("shards", Json::Num(p.shards as f64)),
                                    ("rows_per_sec", Json::Num(p.rows_per_sec)),
                                    ("batch_fill", Json::Num(p.mean_fill)),
                                    (
                                        "steady_state_hot_allocs",
                                        Json::Num(p.steady_allocs as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]);
    if let Err(e) = std::fs::write("BENCH_micro.json", json.to_string()) {
        eprintln!("could not write BENCH_micro.json: {e}");
    } else {
        println!("\nwrote BENCH_micro.json");
    }
}
