//! Ablation: what does self-healing cost?
//!
//! Three fleets, identical workload (pendulum, sync, N=4 x M=2, S=2):
//!
//! * **clean**    — no faults, supervision armed (the always-on price of
//!                  the supervisor: catch_unwind frames + lane deposits);
//! * **faulted**  — a scripted worker kill AND a scripted shard kill
//!                  mid-run, healed by respawn (the recovery price:
//!                  backoff, snapshot restore, chunk replay);
//! * **ckpt**     — no faults, a durable checkpoint every iteration (the
//!                  durability price: barrier waits + serialized writes).
//!
//! Expected: clean supervision is ~free (injection points are one relaxed
//! atomic load when unarmed), recovery costs roughly the replayed work of
//! one worker, and checkpointing adds bounded per-iteration write time —
//! with the faulted run's final parameters BITWISE equal to clean's.
//!
//!     cargo bench --bench ablation_faults

use walle::config::{Backend, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator::{self, RunResult};
use walle::runtime::make_factory;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = Backend::Native;
    cfg.samplers = 4;
    cfg.envs_per_sampler = 2;
    cfg.async_mode = false;
    cfg.inference_mode = InferenceMode::Shared;
    cfg.infer_shards = InferShards::Fixed(2);
    cfg.infer_wait = InferWait::Fixed(500);
    cfg.samples_per_iter = 640;
    cfg.chunk_steps = 40;
    cfg.iterations = 10;
    cfg.hidden = vec![16, 16];
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 128;
    cfg
}

fn run(cfg: &TrainConfig) -> anyhow::Result<(RunResult, f64)> {
    let factory = make_factory(cfg)?;
    let mut log = MetricsLog::quiet();
    let sw = std::time::Instant::now();
    let r = orchestrator::run(cfg, factory.as_ref(), &mut log)?;
    Ok((r, sw.elapsed().as_secs_f64()))
}

fn main() -> anyhow::Result<()> {
    println!("== ablation: fault-handling cost (pendulum, sync, N=4 x M=2, S=2) ==");

    let clean_cfg = base_cfg();
    let (clean, clean_wall) = run(&clean_cfg)?;

    let mut faulted_cfg = base_cfg();
    faulted_cfg.fault_inject = "worker:1@tick:100,shard:0@dispatch:60".into();
    let (faulted, faulted_wall) = run(&faulted_cfg)?;

    let ckpt_dir = std::env::temp_dir().join("walle_ablation_faults_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut ckpt_cfg = base_cfg();
    ckpt_cfg.checkpoint_every = 1;
    ckpt_cfg.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string();
    let (ckpt, ckpt_wall) = run(&ckpt_cfg)?;
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    println!("clean:   wall {clean_wall:.3}s  restarts {}", clean.restarts);
    println!(
        "faulted: wall {faulted_wall:.3}s  restarts {}  faults fired {}  (+{:.1}% wall)",
        faulted.restarts,
        faulted.faults_injected,
        (faulted_wall / clean_wall - 1.0) * 100.0
    );
    let write_us: u64 = ckpt.checkpoint_write_us.iter().sum();
    println!(
        "ckpt:    wall {ckpt_wall:.3}s  {} writes, {:.1}ms total write time  (+{:.1}% wall)",
        ckpt.checkpoint_write_us.len(),
        write_us as f64 / 1000.0,
        (ckpt_wall / clean_wall - 1.0) * 100.0
    );

    assert_eq!(clean.restarts, 0);
    assert_eq!(faulted.restarts, 2, "both scripted kills must respawn");
    assert_eq!(faulted.faults_injected, 2);
    assert_eq!(
        faulted.final_params, clean.final_params,
        "healed run must be bitwise identical to the clean run"
    );
    assert_eq!(ckpt.checkpoint_write_us.len(), ckpt_cfg.iterations);
    Ok(())
}
