//! Ablation: asynchronous (the paper's architecture) vs synchronous
//! barrier mode — the design choice DESIGN.md §6 calls out.
//!
//! Async: samplers produce continuously under the latest policy version;
//! the learner drops chunks staler than `max_staleness`. Sync: each worker
//! produces exactly its share of the budget per policy version, then
//! blocks for the next publication.
//!
//! Expected: async hides collection latency behind learning (lower wall
//! time per iteration once warm), at the cost of bounded staleness in the
//! PPO ratios; returns stay in the same band (the coordinator's
//! staleness-drop policy is what makes that true — see §Perf log item 2).
//!
//!     cargo bench --bench ablation_async_sync

use walle::config::{Backend, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator;
use walle::runtime::make_factory;
use walle::util::stats::mean_f32;

fn run(async_mode: bool) -> anyhow::Result<(f64, f64, f32, f32)> {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = Backend::Native;
    cfg.samplers = 4;
    cfg.iterations = 20;
    cfg.async_mode = async_mode;
    let factory = make_factory(&cfg)?;
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log)?;
    let tail = &r.metrics[r.metrics.len() - 10..];
    let wall_per_iter = tail.iter().map(|m| m.total_secs).sum::<f64>() / tail.len() as f64;
    let staleness = mean_f32(&tail.iter().map(|m| m.staleness).collect::<Vec<_>>());
    let ret = mean_f32(&tail.iter().map(|m| m.mean_return).collect::<Vec<_>>());
    Ok((
        wall_per_iter,
        tail.iter().map(|m| m.collect_secs).sum::<f64>() / tail.len() as f64,
        staleness,
        ret,
    ))
}

fn main() -> anyhow::Result<()> {
    println!("== ablation: async (paper) vs sync barrier (pendulum, N=4, 4k/iter) ==");
    let (async_wall, async_drain, async_stale, async_ret) = run(true)?;
    let (sync_wall, sync_drain, sync_stale, sync_ret) = run(false)?;
    println!(
        "async: wall/iter {async_wall:.3}s  drain {async_drain:.3}s  staleness {async_stale:.2}  return {async_ret:.0}"
    );
    println!(
        "sync:  wall/iter {sync_wall:.3}s  drain {sync_drain:.3}s  staleness {sync_stale:.2}  return {sync_ret:.0}"
    );

    // async must overlap collection with learning: its queue-drain time is
    // a small fraction of the sync mode's post-barrier collection wait
    assert!(
        async_drain <= sync_drain * 1.2,
        "async failed to hide collection latency"
    );
    // sync data is exactly one version old at consumption; async is
    // bounded by max_staleness
    assert!(async_stale <= 2.5, "staleness bound violated: {async_stale}");
    // and learning quality stays in the same band
    assert!(
        (async_ret - sync_ret).abs() < 450.0,
        "async diverged from sync: {async_ret} vs {sync_ret}"
    );
    Ok(())
}
