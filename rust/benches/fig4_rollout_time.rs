//! Bench: paper Fig 4 — experience-collection (rollout) time per
//! iteration vs number of sampler processes N, at a fixed per-iteration
//! sample budget, swept over `envs_per_sampler` M (the vectorized-
//! sampling axis). Expected shapes: monotone decrease in N at every M,
//! and at equal N the M=8 rows collect a multiple faster than M=1 —
//! one batched forward amortized over 8 envs.
//!
//!     cargo bench --bench fig4_rollout_time
//!
//! Scaled-down workload (benches must terminate quickly); the full-size
//! run is `examples/scaling_sweep.rs` / `walle figures`.

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false; // isolate pure collection time per iteration

    let ns = [1usize, 2, 4, 6, 8, 10];
    let ms = [1usize, 8];
    let mut per_m = Vec::new();
    for &m in &ms {
        let mut c = cfg.clone();
        c.envs_per_sampler = m;
        let rows = figures::scaling_sweep(&c, &|cc| make_factory(cc), &ns, 1)?;
        figures::print_sweep_table(
            &rows,
            &format!("Fig 4: rollout time vs N (halfcheetah, 6k samples/iter, M={m})"),
        );
        let monotone = rows
            .windows(2)
            .all(|w| w[1].collect_secs <= w[0].collect_secs * 1.15);
        println!("\nfig4 M={m} shape check (monotone decreasing within 15% noise): {monotone}");
        assert!(
            rows.last().unwrap().collect_secs < rows.first().unwrap().collect_secs,
            "N=10 must collect faster than N=1 (M={m})"
        );
        per_m.push((m, rows));
    }

    // the vectorization claim, measured: steps/sec per sampler worker at
    // equal N, M=8 vs M=1 (acceptance target: >= 2x on the native backend)
    println!("\n== vectorized sampling: per-worker throughput, M=8 vs M=1 ==");
    let (_, base) = &per_m[0];
    let (_, vec8) = &per_m[per_m.len() - 1];
    for (b, v) in base.iter().zip(vec8) {
        assert_eq!(b.n, v.n);
        let steps_per_sec = |r: &figures::SweepRow| {
            cfg.samples_per_iter as f64 / r.collect_secs / r.n as f64
        };
        let ratio = steps_per_sec(v) / steps_per_sec(b);
        println!(
            "N={:>2}: {:>9.0} steps/s/worker (M=1) vs {:>9.0} (M=8) -> {ratio:.2}x",
            b.n,
            steps_per_sec(b),
            steps_per_sec(v)
        );
    }
    Ok(())
}
