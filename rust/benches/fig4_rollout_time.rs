//! Bench: paper Fig 4 — experience-collection (rollout) time per
//! iteration vs number of sampler processes N, at a fixed per-iteration
//! sample budget. Expected shape: monotone decrease, approaching the
//! learner-bound floor.
//!
//!     cargo bench --bench fig4_rollout_time
//!
//! Scaled-down workload (benches must terminate quickly); the full-size
//! run is `examples/scaling_sweep.rs` / `walle figures`.

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false; // isolate pure collection time per iteration

    let ns = [1usize, 2, 4, 6, 8, 10];
    let rows = figures::scaling_sweep(&cfg, &|c| make_factory(c), &ns, 1)?;
    figures::print_sweep_table(&rows, "Fig 4: rollout time vs N (halfcheetah, 6k samples/iter)");

    let monotone = rows
        .windows(2)
        .all(|w| w[1].collect_secs <= w[0].collect_secs * 1.15);
    println!("\nfig4 shape check (monotone decreasing within 15% noise): {monotone}");
    assert!(
        rows.last().unwrap().collect_secs < rows.first().unwrap().collect_secs,
        "N=10 must collect faster than N=1"
    );
    Ok(())
}
