//! Bench: paper Fig 4 — experience-collection (rollout) time per
//! iteration vs number of sampler processes N, at a fixed per-iteration
//! sample budget, swept over `envs_per_sampler` M (the vectorized-
//! sampling axis) and the inference placement (PR 2's shared mega-batch
//! server vs N private backends). Expected shapes: monotone decrease in N
//! at every M, at equal N the M=8 rows collect a multiple faster than M=1
//! (one batched forward amortized over 8 envs), and at N=8+ the shared
//! rows approach one fleet-wide forward per sim tick (batch-fill ratio
//! near 1 when workers stay in phase).
//!
//!     cargo bench --bench fig4_rollout_time
//!
//! Also sweeps the inference-pool shard count (S=1/2/4 at N=16, M=4):
//! one shard serializes every dispatch on a single serve thread, while
//! S>1 shards overlap their forwards, which is what keeps shared mode
//! scaling once a single mega-batch saturates a core.
//!
//! Scaled-down workload (benches must terminate quickly); the full-size
//! run is `examples/scaling_sweep.rs` / `walle figures`. Results are also
//! written machine-readable to `BENCH_fig4.json` (see docs/BENCHMARKS.md
//! for the schema) so the repo records a perf trajectory across commits.

use walle::bench::figures;
use walle::config::{Backend, InferShards, InferenceMode, TrainConfig};
use walle::runtime::make_factory;
use walle::util::json::Json;

struct Series {
    label: &'static str,
    m: usize,
    mode: InferenceMode,
    rows: Vec<figures::SweepRow>,
}

fn steps_per_sec_per_worker(cfg: &TrainConfig, r: &figures::SweepRow) -> f64 {
    cfg.samples_per_iter as f64 / r.collect_secs / r.n as f64
}

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false; // isolate pure collection time per iteration

    let ns = [1usize, 2, 4, 6, 8, 10];
    let configs = [
        ("local_m1", 1usize, InferenceMode::Local),
        ("local_m8", 8, InferenceMode::Local),
        ("shared_m8", 8, InferenceMode::Shared),
    ];
    let mut series = Vec::new();
    for &(label, m, mode) in &configs {
        let mut c = cfg.clone();
        c.envs_per_sampler = m;
        c.inference_mode = mode;
        let rows = figures::scaling_sweep(&c, &|cc| make_factory(cc), &ns, 1)?;
        figures::print_sweep_table(
            &rows,
            &format!(
                "Fig 4: rollout time vs N (halfcheetah, 6k samples/iter, M={m}, {} inference)",
                mode.name()
            ),
        );
        let monotone = rows
            .windows(2)
            .all(|w| w[1].collect_secs <= w[0].collect_secs * 1.15);
        println!("\nfig4 {label} shape check (monotone decreasing within 15% noise): {monotone}");
        assert!(
            rows.last().unwrap().collect_secs < rows.first().unwrap().collect_secs,
            "N=10 must collect faster than N=1 ({label})"
        );
        series.push(Series {
            label,
            m,
            mode,
            rows,
        });
    }

    // the vectorization claim, measured: steps/sec per sampler worker at
    // equal N, M=8 vs M=1 (acceptance target: >= 2x on the native backend)
    println!("\n== vectorized sampling: per-worker throughput, M=8 vs M=1 ==");
    let base = &series[0].rows;
    let vec8 = &series[1].rows;
    for (b, v) in base.iter().zip(vec8) {
        assert_eq!(b.n, v.n);
        let ratio = steps_per_sec_per_worker(&cfg, v) / steps_per_sec_per_worker(&cfg, b);
        println!(
            "N={:>2}: {:>9.0} steps/s/worker (M=1) vs {:>9.0} (M=8) -> {ratio:.2}x",
            b.n,
            steps_per_sec_per_worker(&cfg, b),
            steps_per_sec_per_worker(&cfg, v)
        );
    }

    // the mega-batch claim: shared vs local at M=8, with batch-fill ratio
    println!("\n== shared mega-batch inference vs N private backends (M=8) ==");
    let shared = &series[2].rows;
    for (l, s) in vec8.iter().zip(shared) {
        assert_eq!(l.n, s.n);
        let ratio = steps_per_sec_per_worker(&cfg, s) / steps_per_sec_per_worker(&cfg, l);
        println!(
            "N={:>2}: {:>9.0} steps/s/worker (local) vs {:>9.0} (shared, fill {:>5.1}%) -> {ratio:.2}x",
            l.n,
            steps_per_sec_per_worker(&cfg, l),
            steps_per_sec_per_worker(&cfg, s),
            100.0 * s.mean_batch_fill.unwrap_or(0.0)
        );
    }

    // the sharding claim: S inference shards at a fixed large fleet
    // (N=16 workers x M=4 envs). One shard serializes all dispatches on
    // one thread; S=2/4 split the fleet so shard forwards overlap —
    // collect time should not regress and saturates later in N*M.
    println!("\n== inference shard sweep (N=16, M=4, shared) ==");
    let shard_counts = [1usize, 2, 4];
    let mut shard_rows = Vec::new();
    for &s in &shard_counts {
        let mut c = cfg.clone();
        c.envs_per_sampler = 4;
        c.inference_mode = InferenceMode::Shared;
        c.infer_shards = InferShards::Fixed(s);
        let rows = figures::scaling_sweep(&c, &|cc| make_factory(cc), &[16], 1)?;
        let r = rows.into_iter().next().expect("one N=16 row");
        println!(
            "S={s}: collect {:>7.3}s | {:>9.0} steps/s/worker | fill {:>5.1}%",
            r.collect_secs,
            steps_per_sec_per_worker(&c, &r),
            100.0 * r.mean_batch_fill.unwrap_or(0.0)
        );
        shard_rows.push((s, c, r));
    }

    // machine-readable record (BENCH_fig4.json): rows/s, steps/s-per-
    // worker and batch-fill per (series, N), plus the shard sweep
    let json = Json::obj(vec![
        ("bench", Json::Str("fig4_rollout_time".into())),
        ("env", Json::Str(cfg.env.clone())),
        ("samples_per_iter", Json::Num(cfg.samples_per_iter as f64)),
        ("iterations", Json::Num(cfg.iterations as f64)),
        (
            "series",
            Json::Arr(
                series
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("label", Json::Str(s.label.into())),
                            ("envs_per_sampler", Json::Num(s.m as f64)),
                            ("inference_mode", Json::Str(s.mode.name().into())),
                            (
                                "rows",
                                Json::Arr(
                                    s.rows
                                        .iter()
                                        .map(|r| {
                                            Json::obj(vec![
                                                ("n", Json::Num(r.n as f64)),
                                                ("collect_secs", Json::Num(r.collect_secs)),
                                                (
                                                    "wall_collect_secs",
                                                    Json::Num(r.wall_collect_secs),
                                                ),
                                                ("learn_secs", Json::Num(r.learn_secs)),
                                                (
                                                    "steps_per_sec_per_worker",
                                                    Json::Num(steps_per_sec_per_worker(&cfg, r)),
                                                ),
                                                (
                                                    "batch_fill",
                                                    r.mean_batch_fill
                                                        .map(Json::Num)
                                                        .unwrap_or(Json::Null),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "shard_sweep",
            Json::Arr(
                shard_rows
                    .iter()
                    .map(|(s, c, r)| {
                        Json::obj(vec![
                            ("shards", Json::Num(*s as f64)),
                            ("n", Json::Num(r.n as f64)),
                            ("envs_per_sampler", Json::Num(4.0)),
                            ("collect_secs", Json::Num(r.collect_secs)),
                            ("wall_collect_secs", Json::Num(r.wall_collect_secs)),
                            (
                                "steps_per_sec_per_worker",
                                Json::Num(steps_per_sec_per_worker(c, r)),
                            ),
                            (
                                "batch_fill",
                                r.mean_batch_fill.map(Json::Num).unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_fig4.json", json.to_string())?;
    println!("\nwrote BENCH_fig4.json");
    Ok(())
}
