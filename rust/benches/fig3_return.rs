//! Bench: paper Fig 3 — average return, N parallel samplers vs the
//! single-process baseline (scaled down to bench time; the full-size
//! reproduction is `examples/halfcheetah_ppo.rs`, logged in
//! EXPERIMENTS.md). Expected shape: at equal sample budget, N=4 matches
//! the N=1 return per iteration while finishing in a fraction of the
//! wall-clock — i.e. much higher return *per unit time*.
//!
//!     cargo bench --bench fig3_return

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;
use walle::util::stats::mean_f32;

fn main() -> anyhow::Result<()> {
    // halfcheetah at bench scale: few iterations, collection-weighted
    // epochs so the collect/learn ratio sits in the paper's regime
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.iterations = 8;
    cfg.samples_per_iter = 6_000;
    cfg.ppo.epochs = 2;

    let curves = figures::fig3_return_curves(&cfg, &|c| make_factory(c), &[1, 4])?;

    // virtual wall-clock: cumulative (virtual collect + learn) — the
    // N-core projection of this single-core testbed (DESIGN.md §3)
    let vwall_of = |ms: &[walle::coordinator::metrics::IterationMetrics]| {
        ms.iter()
            .map(|m| m.virtual_collect_secs + m.learn_secs)
            .sum::<f64>()
    };
    println!("\n== Fig 3 (bench scale): return vs iteration and wall-clock ==");
    for (n, ms) in &curves {
        let tail: Vec<f32> = ms.iter().rev().take(5).map(|m| m.mean_return).collect();
        println!(
            "N={n}: final-5 mean return {:>8.1}, virtual wall {:>6.1}s",
            mean_f32(&tail),
            vwall_of(ms)
        );
    }

    let tail_mean = |n: usize| {
        curves
            .iter()
            .find(|(cn, _)| *cn == n)
            .map(|(_, ms)| {
                let t: Vec<f32> = ms.iter().rev().take(5).map(|m| m.mean_return).collect();
                mean_f32(&t)
            })
            .unwrap()
    };
    let wall = |n: usize| {
        curves
            .iter()
            .find(|(cn, _)| *cn == n)
            .map(|(_, ms)| vwall_of(ms))
            .unwrap()
    };
    let (r1, r4) = (tail_mean(1), tail_mean(4));
    let speedup = wall(1) / wall(4);
    println!("\nfig3 shape check: return N=4 {r4:.0} vs N=1 {r1:.0}; wall-clock speedup {speedup:.2}x");
    // parallelism must not degrade the return (cheetah early training sits
    // near -250 with modest variance)...
    assert!(r4 > r1 - 150.0, "N=4 return collapsed vs N=1: {r4} vs {r1}");
    // ...and must deliver it meaningfully faster at equal sample budget.
    // Threshold is conservative: at bench scale (2 epochs, 6k samples) the
    // Amdahl-limited ideal is ~1.6x and single-core timing noise is ±10%.
    assert!(speedup > 1.15, "no wall-clock advantage from parallel sampling");
    Ok(())
}
