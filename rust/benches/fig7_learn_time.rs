//! Bench: paper Fig 7 — policy-learning time per iteration vs N.
//! Expected shape: roughly constant — the learner does the same number of
//! minibatch updates regardless of how many samplers feed it ("the overall
//! policy learning time is almost keeping the same for each iteration").
//!
//!     cargo bench --bench fig7_learn_time

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false;

    let ns = [1usize, 2, 4, 6, 8, 10];
    let rows = figures::scaling_sweep(&cfg, &|c| make_factory(c), &ns, 1)?;

    println!("\n== Fig 7: learn time per iteration vs N ==");
    println!("{:>4} {:>14}", "N", "learn (s)");
    for r in &rows {
        println!("{:>4} {:>14.4}", r.n, r.learn_secs);
    }

    let times: Vec<f64> = rows.iter().map(|r| r.learn_secs).collect();
    let mean = walle::util::stats::mean(&times);
    let max_dev = times
        .iter()
        .map(|t| (t - mean).abs() / mean)
        .fold(0.0f64, f64::max);
    println!("\nfig7 shape check: learn time {mean:.3}s ± {:.0}% across N", 100.0 * max_dev);
    assert!(
        max_dev < 0.5,
        "learn time should be ~constant in N (max deviation {:.0}%)",
        100.0 * max_dev
    );
    Ok(())
}
