//! Bench: paper Fig 5 — experience-collection speedup vs N.
//! Expected shape: near-linear ("while not over-linear") scaling with the
//! variance the paper attributes to asynchrony and queue I/O.
//!
//!     cargo bench --bench fig5_speedup

use walle::bench::figures;
use walle::config::{Backend, TrainConfig};
use walle::runtime::make_factory;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::preset("halfcheetah");
    cfg.backend = Backend::Native;
    cfg.samples_per_iter = 6_000;
    cfg.iterations = 4;
    cfg.ppo.epochs = 4;
    cfg.async_mode = false;

    let ns = [1usize, 2, 4, 6, 8, 10];
    let rows = figures::scaling_sweep(&cfg, &|c| make_factory(c), &ns, 1)?;
    let (series, slope, r2) = figures::speedups(&rows);

    println!("\n== Fig 5: collection speedup vs N ==");
    println!("{:>4} {:>10} {:>8}", "N", "speedup", "ideal");
    for (n, s) in &series {
        println!("{n:>4} {s:>9.2}x {n:>7}x");
    }
    println!("linear fit: slope {slope:.3}, r² {r2:.3}");

    // the paper's claim: near-linear but NOT over-linear
    let over_linear = series.iter().any(|&(n, s)| s > n as f64 * 1.15);
    assert!(!over_linear, "speedup should not be over-linear");
    let n10 = series.iter().find(|(n, _)| *n == 10).map(|&(_, s)| s).unwrap_or(0.0);
    println!("fig5 shape check: speedup(10) = {n10:.2}x (near-linear target, not over-linear)");
    assert!(n10 > 2.0, "parallel sampling shows no meaningful speedup");
    Ok(())
}
