//! Minimal JSON parser + serializer.
//!
//! The offline crate set has no `serde`, so WALL-E carries a small,
//! well-tested JSON implementation: enough to read the AOT `meta.json` /
//! `index.json` artifacts, load run configs, and write metrics/figure
//! series. Numbers are f64 (the artifact metadata only uses integers and
//! small floats, well within f64's exact range).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json access error: {0}")]
    Access(String),
}

type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| JsonError::Access(format!("missing key {key:?}"))),
            _ => Err(JsonError::Access(format!("not an object (key {key:?})"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Access(format!("not a number: {self:?}"))),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(JsonError::Access(format!("not a usize: {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Access(format!("not a string: {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Access(format!("not a bool: {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Access(format!("not an array: {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Access(format!("not an object: {self:?}"))),
        }
    }

    /// Convenience: `obj.get_path(&["ddpg", "actor_count"])`.
    pub fn get_path(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Ok(cur)
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

// ------------------------------------------------------------- serializer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_unicode_escape_and_utf8() {
        let v = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é café");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"ok":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn get_path_walks_objects() {
        let v = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.get_path(&["a", "b", "c"]).unwrap().as_usize().unwrap(), 7);
        assert!(v.get_path(&["a", "x"]).is_err());
    }

    #[test]
    fn parses_real_meta_json() {
        // Shape of the artifact metadata the runtime actually reads.
        let src = r#"{
          "preset": "pendulum", "obs_dim": 3, "act_dim": 1,
          "hidden": [64, 64], "param_count": 9094,
          "params": [{"name": "pi/l0/w", "shape": [3, 64], "offset": 0,
                      "size": 192, "init": "glorot"}],
          "artifacts": {"act": "pendulum/act.hlo.txt"}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("obs_dim").unwrap().as_usize().unwrap(), 3);
        let p0 = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("init").unwrap().as_str().unwrap(), "glorot");
    }
}
