//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so WALL-E carries its own PRNG: a
//! PCG64 (XSL-RR 128/64) generator — small state, excellent statistical
//! quality, trivially seedable per worker. Every sampler worker derives an
//! independent stream via [`Pcg64::split`], which hashes (seed, stream id)
//! so that worker streams never collide; the whole run is reproducible
//! from one root seed.

/// PCG64 XSL-RR 128/64 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator on an explicit stream; distinct streams from the
    /// same seed are statistically independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let init = (seed as u128) << 64 | splitmix64(seed ^ 0x9e37_79b9_7f4a_7c15) as u128;
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(init);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive a child generator for worker `id` (independent stream).
    pub fn split(&self, id: u64) -> Self {
        Self::with_stream(self.peek_seed() ^ splitmix64(id.wrapping_add(1)), id)
    }

    fn peek_seed(&self) -> u64 {
        (self.state >> 64) as u64
    }

    /// Raw generator registers `(state, inc)` — the complete PCG64 state,
    /// exposed for checkpoints and respawn snapshots.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] output. The restored
    /// generator continues the original sequence bitwise.
    pub fn from_raw(state: u128, inc: u128) -> Self {
        Self { state, inc }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for our
    /// shuffles; exact rejection for small n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f32 {
        // Box–Muller without caching: simple and branch-free enough; the
        // sampler hot path requests whole vectors via fill_normal.
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a slice with N(0, 1) samples (pairs per Box–Muller transform).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            out[i] = (r * c) as f32;
            out[i + 1] = (r * s) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal();
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — used for seeding / stream derivation only.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_independent() {
        let root = Pcg64::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let v = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_and_spread() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let mut buf = vec![0.0f32; 200_000];
        r.fill_normal(&mut buf);
        let n = buf.len() as f64;
        let mean = buf.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn raw_state_round_trip_continues_bitwise() {
        let mut a = Pcg64::with_stream(42, 17);
        for _ in 0..100 {
            a.next_u64();
        }
        let (state, inc) = a.raw_state();
        let mut b = Pcg64::from_raw(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
