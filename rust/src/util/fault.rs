//! Deterministic fault-injection plans for the chaos harness.
//!
//! A [`FaultPlan`] describes *which* fleet component dies and *when*, in
//! terms the run can reproduce exactly: sampler workers die at a lifetime
//! sim-tick count, inference shards die at a dispatch count. Two spellings
//! are accepted by [`FaultPlan::parse`]:
//!
//! * explicit — `worker:1@tick:500,shard:0@dispatch:40`
//! * seeded random — `random:seed=7,count=2,horizon=1000` (events are
//!   drawn with the repo's own PCG64 when the plan is compiled against a
//!   concrete fleet shape, so the same spec + shape always yields the
//!   same deaths)
//!
//! [`FaultPlan::compile`] lowers a plan onto a concrete `(workers,
//! shards)` fleet as per-component [`FaultCell`] lists. Injection points
//! in the sampler / serve hot loops hold an `Option` over those lists, so
//! the disabled path costs one branch on `None` and nothing else. A cell
//! fires **once** (atomic swap), then stays spent across respawns — the
//! supervisor restarts the component and the plan does not re-kill it.
//!
//! Firing is a real `panic!` through [`trip`], not a simulated error
//! return: the chaos suite exercises the exact unwind paths (drop guards,
//! poison-tolerant locks, supervisor catch) that a genuine defect would.

use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Which component class a fault event targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// A sampler worker; `at` counts lifetime sim ticks.
    Worker,
    /// An inference shard; `at` counts dispatches.
    Shard,
}

/// One scripted death: component `index` of class `site` dies the first
/// time its progress counter reaches `at`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    /// Component class.
    pub site: FaultSite,
    /// Worker id or shard index.
    pub index: usize,
    /// Progress counter value (sim tick / dispatch) at which to fire.
    pub at: u64,
}

/// A parsed fault plan: either an explicit event list or a seeded random
/// recipe expanded at [`FaultPlan::compile`] time.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultPlan {
    /// Events taken verbatim from the spec.
    Explicit(Vec<FaultEvent>),
    /// `count` events drawn uniformly over all components and
    /// `[1, horizon]` trigger points from `Pcg64::with_stream(seed,
    /// FAULT_STREAM)`.
    Random {
        /// RNG seed for the draw.
        seed: u64,
        /// Number of events to draw.
        count: usize,
        /// Inclusive upper bound on trigger counters.
        horizon: u64,
    },
}

/// RNG stream id reserved for random fault plans.
const FAULT_STREAM: u64 = 0xFA17;

impl FaultPlan {
    /// Parse a `--fault-inject` spec. Empty input yields an empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(FaultPlan::Explicit(Vec::new()));
        }
        if let Some(rest) = spec.strip_prefix("random:") {
            return Self::parse_random(rest);
        }
        let mut events = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            let (lhs, rhs) = tok
                .split_once('@')
                .with_context(|| format!("fault event `{tok}`: expected site:idx@counter:at"))?;
            let (site_s, idx_s) = lhs
                .split_once(':')
                .with_context(|| format!("fault event `{tok}`: expected site:idx before @"))?;
            let (unit_s, at_s) = rhs
                .split_once(':')
                .with_context(|| format!("fault event `{tok}`: expected counter:at after @"))?;
            let site = match site_s {
                "worker" => FaultSite::Worker,
                "shard" => FaultSite::Shard,
                other => bail!("fault event `{tok}`: unknown site `{other}` (worker|shard)"),
            };
            let expect_unit = match site {
                FaultSite::Worker => "tick",
                FaultSite::Shard => "dispatch",
            };
            if unit_s != expect_unit {
                bail!("fault event `{tok}`: {site_s} faults use `{expect_unit}`, got `{unit_s}`");
            }
            let index: usize = idx_s
                .parse()
                .with_context(|| format!("fault event `{tok}`: bad index `{idx_s}`"))?;
            let at: u64 = at_s
                .parse()
                .with_context(|| format!("fault event `{tok}`: bad trigger `{at_s}`"))?;
            if at == 0 {
                bail!("fault event `{tok}`: trigger counters start at 1");
            }
            events.push(FaultEvent { site, index, at });
        }
        Ok(FaultPlan::Explicit(events))
    }

    fn parse_random(rest: &str) -> Result<FaultPlan> {
        let (mut seed, mut count, mut horizon) = (0u64, 1usize, 1000u64);
        for kv in rest.split(',') {
            let kv = kv.trim();
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("random fault spec `{kv}`: expected key=value"))?;
            match k {
                "seed" => seed = v.parse().with_context(|| format!("bad seed `{v}`"))?,
                "count" => count = v.parse().with_context(|| format!("bad count `{v}`"))?,
                "horizon" => horizon = v.parse().with_context(|| format!("bad horizon `{v}`"))?,
                other => bail!("random fault spec: unknown key `{other}` (seed|count|horizon)"),
            }
        }
        if horizon == 0 {
            bail!("random fault spec: horizon must be >= 1");
        }
        Ok(FaultPlan::Random {
            seed,
            count,
            horizon,
        })
    }

    /// True when the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        match self {
            FaultPlan::Explicit(ev) => ev.is_empty(),
            FaultPlan::Random { count, .. } => *count == 0,
        }
    }

    /// Lower the plan onto a concrete fleet shape. Explicit events are
    /// bounds-checked against it; random plans are expanded here (same
    /// spec + shape ⇒ same events). Returns one armed cell list per
    /// worker and per shard.
    pub fn compile(&self, workers: usize, shards: usize) -> Result<CompiledFaults> {
        let events: Vec<FaultEvent> = match self {
            FaultPlan::Explicit(ev) => ev.clone(),
            FaultPlan::Random {
                seed,
                count,
                horizon,
            } => {
                let mut rng = Pcg64::with_stream(*seed, FAULT_STREAM);
                (0..*count)
                    .map(|_| {
                        let slot = rng.below(workers + shards.max(1));
                        let at = 1 + rng.next_u64() % horizon;
                        if slot < workers {
                            FaultEvent {
                                site: FaultSite::Worker,
                                index: slot,
                                at,
                            }
                        } else {
                            FaultEvent {
                                site: FaultSite::Shard,
                                index: (slot - workers) % shards.max(1),
                                at,
                            }
                        }
                    })
                    .collect()
            }
        };
        let mut compiled = CompiledFaults {
            workers: vec![Vec::new(); workers],
            shards: vec![Vec::new(); shards],
            planned: events.len() as u64,
        };
        for ev in &events {
            let (lanes, bound) = match ev.site {
                FaultSite::Worker => (&mut compiled.workers, workers),
                FaultSite::Shard => (&mut compiled.shards, shards),
            };
            if ev.index >= bound {
                bail!(
                    "fault plan targets {:?} {} but the fleet has {}",
                    ev.site,
                    ev.index,
                    bound
                );
            }
            lanes[ev.index].push(Arc::new(FaultCell::new(ev.at)));
        }
        Ok(compiled)
    }
}

/// One armed trigger: fires the first time the owning component's
/// progress counter reaches `at`, then stays spent forever (respawned
/// components are not re-killed by the same event).
#[derive(Debug)]
pub struct FaultCell {
    at: u64,
    fired: AtomicBool,
}

impl FaultCell {
    /// Cell armed at counter value `at`.
    pub fn new(at: u64) -> Self {
        Self {
            at,
            fired: AtomicBool::new(false),
        }
    }

    /// Trigger point this cell is armed at.
    pub fn at(&self) -> u64 {
        self.at
    }

    /// True exactly once: the first call with `counter >= at`.
    pub fn should_fire(&self, counter: u64) -> bool {
        counter >= self.at && !self.fired.swap(true, Ordering::SeqCst)
    }
}

/// A [`FaultPlan`] lowered onto a concrete fleet: per-component armed
/// cells plus the planned event total (for end-of-run assertions).
#[derive(Debug, Default)]
pub struct CompiledFaults {
    /// Armed cells per worker id.
    pub workers: Vec<Vec<Arc<FaultCell>>>,
    /// Armed cells per shard index.
    pub shards: Vec<Vec<Arc<FaultCell>>>,
    /// Total events the plan schedules.
    pub planned: u64,
}

impl CompiledFaults {
    /// Cells for worker `id` (empty ⇒ hand the hot loop `None`).
    pub fn worker_cells(&self, id: usize) -> Option<Vec<Arc<FaultCell>>> {
        let cells = self.workers.get(id)?.clone();
        if cells.is_empty() {
            None
        } else {
            Some(cells)
        }
    }

    /// Cells for shard `idx` (empty ⇒ hand the serve loop `None`).
    pub fn shard_cells(&self, idx: usize) -> Option<Vec<Arc<FaultCell>>> {
        let cells = self.shards.get(idx)?.clone();
        if cells.is_empty() {
            None
        } else {
            Some(cells)
        }
    }
}

/// Injection-point helper: if any armed cell fires at `counter`, bump the
/// fleet-wide counter and panic with a recognizable payload. Call sites
/// gate this behind `Option::Some`, so a run without a plan pays one
/// branch per tick and nothing else.
pub fn trip(cells: &[Arc<FaultCell>], counter: u64, injected: &AtomicU64, what: &str) {
    for cell in cells {
        if cell.should_fire(counter) {
            injected.fetch_add(1, Ordering::SeqCst);
            panic!("fault-injection: {what} tripped at {counter} (armed at {})", cell.at());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_explicit_plan() {
        let p = FaultPlan::parse("worker:1@tick:500, shard:0@dispatch:40").unwrap();
        assert_eq!(
            p,
            FaultPlan::Explicit(vec![
                FaultEvent {
                    site: FaultSite::Worker,
                    index: 1,
                    at: 500
                },
                FaultEvent {
                    site: FaultSite::Shard,
                    index: 0,
                    at: 40
                },
            ])
        );
        assert!(!p.is_empty());
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        let p = FaultPlan::parse("  ").unwrap();
        assert!(p.is_empty());
        let c = p.compile(4, 2).unwrap();
        assert_eq!(c.planned, 0);
        assert!(c.worker_cells(0).is_none());
        assert!(c.shard_cells(1).is_none());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "worker:1",                 // no trigger
            "worker:1@dispatch:5",      // wrong counter unit
            "shard:0@tick:5",           // wrong counter unit
            "learner:0@tick:5",         // unknown site
            "worker:x@tick:5",          // bad index
            "worker:1@tick:0",          // counters start at 1
            "random:seed=1,horizon=0",  // degenerate horizon
            "random:seed=1,period=3",   // unknown key
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn compile_bounds_checks_the_fleet() {
        let p = FaultPlan::parse("worker:4@tick:10").unwrap();
        assert!(p.compile(4, 2).is_err());
        let p = FaultPlan::parse("shard:2@dispatch:10").unwrap();
        assert!(p.compile(4, 2).is_err());
    }

    #[test]
    fn random_plans_are_deterministic_per_shape() {
        let p = FaultPlan::parse("random:seed=7,count=5,horizon=100").unwrap();
        let a = p.compile(4, 2).unwrap();
        let b = p.compile(4, 2).unwrap();
        assert_eq!(a.planned, 5);
        let ats = |c: &CompiledFaults| -> Vec<Vec<u64>> {
            c.workers
                .iter()
                .chain(c.shards.iter())
                .map(|cells| cells.iter().map(|f| f.at()).collect())
                .collect()
        };
        assert_eq!(ats(&a), ats(&b));
        // every drawn trigger honors the horizon
        assert!(ats(&a).iter().flatten().all(|&t| (1..=100).contains(&t)));
    }

    #[test]
    fn cell_fires_exactly_once() {
        let cell = FaultCell::new(10);
        assert!(!cell.should_fire(9));
        assert!(cell.should_fire(10));
        assert!(!cell.should_fire(10));
        assert!(!cell.should_fire(11)); // spent for good — respawns survive
    }

    #[test]
    fn trip_panics_and_counts() {
        let cells = vec![Arc::new(FaultCell::new(3))];
        let injected = AtomicU64::new(0);
        trip(&cells, 2, &injected, "worker 0");
        assert_eq!(injected.load(Ordering::SeqCst), 0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            trip(&cells, 3, &injected, "worker 0");
        }));
        assert!(err.is_err());
        assert_eq!(injected.load(Ordering::SeqCst), 1);
        // spent: calling again is a no-op
        trip(&cells, 4, &injected, "worker 0");
        assert_eq!(injected.load(Ordering::SeqCst), 1);
    }
}
