//! Small statistics toolkit: summary stats, quantiles, running moments,
//! linear regression (used to fit the speedup curve for Fig 5), and EWMA.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p99: f64::NAN,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: quantile_sorted(&sorted, 0.5),
            p90: quantile_sorted(&sorted, 0.9),
            p99: quantile_sorted(&sorted, 0.99),
        }
    }

    pub fn of_f32(xs: &[f32]) -> Summary {
        Summary::of(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }
}

/// Linear-interpolated quantile of a pre-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn mean_f32(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        f32::NAN
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

pub fn std_f32(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return f32::NAN;
    }
    let m = mean_f32(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
}

/// Ordinary least squares y = a + b x. Returns (intercept a, slope b, r^2).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

/// Numerically stable running mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Raw accumulator registers `(n, mean, m2)` for checkpointing.
    pub fn raw(&self) -> (u64, f64, f64) {
        (self.n, self.mean, self.m2)
    }

    /// Rebuild an accumulator from [`Welford::raw`] output (bitwise
    /// resume of the running moments).
    pub fn from_raw(n: u64, mean: f64, m2: f64) -> Self {
        Self { n, mean, m2 }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Merge another accumulator (parallel Welford, Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
    }
}

/// Exponentially weighted moving average.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn quantiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert_eq!(quantile_sorted(&sorted, 0.5), 5.0);
        assert_eq!(quantile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn linreg_recovers_exact_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b, r2) = linreg(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.var() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = Welford::default();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::default();
        let mut b = Welford::default();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
        assert_eq!(a.n, all.n);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.3);
        for _ in 0..100 {
            e.push(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }
}
