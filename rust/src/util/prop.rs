//! Mini property-testing harness (the offline crate set has no `proptest`).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it performs greedy shrinking via the generator's `shrink` hook
//! and panics with the minimal counter-example it found, plus the seed to
//! reproduce. Coordinator invariants (routing, batching, queue state) and
//! the GAE/normalizer math are property-tested with this.

use crate::util::rng::Pcg64;

/// A random-input generator with optional shrinking.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;

    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Candidate smaller inputs; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` random inputs from `gen` (seeded, reproducible).
/// Panics with the (shrunk) counter-example on failure.
pub fn check<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(gen, input, &prop);
            panic!(
                "property failed (seed={seed}, case={case}).\n\
                 minimal counter-example: {minimal:#?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy: keep taking the first shrink candidate that still fails.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

// ---------------------------------------------------------------------------
// stock generators
// ---------------------------------------------------------------------------

/// usize in [lo, hi]; shrinks toward lo.
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f32 in [lo, hi); shrinks toward 0 (clamped into range).
pub struct F32In(pub f32, pub f32);

impl Gen for F32In {
    type Value = f32;

    fn generate(&self, rng: &mut Pcg64) -> f32 {
        rng.uniform(self.0, self.1)
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let zero = 0.0f32.clamp(self.0, self.1);
        if (*v - zero).abs() < 1e-6 {
            Vec::new()
        } else {
            vec![zero, *v / 2.0]
        }
    }
}

/// Vec of f32 with length in [min_len, max_len]; shrinks by halving length
/// and zeroing elements.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let len = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..len).map(|_| rng.uniform(self.lo, self.hi)).collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, &UsizeIn(0, 100), |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counter-example")]
    fn failing_property_panics_with_counterexample() {
        check(2, 200, &UsizeIn(0, 100), |&v| v < 50);
    }

    #[test]
    fn shrinks_to_boundary() {
        // verify the shrinker finds the minimal failing usize (50)
        let gen = UsizeIn(0, 100);
        let failing = 93usize;
        let min = shrink_loop(&gen, failing, &|&v: &usize| v < 50);
        assert_eq!(min, 50);
    }

    #[test]
    fn vec_gen_respects_length_bounds() {
        let gen = VecF32 {
            min_len: 2,
            max_len: 9,
            lo: -1.0,
            hi: 1.0,
        };
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((2..=9).contains(&v.len()));
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        }
    }

    #[test]
    fn pair_generates_both() {
        let gen = Pair(UsizeIn(1, 4), F32In(0.0, 1.0));
        check(4, 100, &gen, |(n, x)| *n >= 1 && *x < 1.0);
    }
}
