//! Hand-rolled substrates: RNG, JSON, CLI parsing, statistics, timing,
//! logging and a mini property-test harness.
//!
//! These exist because the build environment is fully offline and the
//! cached crate set has no `rand` / `serde` / `clap` / `proptest`; see
//! DESIGN.md §7. Each module is small, documented and unit-tested.

pub mod bytes;
pub mod cli;
pub mod fault;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Poison-tolerant lock: recover the guard when another thread panicked
/// while holding the mutex. Used on coordination paths (inference pool
/// queues, completion slots, the policy store, shutdown guards) where the
/// protected state stays structurally valid across an unwinding writer —
/// there, propagating the poison would turn one worker's panic into a
/// fleet-wide deadlock instead of the logged termination we want.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant condvar wait with timeout: the companion to [`plock`]
/// for the wait side of the same coordination paths.
pub fn cv_wait<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

/// Untimed [`cv_wait`]: for waits whose wakeup is guaranteed by a paired
/// notify (e.g. the bounded channel), where a timeout would only mask a
/// missing-notify bug.
pub fn cv_wait_untimed<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

// Dedicated poison-tolerance stress coverage: the chaos/supervision layer
// leans on plock/cv_wait surviving panics that unwind *while holding* the
// coordination locks, so that property gets exercised head-on here rather
// than incidentally through the fleet tests.
#[cfg(test)]
mod poison_tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Condvar, Mutex};
    use std::thread;
    use std::time::Duration;

    #[test]
    fn plock_counts_exactly_across_concurrent_panickers() {
        let m = Arc::new(Mutex::new(0u64));
        let workers = 4;
        let panickers = 4;
        let per_worker = 2000u64;
        thread::scope(|s| {
            for _ in 0..panickers {
                let m = &m;
                s.spawn(move || {
                    let t = thread::spawn({
                        let m = Arc::clone(m);
                        move || {
                            let mut g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                            *g += 1; // poisoned increments still count below
                            panic!("mid-run poison");
                        }
                    });
                    assert!(t.join().is_err());
                });
            }
            for _ in 0..workers {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..per_worker {
                        *plock(m) += 1;
                    }
                });
            }
        });
        // the mutex IS poisoned...
        assert!(m.lock().is_err());
        // ...and yet not a single plock increment was lost or doubled
        assert_eq!(*plock(&m), workers * per_worker + panickers);
    }

    #[test]
    fn cv_wait_survives_a_poisoned_pair() {
        let pair = Arc::new((Mutex::new(0u64), Condvar::new()));
        // poison the condvar's mutex while a waiter is parked on it
        let waiter = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut g = plock(m);
                while *g != 7 {
                    g = cv_wait(cv, g, Duration::from_millis(20));
                }
                *g
            })
        };
        let poisoner = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let (m, _cv) = &*pair;
                let _g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                panic!("poison under the waiter");
            })
        };
        assert!(poisoner.join().is_err());
        {
            let (m, cv) = &*pair;
            *plock(m) = 7;
            cv.notify_all();
        }
        assert_eq!(waiter.join().unwrap(), 7);
        assert!(pair.0.lock().is_err()); // the wait really crossed a poisoned lock
    }

    #[test]
    fn cv_wait_untimed_wakes_through_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let woke = Arc::new(AtomicBool::new(false));
        let waiter = {
            let (pair, woke) = (Arc::clone(&pair), Arc::clone(&woke));
            thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut g = plock(m);
                while !*g {
                    g = cv_wait_untimed(cv, g);
                }
                woke.store(true, Ordering::SeqCst);
            })
        };
        thread::sleep(Duration::from_millis(10));
        let poisoner = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let (m, _cv) = &*pair;
                let _g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                panic!("poison before the notify");
            })
        };
        assert!(poisoner.join().is_err());
        {
            let (m, cv) = &*pair;
            *plock(m) = true;
            cv.notify_all();
        }
        waiter.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
    }
}
