//! Hand-rolled substrates: RNG, JSON, CLI parsing, statistics, timing,
//! logging and a mini property-test harness.
//!
//! These exist because the build environment is fully offline and the
//! cached crate set has no `rand` / `serde` / `clap` / `proptest`; see
//! DESIGN.md §7. Each module is small, documented and unit-tested.

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
