//! Hand-rolled substrates: RNG, JSON, CLI parsing, statistics, timing,
//! logging and a mini property-test harness.
//!
//! These exist because the build environment is fully offline and the
//! cached crate set has no `rand` / `serde` / `clap` / `proptest`; see
//! DESIGN.md §7. Each module is small, documented and unit-tested.

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Poison-tolerant lock: recover the guard when another thread panicked
/// while holding the mutex. Used on coordination paths (inference pool
/// queues, completion slots, the policy store, shutdown guards) where the
/// protected state stays structurally valid across an unwinding writer —
/// there, propagating the poison would turn one worker's panic into a
/// fleet-wide deadlock instead of the logged termination we want.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant condvar wait with timeout: the companion to [`plock`]
/// for the wait side of the same coordination paths.
pub fn cv_wait<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

/// Untimed [`cv_wait`]: for waits whose wakeup is guaranteed by a paired
/// notify (e.g. the bounded channel), where a timeout would only mask a
/// missing-notify bug.
pub fn cv_wait_untimed<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}
