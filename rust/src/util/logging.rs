//! Leveled stderr logger with per-run verbosity (no `log` facade needed —
//! WALL-E is a binary-first framework and the coordinator owns the output).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Write one log line (used by the macros below).
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{tag}] {module}: {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_query_level() {
        let prev = level();
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Error);
        assert!(!enabled(Level::Info));
        set_level(prev);
    }
}
