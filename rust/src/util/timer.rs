//! Timing utilities: scoped stopwatch and a named-phase accumulator used by
//! the coordinator to attribute each iteration's wall-clock to experience
//! collection vs policy learning (the paper's Figs 4–7 decomposition).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Per-thread CPU time in seconds (CLOCK_THREAD_CPUTIME_ID) — counts only
/// cycles this thread actually executed, immune to preemption. This is
/// what the sampler busy-time accounting uses so that the virtual-core
/// timing model (DESIGN.md §3) stays exact even when N worker threads
/// share fewer physical cores.
pub fn thread_cpu_secs() -> f64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates durations under string keys; cheap enough for per-iteration
/// bookkeeping (a handful of map lookups per iteration, not per step).
#[derive(Debug, Clone, Default)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, Duration>,
}

impl PhaseTimer {
    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
    }

    /// Time a closure and accumulate under `phase`, returning its value.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn secs(&self, phase: &str) -> f64 {
        self.acc
            .get(phase)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|d| d.as_secs_f64()).sum()
    }

    /// Fraction of the accumulated total spent in `phase` (0 if empty).
    pub fn fraction(&self, phase: &str) -> f64 {
        let total = self.total_secs();
        if total == 0.0 {
            0.0
        } else {
            self.secs(phase) / total
        }
    }

    pub fn reset(&mut self) {
        self.acc.clear();
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, v.as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_time_advances_with_work() {
        let t0 = thread_cpu_secs();
        // burn some CPU
        let mut acc = 0u64;
        for i in 0..5_000_000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        let spin = thread_cpu_secs() - t0;
        assert!(spin > 0.0, "cpu time did not advance");
        // and sleeping must NOT advance it (the whole point)
        let t1 = thread_cpu_secs();
        std::thread::sleep(Duration::from_millis(30));
        let slept = thread_cpu_secs() - t1;
        assert!(slept < 0.02, "sleep counted as cpu time: {slept}");
    }

    #[test]
    fn stopwatch_measures_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(10));
        assert!(sw.elapsed_secs() >= 0.009);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::default();
        t.add("collect", Duration::from_millis(30));
        t.add("learn", Duration::from_millis(10));
        t.add("collect", Duration::from_millis(30));
        assert!((t.secs("collect") - 0.06).abs() < 1e-9);
        assert!((t.secs("learn") - 0.01).abs() < 1e-9);
        assert!((t.fraction("collect") - 6.0 / 7.0).abs() < 1e-9);
        assert_eq!(t.secs("missing"), 0.0);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimer::default();
        let v = t.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(t.secs("work") >= 0.0);
    }

    #[test]
    fn reset_clears() {
        let mut t = PhaseTimer::default();
        t.add("a", Duration::from_millis(5));
        t.reset();
        assert_eq!(t.total_secs(), 0.0);
    }
}
