//! Minimal command-line argument parser (the offline crate set has no
//! `clap`). Supports subcommands, `--flag`, `--key value` / `--key=value`,
//! typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

/// CLI parse/validation error.
#[derive(Debug, thiserror::Error)]
#[error("{0}")]
pub struct CliError(pub String);

impl Args {
    /// Parse raw argv (without the program name). The first token that does
    /// not start with `--` becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(CliError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    // (then it is a boolean switch).
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else if args.command.is_none() && args.positional.is_empty() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected float, got {v:?}"))),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(CliError(format!("--{key}: expected bool, got {v:?}"))),
        }
    }

    /// Comma-separated usize list, e.g. `--samplers 1,2,4,8,10`.
    pub fn usize_list_or(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, CliError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{key}: bad list item {s:?}")))
                })
                .collect(),
        }
    }

    /// Fail if a required flag is absent.
    pub fn require(&self, key: &str) -> Result<&str, CliError> {
        self.get(key)
            .ok_or_else(|| CliError(format!("missing required flag --{key}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --env halfcheetah --samplers 10 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("env"), Some("halfcheetah"));
        assert_eq!(a.usize_or("samplers", 1).unwrap(), 10);
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn equals_form() {
        let a = parse("figures --fig=4 --out-dir=/tmp/x");
        assert_eq!(a.usize_or("fig", 0).unwrap(), 4);
        assert_eq!(a.get("out-dir"), Some("/tmp/x"));
    }

    #[test]
    fn boolean_switch_before_flag() {
        let a = parse("train --fast --env pendulum");
        assert!(a.bool_or("fast", false).unwrap());
        assert_eq!(a.get("env"), Some("pendulum"));
    }

    #[test]
    fn numeric_and_list_parsing() {
        let a = parse("x --lr 3e-4 --ns 1,2,4");
        assert!((a.f32_or("lr", 0.0).unwrap() - 3e-4).abs() < 1e-9);
        assert_eq!(a.usize_list_or("ns", &[]).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn errors_on_bad_types() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.require("missing").is_err());
        assert!(parse("x --b maybe").bool_or("b", false).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("x --offset -3");
        // "-3" doesn't start with --, so it's consumed as the value
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn positional_after_command() {
        let a = parse("eval ckpt.bin more");
        assert_eq!(a.command.as_deref(), Some("eval"));
        assert_eq!(a.positional(), &["ckpt.bin".to_string(), "more".to_string()]);
    }
}
