//! Tiny little-endian byte (de)serializer for durable state.
//!
//! The offline crate set has no `serde`/`bincode`, so checkpoint files
//! and opaque sampler-state blobs are written through this hand-rolled
//! codec: fixed-width little-endian scalars plus `u64`-length-prefixed
//! slices. The reader is strictly bounds-checked and returns `Err`
//! instead of panicking on truncated or corrupt input, so a damaged
//! checkpoint surfaces as a clean resume error rather than a crash.

use anyhow::{bail, Result};

/// Append-only byte sink; every scalar is written little-endian.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer and return its buffer.
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// Write a `u32`.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `u64`.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `u128`.
    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `usize` (as `u64`; the codebase targets 64-bit hosts).
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Write an `f32` (bit pattern, so round-trips are bitwise).
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an `f64` (bit pattern, so round-trips are bitwise).
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a length-prefixed raw byte slice.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Write a length-prefixed `f32` slice (element count, then bits).
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Write a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

/// Bounds-checked reader over a [`ByteWriter`]-produced buffer.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when the buffer is fully consumed.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated buffer: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u32`.
    pub fn read_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64`.
    pub fn read_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u128`.
    pub fn read_u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    /// Read a `usize` (stored as `u64`).
    pub fn read_usize(&mut self) -> Result<usize> {
        Ok(self.read_u64()? as usize)
    }

    /// Read an `f32`.
    pub fn read_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read an `f64`.
    pub fn read_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a length-prefixed raw byte slice.
    pub fn read_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.read_u64()? as usize;
        self.take(n)
    }

    /// Read a length-prefixed `f32` vector.
    pub fn read_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.read_u64()? as usize;
        // sanity cap: element count cannot exceed remaining bytes / 4
        if n > self.remaining() / 4 {
            bail!("corrupt f32 slice length {n} at offset {}", self.pos);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.read_f32()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn read_str(&mut self) -> Result<String> {
        let b = self.read_bytes()?;
        Ok(String::from_utf8(b.to_vec())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trip_is_bitwise() {
        let mut w = ByteWriter::new();
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 7);
        w.put_u128(u128::MAX / 3);
        w.put_usize(42);
        w.put_f32(-0.0);
        w.put_f64(f64::NAN);
        w.put_str("chunk");
        w.put_f32s(&[1.5, -2.25, f32::INFINITY]);
        w.put_bytes(&[9, 8, 7]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.read_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.read_u64().unwrap(), u64::MAX - 7);
        assert_eq!(r.read_u128().unwrap(), u128::MAX / 3);
        assert_eq!(r.read_usize().unwrap(), 42);
        assert_eq!(r.read_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.read_f64().unwrap().is_nan());
        assert_eq!(r.read_str().unwrap(), "chunk");
        assert_eq!(r.read_f32s().unwrap(), vec![1.5, -2.25, f32::INFINITY]);
        assert_eq!(r.read_bytes().unwrap(), &[9, 8, 7]);
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_input_errors_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.put_u64(1234);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf[..5]);
        assert!(r.read_u64().is_err());
    }

    #[test]
    fn corrupt_slice_length_is_rejected() {
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // absurd element count
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert!(r.read_f32s().is_err());
        let mut r2 = ByteReader::new(&buf);
        assert!(r2.read_bytes().is_err());
    }
}
