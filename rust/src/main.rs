//! `walle` — the WALL-E launcher: a thin CLI adapter over
//! `walle::session::Session` (flags → `TrainConfig` → builder; all run
//! logic lives in the library).
//!
//! Subcommands:
//!   train    train a policy (PPO, DDPG, TD3, or SAC) with N parallel samplers
//!   eval     evaluate a saved policy checkpoint deterministically
//!   figures  regenerate the paper's figures (3–7) as CSV series
//!   info     show the resolved SessionSpec for a config
//!
//! Examples:
//!   walle train --env halfcheetah --samplers 10 --iterations 200 --backend xla
//!   walle train --env pendulum --algo td3 --backend native
//!   walle figures --all --out-dir results
//!   walle eval --env pendulum --checkpoint runs/pendulum/params.bin

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

use walle::bench::figures;
use walle::config::{
    Algo, Backend, EnvEngineCfg, FleetMode, InferEpoch, InferPrecision, InferShards, InferWait,
    InferenceMode, KernelsCfg, ReplayStrategy, TrainConfig,
};
use walle::runtime::daemon;
use walle::session::{load_params, Session};
use walle::util::cli::Args;
use walle::util::logging::{set_level, Level};

const USAGE: &str = "\
walle — An Efficient Reinforcement Learning Research Framework

USAGE:
  walle <COMMAND> [FLAGS]

COMMANDS:
  train     train a policy with N parallel rollout samplers
  eval      deterministically evaluate a saved checkpoint
  figures   regenerate the paper's evaluation figures as CSVs
  info      show the resolved session spec (algorithm, hyper-parameters,
            inference topology) for a config
  serve     run a standalone policy daemon: the shared inference pool
            behind a Unix socket, serving `walle sample` processes
  sample    run one sampler worker against a policy daemon (normally
            spawned by `train --fleet-mode procs`, not by hand)

COMMON FLAGS:
  --env NAME             pendulum|cartpole|reacher|halfcheetah
  --backend NAME         xla|native (default native)
  --config FILE          load a JSON TrainConfig (flags override)
  --seed N               root RNG seed
  --verbose / --quiet    log level

TRAIN FLAGS:
  --samplers N           parallel sampler workers (paper's N, default 10)
  --envs-per-sampler M   vectorized envs per worker, one batched policy
                         forward drives all M in lockstep (default 1)
  --inference-mode MODE  local = private backend per worker (default);
                         shared = a sharded inference pool batches the
                         workers' rows into fleet-wide forwards
  --infer-shards S       shared mode: number of inference-server shards,
                         `auto` (default) = clamp(N/8, 1, cores/2);
                         worker w is served by shard w % S
  --infer-wait POLICY    shared mode straggler cut: `adaptive` (default)
                         tracks inter-arrival gaps and dispatches when
                         waiting stops paying; `fixed:<us>` dispatches a
                         partial batch after exactly <us> microseconds
  --infer-epoch MODE     shared mode version adoption: `pool` (default)
                         flips every shard to a new policy version on the
                         same dispatch boundary (shard count stays a pure
                         performance knob across publishes); `shard` lets
                         each shard observe the store independently
  --infer-precision P    inference numeric precision: `f32` (default) or
                         `int8` — quantize each published actor snapshot
                         to int8 weights + f32 scales for the shared
                         pool's forwards (native backend + shared
                         inference only; the learner stays f32)
  --kernels MODE         `exact` (default) keeps the SIMD microkernels
                         bitwise-identical to the scalar reference;
                         `fast` enables FMA register tiling (~1e-6
                         relative drift, higher throughput)
  --env-engine E         `auto` (default, resolves to `batched`) steps a
                         worker's M envs as one structure-of-arrays
                         sweep; `scalar` forces the legacy per-env loop;
                         bitwise interchangeable under --kernels exact
  --iterations N         training iterations
  --samples-per-iter N   samples per iteration (paper: 20000)
  --algo NAME            learner algorithm: ppo|ddpg|td3|sac
  --replay-shards S      off-policy replay-buffer shards (default 1); the
                         sampled minibatch is shard-count invariant, so S
                         is a pure insert-throughput knob
  --learner-threads L    off-policy learner threads (default 1); grained
                         gradients + fixed-order tree reduction keep
                         published params bitwise identical for any L
                         (native backend only)
  --replay-strategy S    off-policy sampling: uniform (default) or
                         prioritized (proportional TD error, normalized
                         importance weights)
  --sync                 synchronous barrier mode (ablation)
  --checkpoint-every K   write a durable checkpoint after every K-th
                         iteration into --checkpoint-dir (0 = off)
  --checkpoint-dir DIR   checkpoint directory (default `checkpoints`)
  --resume DIR           resume training from the newest checkpoint in
                         DIR (topology + seed must match the checkpoint)
  --max-restarts N       supervisor respawn budget per component after a
                         panic (default 2; 0 = fail fast, PR 4 behavior)
  --fault-inject SPEC    deterministic fault plan for chaos testing:
                         `worker:1@tick:500,shard:0@dispatch:40` or
                         `random:seed=7,count=2,horizon=1000`
  --flip-schedule K      shared pool mode: flip the epoch gate every K
                         fleet dispatches instead of at publish
                         boundaries (0 = off; needs --infer-epoch pool)
  --fleet-mode MODE      `threads` (default) runs samplers as in-process
                         threads; `procs` runs each sampler as a `walle
                         sample` child process served by an in-process
                         policy daemon over a Unix socket (requires
                         --inference-mode shared); per-env chunk streams
                         are bitwise identical either way
  --learner-shards N     data-parallel learner shards (§6.2, PPO only)
  --epochs N / --lr F    PPO optimization knobs (PPO only)
  --out-dir DIR          write metrics.csv + params.bin + config.json

SERVE FLAGS:
  --socket PATH          Unix socket to bind (default: a fresh path under
                         the temp dir, logged at startup)
  --watch-dir DIR        poll DIR for checkpoints (--checkpoint-every
                         output of a colocated learner) and hot-swap the
                         served policy to each newer one

SAMPLE FLAGS:
  --connect PATH         the daemon's Unix socket (required)
  --worker-id N          this worker's fleet slot (default 0); every
                         connected sampler needs a distinct id
  --config FILE          run config; defaults to the daemon's
                         `<socket>.config.json` sidecar

FIGURES FLAGS:
  --all | --fig N        which figure(s): 3,4,5,6,7
  --ns LIST              sampler counts, e.g. 1,2,4,6,8,10
  --iterations N         iterations per point
  --out-dir DIR          output directory (default results)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        set_level(Level::Debug);
    } else if args.has("quiet") {
        set_level(Level::Warn);
    }
    let code = match args.command.as_deref() {
        Some("train") => run_train(&args),
        Some("eval") => run_eval(&args),
        Some("figures") => run_figures(&args),
        Some("info") => run_info(&args),
        Some("serve") => run_serve(&args),
        Some("sample") => run_sample(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Build a TrainConfig from --config + flag overrides. (Validation —
/// including the structural cross-checks — happens in
/// `Session::builder().config(..).build()`.)
fn config_from(args: &Args) -> anyhow::Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::load(path)?,
        None => TrainConfig::preset(&args.str_or("env", "halfcheetah")),
    };
    if let Some(env) = args.get("env") {
        cfg.env = env.to_string();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(a)
            .ok_or_else(|| anyhow::anyhow!("bad --algo {a:?} (ppo|ddpg|td3|sac)"))?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b).ok_or_else(|| anyhow::anyhow!("bad --backend {b:?}"))?;
    }
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.samplers = args.usize_or("samplers", cfg.samplers)?;
    cfg.envs_per_sampler = args.usize_or("envs-per-sampler", cfg.envs_per_sampler)?;
    if let Some(mode) = args.get("inference-mode") {
        cfg.inference_mode = InferenceMode::parse(mode)
            .ok_or_else(|| anyhow::anyhow!("bad --inference-mode {mode:?} (local|shared)"))?;
    }
    if let Some(s) = args.get("infer-shards") {
        cfg.infer_shards = InferShards::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --infer-shards {s:?} (auto or a count >= 1)"))?;
    }
    if let Some(w) = args.get("infer-wait") {
        cfg.infer_wait = InferWait::parse(w)
            .ok_or_else(|| anyhow::anyhow!("bad --infer-wait {w:?} (adaptive or fixed:<us>)"))?;
    } else if args.has("infer-max-wait-us") {
        // legacy PR 2 spelling: a fixed straggler cut in microseconds
        walle::config::warn_legacy_infer_max_wait_us();
        cfg.infer_wait = InferWait::Fixed(args.u64_or("infer-max-wait-us", 200)?);
    }
    if let Some(e) = args.get("infer-epoch") {
        cfg.infer_epoch = InferEpoch::parse(e)
            .ok_or_else(|| anyhow::anyhow!("bad --infer-epoch {e:?} (pool|shard)"))?;
    }
    if let Some(p) = args.get("infer-precision") {
        cfg.infer_precision = InferPrecision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("bad --infer-precision {p:?} (f32|int8)"))?;
    }
    if let Some(k) = args.get("kernels") {
        cfg.kernels = KernelsCfg::parse(k)
            .ok_or_else(|| anyhow::anyhow!("bad --kernels {k:?} (exact|fast)"))?;
    }
    if let Some(e) = args.get("env-engine") {
        cfg.env_engine = EnvEngineCfg::parse(e)
            .ok_or_else(|| anyhow::anyhow!("bad --env-engine {e:?} (auto|batched|scalar)"))?;
    }
    cfg.iterations = args.usize_or("iterations", cfg.iterations)?;
    cfg.samples_per_iter = args.usize_or("samples-per-iter", cfg.samples_per_iter)?;
    cfg.chunk_steps = args.usize_or("chunk-steps", cfg.chunk_steps)?;
    cfg.queue_capacity = args.usize_or("queue-capacity", cfg.queue_capacity)?;
    // PPO-only CLI knobs: reject loudly under other algorithms instead
    // of silently ignoring them
    if cfg.algo != Algo::Ppo {
        for knob in ["lr", "epochs", "learner-shards"] {
            if args.has(knob) {
                anyhow::bail!(
                    "--{knob} is a PPO-only knob but --algo is {} — drop it or \
                     set the matching {} hyper-parameter in a --config file",
                    cfg.algo.name(),
                    cfg.algo.name()
                );
            }
        }
    }
    cfg.learner_shards = args.usize_or("learner-shards", cfg.learner_shards)?;
    cfg.ppo.lr = args.f32_or("lr", cfg.ppo.lr)?;
    cfg.ppo.epochs = args.usize_or("epochs", cfg.ppo.epochs)?;
    // off-policy replay/learner knobs (cfg.validate() rejects them under
    // PPO and checks the backend constraints)
    cfg.replay_shards = args.usize_or("replay-shards", cfg.replay_shards)?;
    cfg.learner_threads = args.usize_or("learner-threads", cfg.learner_threads)?;
    if let Some(s) = args.get("replay-strategy") {
        cfg.replay_strategy = ReplayStrategy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --replay-strategy {s:?} (uniform|prioritized)"))?;
    }
    if args.has("sync") {
        cfg.async_mode = false;
    }
    cfg.checkpoint_every = args.usize_or("checkpoint-every", cfg.checkpoint_every)?;
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = d.to_string();
    }
    if let Some(d) = args.get("resume") {
        cfg.resume = d.to_string();
    }
    if let Some(s) = args.get("fault-inject") {
        cfg.fault_inject = s.to_string();
    }
    cfg.flip_schedule = args.u64_or("flip-schedule", cfg.flip_schedule)?;
    cfg.max_restarts = args.usize_or("max-restarts", cfg.max_restarts)?;
    if let Some(fm) = args.get("fleet-mode") {
        cfg.fleet_mode = FleetMode::parse(fm)
            .ok_or_else(|| anyhow::anyhow!("bad --fleet-mode {fm:?} (threads|procs)"))?;
    }
    if let Some(d) = args.get("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    Ok(cfg)
}

/// Flipped by [`on_signal`]; watched by `walle train` and `walle serve`
/// so SIGINT/SIGTERM drain the fleet through the normal stop/queue-close
/// paths instead of killing threads mid-write.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: libc::c_int) {
    // async-signal-safe: one atomic store, nothing else
    SHUTDOWN.store(true, Ordering::Relaxed);
}

fn install_signal_handlers() {
    unsafe {
        libc::signal(
            libc::SIGINT,
            on_signal as extern "C" fn(libc::c_int) as libc::sighandler_t,
        );
        libc::signal(
            libc::SIGTERM,
            on_signal as extern "C" fn(libc::c_int) as libc::sighandler_t,
        );
    }
}

fn run_train(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let out_dir = args.str_or("out-dir", &format!("runs/{}", cfg.env));
    let session = Session::builder().config(cfg).out_dir(&out_dir).build()?;

    for line in session.spec().render().lines() {
        walle::log_info!("{line}");
    }
    install_signal_handlers();
    let result = match session.run_watched(&SHUTDOWN) {
        Ok(r) => r,
        // a run torn down by the signal monitor surfaces as a learner
        // error (closed queue); with the flag set that IS clean shutdown
        Err(_) if SHUTDOWN.load(Ordering::Relaxed) => {
            walle::log_info!("interrupted — fleet shut down cleanly");
            return Ok(());
        }
        Err(e) => return Err(e),
    };

    let (pushed, popped, pblk, cblk) = result.queue_stats;
    walle::log_info!(
        "done: {} iterations, queue pushed {pushed} popped {popped}, \
         producer blocked {:.2}s consumer blocked {:.2}s; saved {out_dir}/params.bin",
        result.metrics.len(),
        pblk.as_secs_f64(),
        cblk.as_secs_f64()
    );
    if result.restarts > 0 || result.faults_injected > 0 {
        walle::log_info!(
            "fleet health: {} supervisor respawn(s), {} scripted fault(s) fired",
            result.restarts,
            result.faults_injected
        );
    }
    if let Some(rep) = &result.infer {
        for line in rep.render().lines() {
            walle::log_info!("{line}");
        }
    }
    Ok(())
}

fn run_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    anyhow::ensure!(
        cfg.inference_mode == InferenceMode::Shared,
        "walle serve fronts the shared inference pool — add --inference-mode shared"
    );
    let sock = match args.get("socket") {
        Some(s) => PathBuf::from(s),
        None => daemon::default_socket_path(),
    };
    let watch_dir = args.get("watch-dir").map(PathBuf::from);
    install_signal_handlers();
    let factory = walle::runtime::make_factory(&cfg)?;
    let algo = walle::algo::api::algorithm_from_config(&cfg);
    // sidecar first, so `walle sample --connect <sock>` resolves the
    // IDENTICAL config without an explicit --config
    let sidecar = daemon::config_sidecar(&sock);
    let sidecar_str = sidecar
        .to_str()
        .ok_or_else(|| anyhow::anyhow!("non-UTF8 sidecar path {}", sidecar.display()))?;
    cfg.save(sidecar_str)?;
    let summary = daemon::serve_forever(
        algo.as_ref(),
        &cfg,
        factory.as_ref(),
        &sock,
        watch_dir.as_deref(),
        &SHUTDOWN,
    );
    let _ = std::fs::remove_file(&sidecar);
    let summary = summary?;
    walle::log_info!("daemon closed: {} chunk(s) drained", summary.chunks_drained);
    for line in summary.report.render().lines() {
        walle::log_info!("{line}");
    }
    Ok(())
}

fn run_sample(args: &Args) -> anyhow::Result<()> {
    let sock = PathBuf::from(args.require("connect")?);
    let worker_id = args.usize_or("worker-id", 0)?;
    let cfg = match args.get("config") {
        Some(p) => TrainConfig::load(p)?,
        None => {
            let sidecar = daemon::config_sidecar(&sock);
            let p = sidecar.to_str().ok_or_else(|| {
                anyhow::anyhow!("non-UTF8 sidecar path {}", sidecar.display())
            })?;
            TrainConfig::load(p).map_err(|e| {
                anyhow::anyhow!(
                    "no --config given and the daemon's sidecar could not be \
                     loaded: {e:#}"
                )
            })?
        }
    };
    daemon::run_sample_child(
        &cfg,
        &sock,
        worker_id,
        std::sync::Arc::new(AtomicBool::new(false)),
    )
}

fn run_eval(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    // eval bypasses the orchestrator (which sets these for training
    // runs), so honor --kernels and --env-engine here too
    walle::nn::kernels::set_mode(cfg.kernels.mode());
    walle::env::batch::set_engine(cfg.env_engine.engine());
    let ckpt = args.require("checkpoint")?;
    let params = load_params(ckpt)?;
    let episodes = args.usize_or("episodes", 10)?;
    let session = Session::builder().config(cfg).build()?;
    let r = session.evaluate(&params, episodes)?;
    println!(
        "eval {} ({}): mean return {:.2} ± {:.2} over {} episodes (mean len {:.0})",
        session.config().env,
        session.algorithm().name(),
        r.mean_return,
        r.std_return,
        episodes,
        r.mean_len
    );
    Ok(())
}

fn run_figures(args: &Args) -> anyhow::Result<()> {
    let mut cfg = config_from(args)?;
    // figure sweeps need only a handful of steady-state iterations per
    // point; the training default (100) would make `figures --all` take
    // hours. Explicit --iterations still wins.
    if args.get("iterations").is_none() {
        cfg.iterations = 4;
    }
    // validate the base combination once up front (each sweep point
    // re-validates through the session/orchestrator anyway)
    let cfg = Session::builder().config(cfg).build()?.config().clone();
    let out_dir = args.str_or("out-dir", "results");
    let ns = args.usize_list_or("ns", &[1, 2, 4, 6, 8, 10])?;
    let which: Vec<usize> = if args.has("all") || !args.has("fig") {
        vec![3, 4, 5, 6, 7]
    } else {
        vec![args.usize_or("fig", 4)?]
    };
    let factory_for = |c: &TrainConfig| walle::runtime::make_factory(c);

    if which.iter().any(|f| (4..=7).contains(f)) {
        let skip = if cfg.iterations > 2 { 1 } else { 0 };
        let rows = figures::scaling_sweep(&cfg, &factory_for, &ns, skip)?;
        figures::print_sweep_table(&rows, &format!("Figs 4-7 sweep ({})", cfg.env));
        figures::write_sweep_csvs(&rows, &out_dir)?;
        walle::log_info!("wrote fig4..fig7 CSVs to {out_dir}/");
    }
    if which.contains(&3) {
        let fig3_ns = if ns.contains(&10) { vec![1, 10] } else { ns.clone() };
        let curves = figures::fig3_return_curves(&cfg, &factory_for, &fig3_ns)?;
        figures::write_fig3_csv(&curves, &out_dir)?;
        for (n, ms) in &curves {
            let last = ms.last().map(|m| m.mean_return).unwrap_or(f32::NAN);
            walle::log_info!("fig3 N={n}: final return {last:.2}");
        }
        walle::log_info!("wrote fig3 CSV to {out_dir}/");
    }
    Ok(())
}

/// Render the resolved `SessionSpec` for a config — algorithm name,
/// hyper-parameters, and inference topology all come through the
/// `Algorithm` trait (no hard-coded per-algo matches), and the spec JSON
/// round-trips (`SessionSpec::from_json(spec.to_json())`).
fn run_info(args: &Args) -> anyhow::Result<()> {
    println!(
        "registered envs: {:?}",
        walle::env::registry::ENV_NAMES
    );
    let session = Session::builder().config(config_from(args)?).build()?;
    print!("{}", session.spec().render());
    println!("\nspec json:\n{}", session.spec().to_json());
    let env = &session.config().env;
    let artifacts_dir = session.config().artifacts_dir.clone();
    match walle::runtime::artifacts::PresetMeta::load(&artifacts_dir, env) {
        Ok(meta) => {
            println!(
                "artifacts ({artifacts_dir}/{env}): {} params, act_batch {}, minibatch {}, horizon {}",
                meta.param_count, meta.act_batch, meta.minibatch, meta.horizon
            );
        }
        Err(e) => println!("artifacts not available: {e:#}"),
    }
    Ok(())
}
