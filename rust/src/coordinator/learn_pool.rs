//! Deterministic parallel gradient pool for the off-policy learners (PR 8).
//!
//! A minibatch of B rows is cut into fixed-size *grains* of
//! [`GRAIN_ROWS`] rows. Each grain's forward/backward runs independently
//! (row-parallel math: per-grain outputs are bitwise identical no matter
//! which thread computes them), then the per-grain gradient partials are
//! combined by [`tree_reduce`] — a fixed pairwise reduction whose float
//! summation order depends only on the grain order, never on thread
//! scheduling. The same grain decomposition runs at `--learner-threads 1`
//! (serially) and at any L > 1, which is what makes the published
//! parameters **bitwise identical for every L** — a full-batch fused pass
//! would associate the row sums differently and could never match the
//! grained result bitwise. `rust/tests/chaos.rs` enforces the invariance
//! end-to-end for DDPG and TD3.
//!
//! Worker w owns grains `w, w+L, w+2L, …` (static round-robin — no work
//! queue, no ordering nondeterminism); results are placed into a slot
//! array by grain index before reduction. Threads are scoped
//! (`std::thread::scope`), so a panicking grain propagates as a learner
//! panic instead of a detached-thread leak.

/// Rows per gradient grain. Fixed — independent of thread count — so the
/// reduction tree (and therefore every float) is L-invariant.
pub const GRAIN_ROWS: usize = 64;

/// Cut `n_rows` into `[start, end)` grain ranges of [`GRAIN_ROWS`] rows
/// (last grain ragged).
pub fn grain_ranges(n_rows: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n_rows.div_euclid(GRAIN_ROWS) + 1);
    let mut start = 0;
    while start < n_rows {
        let end = (start + GRAIN_ROWS).min(n_rows);
        out.push((start, end));
        start = end;
    }
    out
}

/// Run `f(grain_index)` for every grain across `threads` workers and
/// return the results **in grain order**. `threads <= 1` runs serially on
/// the caller; either way the output is identical because `f` is pure
/// per-grain and placement is by index.
pub fn run_grains<T, F>(n_grains: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n_grains <= 1 {
        return (0..n_grains).map(f).collect();
    }
    let workers = threads.min(n_grains);
    let mut slots: Vec<Option<T>> = (0..n_grains).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let f = &f;
                s.spawn(move || {
                    (w..n_grains)
                        .step_by(workers)
                        .map(|g| (g, f(g)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (g, v) in h.join().expect("learn-pool worker panicked") {
                slots[g] = Some(v);
            }
        }
    });
    slots
        .into_iter()
        .map(|v| v.expect("grain result missing"))
        .collect()
}

/// Pairwise tree reduction of equal-length partial vectors: adjacent
/// pairs are summed until one remains. The association depends only on
/// the input order, so the result is bitwise stable across thread counts.
pub fn tree_reduce(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!parts.is_empty(), "tree_reduce over zero partials");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity((parts.len() + 1) / 2);
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                debug_assert_eq!(a.len(), b.len());
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += y;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// Scalar companion to [`tree_reduce`] (losses, per-grain row counts):
/// same pairwise association.
pub fn tree_reduce_scalar(mut parts: Vec<f32>) -> f32 {
    assert!(!parts.is_empty(), "tree_reduce_scalar over zero partials");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity((parts.len() + 1) / 2);
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a + b),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn grain_ranges_cover_exactly() {
        for n in [0, 1, 63, 64, 65, 200, 4096] {
            let gs = grain_ranges(n);
            let mut covered = 0;
            let mut cursor = 0;
            for &(s, e) in &gs {
                assert_eq!(s, cursor);
                assert!(e > s && e - s <= GRAIN_ROWS);
                covered += e - s;
                cursor = e;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn run_grains_result_is_thread_count_invariant() {
        // f32 partial sums whose order of combination matters: identical
        // results across L prove both placement-by-index and reduction.
        let mut rng = Pcg64::new(3);
        let data: Vec<Vec<f32>> = (0..13)
            .map(|_| {
                let mut v = vec![0.0f32; 32];
                rng.fill_normal(&mut v);
                v
            })
            .collect();
        let run = |threads: usize| {
            let parts = run_grains(data.len(), threads, |g| data[g].clone());
            tree_reduce(parts)
        };
        let want = run(1);
        for threads in [2, 3, 4, 8, 32] {
            let got = run(threads);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn tree_reduce_uses_fixed_pairwise_order() {
        // 3 partials: ((a+b) + c) under pairwise reduction
        let a = vec![1e8f32];
        let b = vec![-1e8f32];
        let c = vec![1.0f32];
        let got = tree_reduce(vec![a.clone(), b.clone(), c.clone()]);
        let want = ((1e8f32 + -1e8f32) + 1.0f32).to_bits();
        assert_eq!(got[0].to_bits(), want);
        assert_eq!(tree_reduce_scalar(vec![1e8, -1e8, 1.0]).to_bits(), want);
    }

    #[test]
    fn run_grains_serial_matches_parallel_for_single_grain() {
        let out = run_grains(1, 8, |g| g * 2);
        assert_eq!(out, vec![0]);
    }

    #[test]
    #[should_panic(expected = "learn-pool worker panicked")]
    fn panicking_grain_propagates() {
        run_grains(4, 2, |g| {
            if g == 3 {
                panic!("injected grain fault");
            }
            g
        });
    }
}
