//! The policy queue: versioned broadcast of policy parameters from the
//! learner to all sampler workers — the right half of the paper's Fig 2.
//!
//! Implemented as a single-slot versioned store rather than a literal
//! queue: samplers always want the *latest* parameters, so intermediate
//! versions are superseded, exactly like the paper's "primed policy queue"
//! that samplers read the freshest entry from. Readers poll cheaply
//! (version check = one atomic load) and clone the Arc only on change.

use crate::algo::normalizer::NormSnapshot;
use crate::nn::quant::QuantizedPolicySnapshot;
use crate::util::{cv_wait, plock};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Immutable snapshot shipped to samplers: parameters + obs normalization,
/// plus (when `--infer-precision int8` installed a quantizer) the int8
/// actor produced from the same parameters at publish time.
#[derive(Debug, Clone)]
pub struct PolicySnapshot {
    pub version: u64,
    /// Flat parameter vector (PPO nets or DDPG actor).
    pub params: Arc<Vec<f32>>,
    pub norm: NormSnapshot,
    /// int8 actor snapshot (None on the default f32 path). Rides the same
    /// Arc through EpochGate propose/ack/flip, so every inference shard
    /// flips to the identical quantized weights on the epoch boundary.
    pub quant: Option<Arc<QuantizedPolicySnapshot>>,
}

/// Publish-time hook turning a flat f32 parameter vector into an int8
/// actor snapshot (installed by the orchestrator when int8 inference is
/// requested; algorithm-specific — see `Algorithm::quantizer`).
pub type Quantizer = Box<dyn Fn(&[f32]) -> QuantizedPolicySnapshot + Send + Sync>;

/// Versioned single-slot broadcast store.
pub struct PolicyStore {
    slot: Mutex<Option<Arc<PolicySnapshot>>>,
    version: AtomicU64,
    changed: Condvar,
    quantizer: Mutex<Option<Quantizer>>,
}

impl PolicyStore {
    pub fn new() -> Self {
        Self {
            slot: Mutex::new(None),
            version: AtomicU64::new(0),
            changed: Condvar::new(),
            quantizer: Mutex::new(None),
        }
    }

    /// Install the publish-time quantizer (before the learner starts; the
    /// learner thread owns all publishes, so there is no ordering race).
    pub fn set_quantizer(&self, q: Quantizer) {
        *plock(&self.quantizer) = Some(q);
    }

    /// Publish new parameters; returns the new version (monotonic).
    /// Poison-tolerant: the slot always holds a complete snapshot, so a
    /// reader or writer that panicked elsewhere must not wedge the whole
    /// policy broadcast. With a quantizer installed, the int8 snapshot is
    /// produced here — once per publish, on the learner thread — so the
    /// per-request inference path never quantizes weights.
    pub fn publish(&self, params: Vec<f32>, norm: NormSnapshot) -> u64 {
        let quant = plock(&self.quantizer)
            .as_ref()
            .map(|q| Arc::new(q(&params)));
        let v = self.version.fetch_add(1, Ordering::AcqRel) + 1;
        let snap = Arc::new(PolicySnapshot {
            version: v,
            params: Arc::new(params),
            norm,
            quant,
        });
        *plock(&self.slot) = Some(snap);
        self.changed.notify_all();
        v
    }

    /// Latest published version (0 = nothing published yet).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Re-seat the version counter so the NEXT publish lands at
    /// `version + 1`. Resume-from-checkpoint calls this (before any
    /// publish, on the orchestrator thread) so the restored learner's
    /// `publish_initial` re-creates exactly the version the checkpoint
    /// barrier was taken at, keeping chunk `policy_version` labels
    /// bitwise-stable across the restart.
    pub fn resume_at(&self, version: u64) {
        self.version.store(version, Ordering::Release);
    }

    /// Cheap staleness check for samplers.
    pub fn newer_than(&self, seen: u64) -> bool {
        self.version() > seen
    }

    /// Get the latest snapshot (None before the first publish).
    pub fn latest(&self) -> Option<Arc<PolicySnapshot>> {
        plock(&self.slot).clone()
    }

    /// Block until a version newer than `seen` is published (or timeout).
    pub fn wait_newer(&self, seen: u64, timeout: Duration) -> Option<Arc<PolicySnapshot>> {
        let mut g = plock(&self.slot);
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(s) = g.as_ref() {
                if s.version > seen {
                    return Some(s.clone());
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            g = cv_wait(&self.changed, g, deadline - now);
        }
    }
}

impl Default for PolicyStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn norm(dim: usize) -> NormSnapshot {
        NormSnapshot::identity(dim)
    }

    #[test]
    fn starts_empty_with_version_zero() {
        let store = PolicyStore::new();
        assert_eq!(store.version(), 0);
        assert!(store.latest().is_none());
        assert!(!store.newer_than(0));
    }

    #[test]
    fn publish_increments_version_and_updates_slot() {
        let store = PolicyStore::new();
        let v1 = store.publish(vec![1.0, 2.0], norm(2));
        assert_eq!(v1, 1);
        let v2 = store.publish(vec![3.0, 4.0], norm(2));
        assert_eq!(v2, 2);
        let snap = store.latest().unwrap();
        assert_eq!(snap.version, 2);
        assert_eq!(*snap.params, vec![3.0, 4.0]);
        assert!(store.newer_than(1));
        assert!(!store.newer_than(2));
    }

    #[test]
    fn readers_see_latest_not_intermediate() {
        // single-slot semantics: a late reader skips superseded versions
        let store = PolicyStore::new();
        for i in 0..10 {
            store.publish(vec![i as f32], norm(1));
        }
        assert_eq!(*store.latest().unwrap().params, vec![9.0]);
    }

    #[test]
    fn wait_newer_blocks_until_publish() {
        let store = Arc::new(PolicyStore::new());
        store.publish(vec![0.0], norm(1));
        let s2 = store.clone();
        let h = thread::spawn(move || s2.wait_newer(1, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(30));
        store.publish(vec![1.0], norm(1));
        let snap = h.join().unwrap().expect("should see v2");
        assert_eq!(snap.version, 2);
    }

    #[test]
    fn wait_newer_times_out() {
        let store = PolicyStore::new();
        store.publish(vec![0.0], norm(1));
        let got = store.wait_newer(1, Duration::from_millis(30));
        assert!(got.is_none());
    }

    #[test]
    fn installed_quantizer_attaches_int8_snapshot_on_publish() {
        use crate::nn::layout::ppo_layout;
        use crate::nn::mlp::NetShape;
        use crate::nn::quant::quantize_ppo;
        let shape = NetShape::new(3, 2, &[8]);
        let layout = ppo_layout(3, 2, &[8]);
        let mut rng = crate::util::rng::Pcg64::new(7);
        let flat = layout.init_flat(&mut rng);

        let store = PolicyStore::new();
        store.publish(flat.clone(), norm(3));
        assert!(store.latest().unwrap().quant.is_none(), "no quantizer yet");

        store.set_quantizer(Box::new(move |p| quantize_ppo(&layout, p, &shape)));
        store.publish(flat, norm(3));
        let snap = store.latest().unwrap();
        let q = snap.quant.as_ref().expect("quantized snapshot attached");
        assert_eq!(q.obs_dim, 3);
        assert_eq!(q.act_dim, 2);
        assert!(q.vf.is_some());
    }

    #[test]
    fn concurrent_publish_and_read_is_consistent() {
        let store = Arc::new(PolicyStore::new());
        let s2 = store.clone();
        let writer = thread::spawn(move || {
            for i in 0..1000u64 {
                s2.publish(vec![i as f32], norm(1));
            }
        });
        let s3 = store.clone();
        let reader = thread::spawn(move || {
            let mut last = 0u64;
            for _ in 0..1000 {
                if let Some(s) = s3.latest() {
                    // versions observed must be monotonic and params match
                    assert!(s.version >= last);
                    assert_eq!(*s.params, vec![(s.version - 1) as f32]);
                    last = s.version;
                }
            }
        });
        writer.join().unwrap();
        reader.join().unwrap();
    }
}
