//! Sampler worker: one of the paper's N parallel rollout processes,
//! vectorized over M environments per worker.
//!
//! Each worker owns a [`VecEnv`] of `envs_per_sampler` homogeneous env
//! instances, a thread-local policy backend (its own PJRT client +
//! compiled `act` executable on the XLA path), and per-env RNG streams.
//! It repeatedly:
//!   1. refreshes parameters from the policy store at chunk boundaries,
//!   2. issues ONE batched `act` call with M real rows per sim tick and
//!      steps all M envs in lockstep, scattering (obs, act, logp, V)
//!      into per-env chunk buffers,
//!   3. flushes per-env `ExperienceChunk`s into the bounded experience
//!      queue, preserving GAE segment semantics exactly (terminal vs
//!      time-limit truncation vs mid-episode continuation).
//!
//! Chunk cuts follow two rules (see `plan_boundaries`): episode ends cut
//! only their own env, while full-buffer cuts happen for the whole worker
//! at a shared `chunk_steps` window edge — so the V(s') bootstrap forward
//! fires once per window plus once per mid-window truncation (not once
//! per env), and a policy refresh (which flushes every buffer to keep
//! chunks single-version) always lands on empty buffers.
//!
//! Vectorization amortizes policy inference M-fold per worker (the
//! WarpDrive/Spreeze observation); per-env RNG streams keep every env's
//! trajectory bitwise-independent of M. In async mode (the paper's
//! architecture) workers never wait for the learner except through queue
//! backpressure; in sync mode each worker produces its share of the
//! per-iteration budget under one policy version and then blocks for the
//! next publication (the ablation baseline).

use crate::algo::ddpg::OuNoise;
use crate::algo::normalizer::{NormSnapshot, RunningNorm};
use crate::algo::rollout::{ChunkEnd, ExperienceChunk};
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::coordinator::queue::Channel;
use crate::env::vec_env::{VecEnv, VecStepInfo};
use crate::runtime::{ActorBackend, DdpgActorBackend};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Stream-id base for PPO action-noise RNGs (global env index is added).
/// High bases keep noise streams disjoint from env dynamics streams,
/// which the orchestrator numbers from 1.
const PPO_NOISE_STREAM_BASE: u64 = 1 << 32;
/// Stream-id base for DDPG exploration-noise RNGs.
const DDPG_NOISE_STREAM_BASE: u64 = 1 << 33;

/// Static sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerCfg {
    pub id: usize,
    pub seed: u64,
    pub chunk_steps: usize,
    /// Some(budget) = sync mode: produce `budget` samples per policy
    /// version, then wait for the next version.
    pub sync_budget: Option<usize>,
    /// Learning-signal reward scale (reported episode returns stay raw).
    pub reward_scale: f32,
}

impl SamplerCfg {
    /// Global index of this worker's env slot `i` (workers hold `m` envs
    /// each, numbered contiguously). Noise streams derive from this, so a
    /// trajectory is pinned to its global slot, not to the worker layout.
    fn global_env(&self, m: usize, i: usize) -> u64 {
        (self.id * m + i) as u64
    }
}

/// What a sampler did before stopping (for logs/tests).
#[derive(Debug, Clone, Default)]
pub struct SamplerReport {
    pub steps: u64,
    pub episodes: u64,
    pub chunks: u64,
    pub policy_refreshes: u64,
}

fn wait_first_policy(store: &PolicyStore, stop: &AtomicBool) -> Option<Arc<PolicySnapshot>> {
    loop {
        if stop.load(Ordering::Relaxed) {
            return None;
        }
        if let Some(s) = store.wait_newer(0, Duration::from_millis(50)) {
            return Some(s);
        }
    }
}

/// Normalize `rows` raw observation rows from `src` into `dst` in place.
fn normalize_rows(dst: &mut [f32], src: &[f32], norm: &NormSnapshot, rows: usize, dim: usize) {
    dst[..rows * dim].copy_from_slice(&src[..rows * dim]);
    for r in 0..rows {
        norm.apply(&mut dst[r * dim..(r + 1) * dim]);
    }
}

/// Decide this tick's chunk cuts (shared by the PPO and DDPG loops).
///
/// Cuts happen per env at episode ends, and for ALL envs together at the
/// worker's chunk window edge (`window_ticks >= chunk_steps`). Aligning
/// full-buffer cuts on one global window keeps buffers from drifting
/// apart after uneven episode ends, so the bootstrap forward fires at
/// most once per window instead of once per env.
///
/// A pending policy refresh forces every buffer to cut as well, keeping
/// the one-policy-version-per-chunk invariant. Sync mode evaluates its
/// budget against produced + currently-buffered samples every tick, so a
/// worker overshoots its per-version share by at most M-1 samples no
/// matter how large M is. Returns (any_flush, do_refresh).
#[allow(clippy::too_many_arguments)]
fn plan_boundaries(
    infos: &[VecStepInfo],
    bufs: &[ChunkBuf],
    window_ticks: usize,
    chunk_steps: usize,
    produced_for_version: usize,
    sync_budget: Option<usize>,
    store: &PolicyStore,
    policy_version: u64,
    flush: &mut [bool],
) -> (bool, bool) {
    let window_cut = window_ticks >= chunk_steps;
    for (f, info) in flush.iter_mut().zip(infos) {
        *f = info.ended() || window_cut;
    }
    let natural = flush.iter().any(|&f| f);
    let do_refresh = match sync_budget {
        Some(budget) => {
            let buffered: usize = bufs.iter().map(|b| b.len()).sum();
            produced_for_version + buffered >= budget
        }
        // async: refresh only piggybacks on a natural boundary
        None => natural && store.newer_than(policy_version),
    };
    if do_refresh {
        for f in flush.iter_mut() {
            *f = true;
        }
    }
    (natural || do_refresh, do_refresh)
}

/// Take a fresher policy at a chunk boundary. Sync mode blocks until the
/// learner publishes the next version; async just swaps in the latest.
/// Returns false when `stop` was raised while blocking.
fn refresh_policy(
    policy: &mut Arc<PolicySnapshot>,
    sync: bool,
    store: &PolicyStore,
    stop: &AtomicBool,
    report: &mut SamplerReport,
) -> bool {
    if sync {
        loop {
            if stop.load(Ordering::Relaxed) {
                return false;
            }
            if let Some(p) = store.wait_newer(policy.version, Duration::from_millis(50)) {
                *policy = p;
                report.policy_refreshes += 1;
                return true;
            }
        }
    }
    if let Some(p) = store.latest() {
        if p.version > policy.version {
            *policy = p;
            report.policy_refreshes += 1;
        }
    }
    true
}

/// Buffers for an in-progress chunk (one per env slot, reused).
struct ChunkBuf {
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    logp: Vec<f32>,
    value: Vec<f32>,
    episode_returns: Vec<f32>,
    episode_lengths: Vec<usize>,
    /// Raw-obs Welford stats shipped to the learner's master normalizer.
    stats: RunningNorm,
    /// Busy seconds accumulated for the current chunk (work only).
    busy_secs: f64,
}

impl ChunkBuf {
    fn new(obs_dim: usize) -> Self {
        Self {
            obs: Vec::new(),
            act: Vec::new(),
            rew: Vec::new(),
            logp: Vec::new(),
            value: Vec::new(),
            episode_returns: Vec::new(),
            episode_lengths: Vec::new(),
            stats: RunningNorm::new(obs_dim, 10.0),
            busy_secs: 0.0,
        }
    }

    fn len(&self) -> usize {
        self.rew.len()
    }

    fn take(
        &mut self,
        id: usize,
        env_slot: usize,
        version: u64,
        end: ChunkEnd,
        bootstrap: f32,
    ) -> ExperienceChunk {
        let dim = self.stats.dim();
        ExperienceChunk {
            sampler_id: id,
            env_slot,
            policy_version: version,
            obs: std::mem::take(&mut self.obs),
            act: std::mem::take(&mut self.act),
            rew: std::mem::take(&mut self.rew),
            logp: std::mem::take(&mut self.logp),
            value: std::mem::take(&mut self.value),
            end,
            bootstrap_value: bootstrap,
            episode_returns: std::mem::take(&mut self.episode_returns),
            episode_lengths: std::mem::take(&mut self.episode_lengths),
            obs_stats: Some(std::mem::replace(&mut self.stats, RunningNorm::new(dim, 10.0))),
            busy_secs: std::mem::take(&mut self.busy_secs),
        }
    }
}

/// Run the PPO sampler loop until `stop` is set or the queue closes.
///
/// `venv` holds this worker's M lockstep envs; `actor` must accept at
/// least M rows per call (`BackendFactory::make_actor_batched` aligns the
/// two so the forward carries no padding on the native path).
pub fn run_ppo_sampler(
    cfg: SamplerCfg,
    mut venv: VecEnv,
    mut actor: Box<dyn ActorBackend>,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let mut report = SamplerReport::default();
    let m = venv.num_envs();
    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    // backend may require a fixed batch > M (XLA artifacts): rows past M
    // are zero padding whose outputs are ignored. Native batched actors
    // advertise exactly M, so the forward is full.
    let backend_batch = if actor.batch() == 0 { m } else { actor.batch() };
    if backend_batch < m {
        crate::log_error!(
            "sampler {}: backend batch {} cannot hold {} envs",
            cfg.id,
            backend_batch,
            m
        );
        return report;
    }

    let mut policy = match wait_first_policy(store, stop) {
        Some(p) => p,
        None => return report,
    };
    let mut produced_for_version = 0usize;

    // per-env policy-noise streams: disjoint from env dynamics streams and
    // pinned to the global env slot, so trajectories don't depend on M.
    let mut noise_rngs: Vec<Pcg64> = (0..m)
        .map(|i| Pcg64::with_stream(cfg.seed, PPO_NOISE_STREAM_BASE + cfg.global_env(m, i)))
        .collect();

    let mut obs_in = vec![0.0f32; backend_batch * obs_dim];
    let mut noise = vec![0.0f32; backend_batch * act_dim];
    let mut actions = vec![0.0f32; m * act_dim];
    let mut infos = vec![VecStepInfo::default(); m];
    let mut flush = vec![false; m];
    let mut boot_values = vec![0.0f32; m];
    let mut bufs: Vec<ChunkBuf> = (0..m).map(|_| ChunkBuf::new(obs_dim)).collect();
    // ticks since the last whole-worker chunk cut (see plan_boundaries)
    let mut window_ticks = 0usize;

    venv.reset_all();

    'outer: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }

        // --- one lockstep sim tick under the current policy (busy-timed
        // with the per-thread CPU clock: preemption-immune)
        let busy_t0 = crate::util::timer::thread_cpu_secs();
        normalize_rows(&mut obs_in, venv.obs(), &policy.norm, m, obs_dim);
        for (i, rng) in noise_rngs.iter_mut().enumerate() {
            rng.fill_normal(&mut noise[i * act_dim..(i + 1) * act_dim]);
        }
        let out = match actor.act(&policy.params, &obs_in, &noise) {
            Ok(r) => r,
            Err(e) => {
                crate::log_error!("sampler {}: act failed: {e:#}", cfg.id);
                break;
            }
        };
        for i in 0..m {
            let buf = &mut bufs[i];
            buf.obs
                .extend_from_slice(&obs_in[i * obs_dim..(i + 1) * obs_dim]);
            buf.stats.update(venv.obs_row(i)); // raw pre-step obs feeds the normalizer
            let arow = &out.action[i * act_dim..(i + 1) * act_dim];
            buf.act.extend_from_slice(arow); // pre-clip action (matches logp)
            buf.logp.push(out.logp[i]);
            buf.value.push(out.value[i]);
            let dst = &mut actions[i * act_dim..(i + 1) * act_dim];
            dst.copy_from_slice(arow);
            crate::env::clip_action(dst);
        }

        venv.step_all(&actions, &mut infos);
        for (buf, info) in bufs.iter_mut().zip(&infos) {
            buf.rew.push(info.reward * cfg.reward_scale);
        }
        report.steps += m as u64;
        let tick_busy = crate::util::timer::thread_cpu_secs() - busy_t0;
        for buf in bufs.iter_mut() {
            buf.busy_secs += tick_busy / m as f64;
        }

        // --- chunk boundaries
        window_ticks += 1;
        let (any_flush, do_refresh) = plan_boundaries(
            &infos,
            &bufs,
            window_ticks,
            cfg.chunk_steps,
            produced_for_version,
            cfg.sync_budget,
            store,
            policy.version,
            &mut flush,
        );
        if !any_flush {
            continue;
        }
        if flush.iter().all(|&f| f) {
            window_ticks = 0; // every buffer restarts together
        }
        let mut any_needs_boot = false;
        for i in 0..m {
            any_needs_boot |= flush[i] && !infos[i].terminal;
        }
        let n_flush = flush.iter().filter(|&&f| f).count();

        // Bootstrap values V(s') for truncated/continuation cuts: one
        // batched forward over the post-step observations, zero noise.
        // An inference failure here would silently corrupt GAE targets
        // (V = 0 looks like a terminal), so it terminates the worker
        // exactly like the main-loop path.
        if any_needs_boot {
            let boot_t0 = crate::util::timer::thread_cpu_secs();
            normalize_rows(&mut obs_in, venv.obs(), &policy.norm, m, obs_dim);
            for z in noise.iter_mut() {
                *z = 0.0;
            }
            match actor.act(&policy.params, &obs_in, &noise) {
                Ok(r) => boot_values[..m].copy_from_slice(&r.value[..m]),
                Err(e) => {
                    crate::log_error!(
                        "sampler {}: bootstrap value inference failed: {e:#}",
                        cfg.id
                    );
                    break 'outer;
                }
            }
            let boot_busy = crate::util::timer::thread_cpu_secs() - boot_t0;
            for (i, buf) in bufs.iter_mut().enumerate() {
                if flush[i] {
                    buf.busy_secs += boot_busy / n_flush as f64;
                }
            }
        }

        for i in 0..m {
            if !flush[i] {
                continue;
            }
            let (terminal, truncated) = (infos[i].terminal, infos[i].truncated);
            if terminal || truncated {
                bufs[i].episode_returns.push(venv.ep_return(i));
                bufs[i].episode_lengths.push(venv.ep_len(i));
                report.episodes += 1;
            }
            let (end, bootstrap) = if terminal {
                (ChunkEnd::Terminal, 0.0)
            } else if truncated {
                (ChunkEnd::Truncated, boot_values[i])
            } else {
                (ChunkEnd::Continuation, boot_values[i])
            };
            let n = bufs[i].len();
            let chunk = bufs[i].take(cfg.id, i, policy.version, end, bootstrap);
            if queue.push(chunk).is_err() {
                break 'outer; // queue closed: shutting down
            }
            report.chunks += 1;
            produced_for_version += n;
            if terminal || truncated {
                venv.reset_env(i);
            }
        }

        // --- policy refresh (all buffers are empty now: flush-all above)
        if do_refresh {
            if !refresh_policy(&mut policy, cfg.sync_budget.is_some(), store, stop, &mut report)
            {
                break 'outer;
            }
            produced_for_version = 0;
        }
    }
    report
}

/// Run the DDPG sampler loop (deterministic actor + per-env exploration
/// noise; chunks carry raw transitions for the replay buffer).
pub fn run_ddpg_sampler(
    cfg: SamplerCfg,
    mut venv: VecEnv,
    mut actor: Box<dyn DdpgActorBackend>,
    explore_noise: f32,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let mut report = SamplerReport::default();
    let m = venv.num_envs();
    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    let backend_batch = if actor.batch() == 0 { m } else { actor.batch() };
    if backend_batch < m {
        crate::log_error!(
            "ddpg sampler {}: backend batch {} cannot hold {} envs",
            cfg.id,
            backend_batch,
            m
        );
        return report;
    }

    let mut policy = match wait_first_policy(store, stop) {
        Some(p) => p,
        None => return report,
    };

    let mut noise_rngs: Vec<Pcg64> = (0..m)
        .map(|i| Pcg64::with_stream(cfg.seed, DDPG_NOISE_STREAM_BASE + cfg.global_env(m, i)))
        .collect();
    let mut ous: Vec<OuNoise> = (0..m)
        .map(|_| OuNoise::gaussian(act_dim, explore_noise))
        .collect();

    let mut obs_in = vec![0.0f32; backend_batch * obs_dim];
    let mut noise = vec![0.0f32; act_dim];
    let mut actions = vec![0.0f32; m * act_dim];
    let mut infos = vec![VecStepInfo::default(); m];
    let mut flush = vec![false; m];
    let mut bufs: Vec<ChunkBuf> = (0..m).map(|_| ChunkBuf::new(obs_dim)).collect();
    let mut window_ticks = 0usize;
    let mut produced_for_version = 0usize;

    venv.reset_all();

    'outer: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let busy_t0 = crate::util::timer::thread_cpu_secs();
        normalize_rows(&mut obs_in, venv.obs(), &policy.norm, m, obs_dim);
        let det_actions = match actor.act(&policy.params, &obs_in) {
            Ok(a) => a,
            Err(e) => {
                crate::log_error!("ddpg sampler {}: act failed: {e:#}", cfg.id);
                break;
            }
        };
        for i in 0..m {
            let buf = &mut bufs[i];
            buf.obs
                .extend_from_slice(&obs_in[i * obs_dim..(i + 1) * obs_dim]);
            buf.stats.update(venv.obs_row(i));
            let dst = &mut actions[i * act_dim..(i + 1) * act_dim];
            dst.copy_from_slice(&det_actions[i * act_dim..(i + 1) * act_dim]);
            ous[i].sample(&mut noise_rngs[i], &mut noise);
            for (a, n) in dst.iter_mut().zip(&noise) {
                *a += n;
            }
            crate::env::clip_action(dst);
            buf.act.extend_from_slice(dst);
            buf.logp.push(0.0);
            buf.value.push(0.0);
        }

        venv.step_all(&actions, &mut infos);
        for (buf, info) in bufs.iter_mut().zip(&infos) {
            buf.rew.push(info.reward * cfg.reward_scale);
        }
        report.steps += m as u64;
        let tick_busy = crate::util::timer::thread_cpu_secs() - busy_t0;
        for buf in bufs.iter_mut() {
            buf.busy_secs += tick_busy / m as f64;
        }

        // --- chunk boundaries (same rules as the PPO loop)
        window_ticks += 1;
        let (any_flush, do_refresh) = plan_boundaries(
            &infos,
            &bufs,
            window_ticks,
            cfg.chunk_steps,
            produced_for_version,
            cfg.sync_budget,
            store,
            policy.version,
            &mut flush,
        );
        if !any_flush {
            continue;
        }
        if flush.iter().all(|&f| f) {
            window_ticks = 0;
        }

        for i in 0..m {
            if !flush[i] {
                continue;
            }
            let (terminal, truncated) = (infos[i].terminal, infos[i].truncated);
            if terminal || truncated {
                bufs[i].episode_returns.push(venv.ep_return(i));
                bufs[i].episode_lengths.push(venv.ep_len(i));
                report.episodes += 1;
            }
            let end = if terminal {
                ChunkEnd::Terminal
            } else if truncated {
                ChunkEnd::Truncated
            } else {
                ChunkEnd::Continuation
            };
            // replay reconstruction needs s' of the last row: append the
            // normalized next obs to `obs` (len+1 rows). The learner
            // splits it.
            let mut next_row = venv.obs_row(i).to_vec();
            policy.norm.apply(&mut next_row);
            bufs[i].obs.extend_from_slice(&next_row);
            let n = bufs[i].len();
            let chunk = bufs[i].take(cfg.id, i, policy.version, end, 0.0);
            if queue.push(chunk).is_err() {
                break 'outer;
            }
            report.chunks += 1;
            produced_for_version += n;
            if terminal || truncated {
                venv.reset_env(i);
                ous[i].reset();
            }
        }

        if do_refresh {
            if !refresh_policy(&mut policy, cfg.sync_budget.is_some(), store, stop, &mut report)
            {
                break 'outer;
            }
            produced_for_version = 0;
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;
    use std::thread;

    fn pendulum_factory() -> NativeFactory {
        NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default())
    }

    fn pendulum_venv(id: usize, m: usize, seed: u64) -> VecEnv {
        VecEnv::from_registry("pendulum", m, seed, (id * m) as u64 + 1).unwrap()
    }

    fn spawn_ppo(
        cfg: SamplerCfg,
        m: usize,
        store: Arc<PolicyStore>,
        queue: Arc<Channel<ExperienceChunk>>,
        stop: Arc<AtomicBool>,
    ) -> thread::JoinHandle<SamplerReport> {
        thread::spawn(move || {
            let f = pendulum_factory();
            let venv = pendulum_venv(cfg.id, m, cfg.seed);
            let actor = f.make_actor_batched(m).unwrap();
            run_ppo_sampler(cfg, venv, actor, &store, &queue, &stop)
        })
    }

    #[test]
    fn sampler_produces_chunks_with_consistent_shapes() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(32));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 7,
                chunk_steps: 64,
                sync_budget: None,
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        let mut total = 0usize;
        let mut chunks = Vec::new();
        while total < 600 {
            let c = queue.pop().unwrap();
            total += c.len();
            chunks.push(c);
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();

        for c in &chunks {
            assert_eq!(c.obs.len(), c.len() * 3);
            assert_eq!(c.act.len(), c.len());
            assert_eq!(c.logp.len(), c.len());
            assert_eq!(c.value.len(), c.len());
            assert!(c.len() <= 64);
            assert!(c.rew.iter().all(|r| r.is_finite()));
            assert_eq!(c.policy_version, 1);
            assert_eq!(c.env_slot, 0);
            // pendulum never terminates: only Truncated (at 200) or
            // Continuation chunks
            assert_ne!(c.end, ChunkEnd::Terminal);
        }
        assert!(report.steps >= 600);
        // pendulum episodes are 200 steps; ~3 episodes in 600 samples
        assert!(report.episodes >= 2);
    }

    #[test]
    fn vectorized_sampler_fans_chunks_across_env_slots() {
        let m = 4;
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 21,
                chunk_steps: 50,
                sync_budget: None,
                reward_scale: 1.0,
            },
            m,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        let mut total = 0usize;
        let mut chunks = Vec::new();
        while total < 1600 {
            let c = queue.pop().unwrap();
            total += c.len();
            chunks.push(c);
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();

        for c in &chunks {
            assert!(c.env_slot < m);
            assert_eq!(c.obs.len(), c.len() * 3);
            assert!(c.len() <= 50);
            assert!(c
                .obs_stats
                .as_ref()
                .map(|s| s.count() as usize == c.len())
                .unwrap_or(false));
        }
        // all env slots contribute
        for slot in 0..m {
            assert!(
                chunks.iter().any(|c| c.env_slot == slot),
                "no chunks from env slot {slot}"
            );
        }
        assert!(report.steps >= 1600);
        // M envs in lockstep: first M chunks (one full chunk per env)
        // arrive within the same policy version
        assert!(report.chunks >= m as u64);
    }

    /// Vectorization must be observationally transparent: under a fixed
    /// policy, env slot 0's chunk stream from an M=4 worker is bitwise-
    /// identical to the chunk stream of an M=1 worker with the same
    /// dynamics + noise streams.
    #[test]
    fn env_slot_trajectories_independent_of_vector_width() {
        let collect = |m: usize, budget: usize| -> Vec<ExperienceChunk> {
            let store = Arc::new(PolicyStore::new());
            let queue = Arc::new(Channel::new(256));
            let stop = Arc::new(AtomicBool::new(false));
            let f = pendulum_factory();
            store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));
            let h = spawn_ppo(
                SamplerCfg {
                    id: 0,
                    seed: 33,
                    chunk_steps: 40,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                m,
                store.clone(),
                queue.clone(),
                stop.clone(),
            );
            let mut total = 0usize;
            let mut chunks = Vec::new();
            while total < budget {
                let c = queue.pop().unwrap();
                total += c.len();
                chunks.push(c);
            }
            stop.store(true, Ordering::Relaxed);
            queue.close();
            h.join().unwrap();
            chunks
        };

        let solo: Vec<_> = collect(1, 400);
        let vec4: Vec<_> = collect(4, 1600)
            .into_iter()
            .filter(|c| c.env_slot == 0)
            .collect();
        let n = solo.len().min(vec4.len());
        assert!(n >= 3, "not enough chunks to compare ({n})");
        for (a, b) in solo[..n].iter().zip(&vec4[..n]) {
            assert_eq!(a.obs, b.obs, "obs diverged between M=1 and M=4");
            assert_eq!(a.act, b.act, "actions diverged");
            assert_eq!(a.rew, b.rew, "rewards diverged");
            assert_eq!(a.logp, b.logp, "logp diverged");
            assert_eq!(a.value, b.value, "values diverged");
            assert_eq!(a.end, b.end, "chunk ends diverged");
            assert_eq!(a.bootstrap_value, b.bootstrap_value, "bootstraps diverged");
        }
    }

    #[test]
    fn sampler_tags_chunks_with_policy_version() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 1,
                seed: 8,
                chunk_steps: 50,
                sync_budget: None,
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // consume a few v1 chunks, then publish v2 and expect the tag to move
        for _ in 0..3 {
            assert_eq!(queue.pop().unwrap().policy_version, 1);
        }
        store.publish(f.init_ppo_params(1), NormSnapshot::identity(3));
        let mut saw_v2 = false;
        for _ in 0..10 {
            if queue.pop().unwrap().policy_version == 2 {
                saw_v2 = true;
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();
        assert!(saw_v2, "sampler never picked up v2");
        assert!(report.policy_refreshes >= 1);
    }

    #[test]
    fn sync_mode_stops_at_budget() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 9,
                chunk_steps: 40,
                sync_budget: Some(120),
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // worker should produce exactly ceil-to-chunk >= 120 samples then stall
        thread::sleep(Duration::from_millis(300));
        let mut total = 0;
        while let Ok(Some(c)) = queue.try_pop() {
            assert_eq!(c.policy_version, 1);
            total += c.len();
        }
        assert!(
            (120..=160).contains(&total),
            "sync budget not respected: {total}"
        );
        // release the barrier with v2; more chunks must arrive
        store.publish(f.init_ppo_params(2), NormSnapshot::identity(3));
        let c = queue.pop_timeout(Duration::from_secs(5)).unwrap();
        assert!(c.is_some(), "sampler did not resume after publish");
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn ddpg_sampler_appends_next_obs_row() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        let (actor_params, _) = f.init_ddpg_params(0);
        store.publish(actor_params, NormSnapshot::identity(3));

        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = thread::spawn(move || {
            let f = pendulum_factory();
            let venv = pendulum_venv(0, 2, 11);
            let actor = f.make_ddpg_actor_batched(2).unwrap();
            run_ddpg_sampler(
                SamplerCfg {
                    id: 0,
                    seed: 11,
                    chunk_steps: 32,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                venv,
                actor,
                0.1,
                &store2,
                &queue2,
                &stop2,
            )
        });

        let c = queue.pop().unwrap();
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
        // obs has len+1 rows (trailing next-obs row for replay)
        assert_eq!(c.obs.len(), (c.len() + 1) * 3);
        // actions are clipped
        assert!(c.act.iter().all(|a| a.abs() <= 1.0));
        assert!(c.env_slot < 2);
    }
}
