//! Sampler worker: one of the paper's N parallel rollout processes.
//!
//! Each worker owns an environment instance, a thread-local policy backend
//! (its own PJRT client + compiled `act` executable on the XLA path), and
//! an independent RNG stream. It repeatedly:
//!   1. refreshes parameters from the policy store at chunk boundaries,
//!   2. rolls the environment, recording (obs, act, logp, V) transitions,
//!   3. pushes experience chunks into the bounded experience queue.
//!
//! In async mode (the paper's architecture) workers never wait for the
//! learner except through queue backpressure; in sync mode each worker
//! produces its share of the per-iteration budget under one policy version
//! and then blocks for the next publication (the ablation baseline).

use crate::algo::ddpg::OuNoise;
use crate::algo::normalizer::RunningNorm;
use crate::algo::rollout::{ChunkEnd, ExperienceChunk};
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::coordinator::queue::Channel;
use crate::env::{clip_action, Env};
use crate::runtime::{ActorBackend, DdpgActorBackend};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Static sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerCfg {
    pub id: usize,
    pub seed: u64,
    pub chunk_steps: usize,
    /// Some(budget) = sync mode: produce `budget` samples per policy
    /// version, then wait for the next version.
    pub sync_budget: Option<usize>,
    /// Learning-signal reward scale (reported episode returns stay raw).
    pub reward_scale: f32,
}

/// What a sampler did before stopping (for logs/tests).
#[derive(Debug, Clone, Default)]
pub struct SamplerReport {
    pub steps: u64,
    pub episodes: u64,
    pub chunks: u64,
    pub policy_refreshes: u64,
}

fn wait_first_policy(store: &PolicyStore, stop: &AtomicBool) -> Option<Arc<PolicySnapshot>> {
    loop {
        if stop.load(Ordering::Relaxed) {
            return None;
        }
        if let Some(s) = store.wait_newer(0, Duration::from_millis(50)) {
            return Some(s);
        }
    }
}

/// Buffers for an in-progress chunk (reused across chunks).
struct ChunkBuf {
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    logp: Vec<f32>,
    value: Vec<f32>,
    episode_returns: Vec<f32>,
    episode_lengths: Vec<usize>,
    /// Raw-obs Welford stats shipped to the learner's master normalizer.
    stats: RunningNorm,
    /// Busy seconds accumulated for the current chunk (work only).
    busy_secs: f64,
}

impl ChunkBuf {
    fn new(obs_dim: usize) -> Self {
        Self {
            obs: Vec::new(),
            act: Vec::new(),
            rew: Vec::new(),
            logp: Vec::new(),
            value: Vec::new(),
            episode_returns: Vec::new(),
            episode_lengths: Vec::new(),
            stats: RunningNorm::new(obs_dim, 10.0),
            busy_secs: 0.0,
        }
    }

    fn len(&self) -> usize {
        self.rew.len()
    }

    fn take(
        &mut self,
        id: usize,
        version: u64,
        end: ChunkEnd,
        bootstrap: f32,
    ) -> ExperienceChunk {
        let dim = self.stats.dim();
        ExperienceChunk {
            sampler_id: id,
            policy_version: version,
            obs: std::mem::take(&mut self.obs),
            act: std::mem::take(&mut self.act),
            rew: std::mem::take(&mut self.rew),
            logp: std::mem::take(&mut self.logp),
            value: std::mem::take(&mut self.value),
            end,
            bootstrap_value: bootstrap,
            episode_returns: std::mem::take(&mut self.episode_returns),
            episode_lengths: std::mem::take(&mut self.episode_lengths),
            obs_stats: Some(std::mem::replace(&mut self.stats, RunningNorm::new(dim, 10.0))),
            busy_secs: std::mem::take(&mut self.busy_secs),
        }
    }
}

/// Run the PPO sampler loop until `stop` is set or the queue closes.
pub fn run_ppo_sampler(
    cfg: SamplerCfg,
    mut env: Box<dyn Env>,
    mut actor: Box<dyn ActorBackend>,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let mut report = SamplerReport::default();
    let obs_dim = env.obs_dim();
    let act_dim = env.act_dim();
    let backend_batch = actor.batch().max(1);

    let mut policy = match wait_first_policy(store, stop) {
        Some(p) => p,
        None => return report,
    };
    let mut produced_for_version = 0usize;

    let mut rng = Pcg64::with_stream(cfg.seed, cfg.id as u64 + 1);
    let mut raw_obs = vec![0.0f32; obs_dim];
    // backend may require a fixed batch > 1: rows past 0 are zero padding
    let mut obs_in = vec![0.0f32; backend_batch * obs_dim];
    let mut noise = vec![0.0f32; backend_batch * act_dim];
    let mut buf = ChunkBuf::new(obs_dim);

    env.reset(&mut rng, &mut raw_obs);
    let mut norm_obs = raw_obs.clone();
    policy.norm.apply(&mut norm_obs);
    let mut ep_return = 0.0f32;
    let mut ep_len = 0usize;
    let max_ep = env.max_episode_steps();

    // evaluate V(s) of the current normalized obs (used for bootstrapping)
    macro_rules! value_of {
        ($norm_obs:expr) => {{
            obs_in[..obs_dim].copy_from_slice($norm_obs);
            for z in noise.iter_mut() {
                *z = 0.0;
            }
            match actor.act(&policy.params, &obs_in, &noise) {
                Ok(r) => r.value[0],
                Err(_) => 0.0,
            }
        }};
    }

    'outer: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }

        // --- one environment step under the current policy (busy-timed
        // with the per-thread CPU clock: preemption-immune)
        let busy_t0 = crate::util::timer::thread_cpu_secs();
        obs_in[..obs_dim].copy_from_slice(&norm_obs);
        rng.fill_normal(&mut noise);
        let out = match actor.act(&policy.params, &obs_in, &noise) {
            Ok(r) => r,
            Err(e) => {
                crate::log_error!("sampler {}: act failed: {e:#}", cfg.id);
                break;
            }
        };
        let mut action = out.action[..act_dim].to_vec();
        clip_action(&mut action);

        buf.obs.extend_from_slice(&norm_obs);
        buf.stats.update(&raw_obs); // raw obs (pre-step) feeds the normalizer
        buf.act.extend_from_slice(&out.action[..act_dim]); // pre-clip action (matches logp)
        buf.logp.push(out.logp[0]);
        buf.value.push(out.value[0]);

        let step = env.step(&action, &mut raw_obs);
        buf.rew.push(step.reward * cfg.reward_scale);
        ep_return += step.reward;
        ep_len += 1;
        report.steps += 1;

        norm_obs.copy_from_slice(&raw_obs);
        policy.norm.apply(&mut norm_obs);
        buf.busy_secs += crate::util::timer::thread_cpu_secs() - busy_t0;

        let terminal = step.done;
        let truncated = !terminal && ep_len >= max_ep;
        let chunk_full = buf.len() >= cfg.chunk_steps;

        if terminal || truncated || chunk_full {
            let boot_t0 = crate::util::timer::thread_cpu_secs();
            let (end, bootstrap) = if terminal {
                (ChunkEnd::Terminal, 0.0)
            } else {
                let v = value_of!(&norm_obs);
                (
                    if truncated {
                        ChunkEnd::Truncated
                    } else {
                        ChunkEnd::Continuation
                    },
                    v,
                )
            };
            buf.busy_secs += crate::util::timer::thread_cpu_secs() - boot_t0;

            if terminal || truncated {
                buf.episode_returns.push(ep_return);
                buf.episode_lengths.push(ep_len);
                report.episodes += 1;
            }
            let n = buf.len();
            let chunk = buf.take(cfg.id, policy.version, end, bootstrap);
            if queue.push(chunk).is_err() {
                break 'outer; // queue closed: shutting down
            }
            report.chunks += 1;
            produced_for_version += n;

            if terminal || truncated {
                env.reset(&mut rng, &mut raw_obs);
                norm_obs.copy_from_slice(&raw_obs);
                policy.norm.apply(&mut norm_obs);
                ep_return = 0.0;
                ep_len = 0;
            }

            // --- policy refresh at chunk boundaries
            if let Some(budget) = cfg.sync_budget {
                if produced_for_version >= budget {
                    // sync mode: block for the next version
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break 'outer;
                        }
                        if let Some(p) =
                            store.wait_newer(policy.version, Duration::from_millis(50))
                        {
                            policy = p;
                            produced_for_version = 0;
                            report.policy_refreshes += 1;
                            break;
                        }
                    }
                }
            } else if store.newer_than(policy.version) {
                if let Some(p) = store.latest() {
                    policy = p;
                    produced_for_version = 0;
                    report.policy_refreshes += 1;
                }
            }
        }
    }
    report
}

/// Run the DDPG sampler loop (deterministic actor + OU exploration noise;
/// chunks carry raw transitions for the replay buffer).
pub fn run_ddpg_sampler(
    cfg: SamplerCfg,
    mut env: Box<dyn Env>,
    mut actor: Box<dyn DdpgActorBackend>,
    explore_noise: f32,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let mut report = SamplerReport::default();
    let obs_dim = env.obs_dim();
    let act_dim = env.act_dim();
    let backend_batch = actor.batch().max(1);

    let mut policy = match wait_first_policy(store, stop) {
        Some(p) => p,
        None => return report,
    };

    let mut rng = Pcg64::with_stream(cfg.seed, cfg.id as u64 + 101);
    let mut ou = OuNoise::gaussian(act_dim, explore_noise);
    let mut raw_obs = vec![0.0f32; obs_dim];
    let mut obs_in = vec![0.0f32; backend_batch * obs_dim];
    let mut noise = vec![0.0f32; act_dim];
    let mut buf = ChunkBuf::new(obs_dim);

    env.reset(&mut rng, &mut raw_obs);
    let mut norm_obs = raw_obs.clone();
    policy.norm.apply(&mut norm_obs);
    let mut ep_return = 0.0f32;
    let mut ep_len = 0usize;
    let max_ep = env.max_episode_steps();

    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let busy_t0 = crate::util::timer::thread_cpu_secs();
        obs_in[..obs_dim].copy_from_slice(&norm_obs);
        let mut action = match actor.act(&policy.params, &obs_in) {
            Ok(a) => a[..act_dim].to_vec(),
            Err(e) => {
                crate::log_error!("ddpg sampler {}: act failed: {e:#}", cfg.id);
                break;
            }
        };
        ou.sample(&mut rng, &mut noise);
        for (a, n) in action.iter_mut().zip(&noise) {
            *a += n;
        }
        clip_action(&mut action);

        buf.obs.extend_from_slice(&norm_obs);
        buf.stats.update(&raw_obs);
        buf.act.extend_from_slice(&action);
        buf.logp.push(0.0);
        buf.value.push(0.0);

        let step = env.step(&action, &mut raw_obs);
        buf.rew.push(step.reward * cfg.reward_scale);
        ep_return += step.reward;
        ep_len += 1;
        report.steps += 1;

        norm_obs.copy_from_slice(&raw_obs);
        policy.norm.apply(&mut norm_obs);
        buf.busy_secs += crate::util::timer::thread_cpu_secs() - busy_t0;

        let terminal = step.done;
        let truncated = !terminal && ep_len >= max_ep;
        if terminal || truncated || buf.len() >= cfg.chunk_steps {
            if terminal || truncated {
                buf.episode_returns.push(ep_return);
                buf.episode_lengths.push(ep_len);
                report.episodes += 1;
            }
            let end = if terminal {
                ChunkEnd::Terminal
            } else if truncated {
                ChunkEnd::Truncated
            } else {
                ChunkEnd::Continuation
            };
            // replay reconstruction needs s' of the last row: stash the
            // normalized next obs in `bootstrap_value`-adjacent storage by
            // appending it to `obs` (len+1 rows). The learner splits it.
            buf.obs.extend_from_slice(&norm_obs);
            let chunk = buf.take(cfg.id, policy.version, end, 0.0);
            if queue.push(chunk).is_err() {
                break;
            }
            report.chunks += 1;

            if terminal || truncated {
                env.reset(&mut rng, &mut raw_obs);
                norm_obs.copy_from_slice(&raw_obs);
                policy.norm.apply(&mut norm_obs);
                ou.reset();
                ep_return = 0.0;
                ep_len = 0;
            }
            if store.newer_than(policy.version) {
                if let Some(p) = store.latest() {
                    policy = p;
                    report.policy_refreshes += 1;
                }
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::env::registry::make_env;
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;
    use std::thread;

    fn pendulum_factory() -> NativeFactory {
        NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default())
    }

    fn spawn_ppo(
        cfg: SamplerCfg,
        store: Arc<PolicyStore>,
        queue: Arc<Channel<ExperienceChunk>>,
        stop: Arc<AtomicBool>,
    ) -> thread::JoinHandle<SamplerReport> {
        thread::spawn(move || {
            let f = pendulum_factory();
            let env = make_env("pendulum").unwrap();
            let actor = f.make_actor().unwrap();
            run_ppo_sampler(cfg, env, actor, &store, &queue, &stop)
        })
    }

    #[test]
    fn sampler_produces_chunks_with_consistent_shapes() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(32));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 7,
                chunk_steps: 64,
                sync_budget: None,
                reward_scale: 1.0,
            },
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        let mut total = 0usize;
        let mut chunks = Vec::new();
        while total < 600 {
            let c = queue.pop().unwrap();
            total += c.len();
            chunks.push(c);
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();

        for c in &chunks {
            assert_eq!(c.obs.len(), c.len() * 3);
            assert_eq!(c.act.len(), c.len());
            assert_eq!(c.logp.len(), c.len());
            assert_eq!(c.value.len(), c.len());
            assert!(c.len() <= 64);
            assert!(c.rew.iter().all(|r| r.is_finite()));
            assert_eq!(c.policy_version, 1);
            // pendulum never terminates: only Truncated (at 200) or
            // Continuation chunks
            assert_ne!(c.end, ChunkEnd::Terminal);
        }
        assert!(report.steps >= 600);
        // pendulum episodes are 200 steps; ~3 episodes in 600 samples
        assert!(report.episodes >= 2);
    }

    #[test]
    fn sampler_tags_chunks_with_policy_version() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 1,
                seed: 8,
                chunk_steps: 50,
                sync_budget: None,
                reward_scale: 1.0,
            },
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // consume a few v1 chunks, then publish v2 and expect the tag to move
        for _ in 0..3 {
            assert_eq!(queue.pop().unwrap().policy_version, 1);
        }
        store.publish(f.init_ppo_params(1), NormSnapshot::identity(3));
        let mut saw_v2 = false;
        for _ in 0..10 {
            if queue.pop().unwrap().policy_version == 2 {
                saw_v2 = true;
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();
        assert!(saw_v2, "sampler never picked up v2");
        assert!(report.policy_refreshes >= 1);
    }

    #[test]
    fn sync_mode_stops_at_budget() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 9,
                chunk_steps: 40,
                sync_budget: Some(120),
                reward_scale: 1.0,
            },
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // worker should produce exactly ceil-to-chunk >= 120 samples then stall
        thread::sleep(Duration::from_millis(300));
        let mut total = 0;
        while let Ok(Some(c)) = queue.try_pop() {
            assert_eq!(c.policy_version, 1);
            total += c.len();
        }
        assert!(
            (120..=160).contains(&total),
            "sync budget not respected: {total}"
        );
        // release the barrier with v2; more chunks must arrive
        store.publish(f.init_ppo_params(2), NormSnapshot::identity(3));
        let c = queue.pop_timeout(Duration::from_secs(5)).unwrap();
        assert!(c.is_some(), "sampler did not resume after publish");
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn ddpg_sampler_appends_next_obs_row() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        let (actor_params, _) = f.init_ddpg_params(0);
        store.publish(actor_params, NormSnapshot::identity(3));

        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = thread::spawn(move || {
            let f = pendulum_factory();
            let env = make_env("pendulum").unwrap();
            let actor = f.make_ddpg_actor().unwrap();
            run_ddpg_sampler(
                SamplerCfg {
                    id: 0,
                    seed: 11,
                    chunk_steps: 32,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                env,
                actor,
                0.1,
                &store2,
                &queue2,
                &stop2,
            )
        });

        let c = queue.pop().unwrap();
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
        // obs has len+1 rows (trailing next-obs row for replay)
        assert_eq!(c.obs.len(), (c.len() + 1) * 3);
        // actions are clipped
        assert!(c.act.iter().all(|a| a.abs() <= 1.0));
    }
}
