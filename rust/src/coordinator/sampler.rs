//! Sampler worker: one of the paper's N parallel rollout processes,
//! vectorized over M environments per worker — ONE generic hot loop,
//! parameterized by the [`Algorithm`] trait.
//!
//! Each worker owns a [`VecEnv`] of `envs_per_sampler` homogeneous env
//! instances, a thread-local policy backend (its own PJRT client +
//! compiled `act` executable on the XLA path), and the algorithm's
//! per-env exploration streams ([`AlgoSampler`]). It repeatedly:
//!   1. refreshes parameters from the policy store at chunk boundaries,
//!   2. issues ONE batched `act` call with M real rows per sim tick and
//!      steps all M envs in lockstep, scattering the algorithm's lanes
//!      (actions, and logp/V for stochastic policies) into per-env chunk
//!      buffers,
//!   3. flushes per-env `ExperienceChunk`s into the bounded experience
//!      queue, preserving segment semantics exactly (terminal vs
//!      time-limit truncation vs mid-episode continuation).
//!
//! The loop owns everything algorithm-independent: lockstep stepping,
//! chunk windows, sync budgets, policy refreshes, busy-time accounting,
//! and the shared-inference epoch cuts. Everything algorithm-specific —
//! which noise lanes each act call consumes, what gets recorded per
//! tick, whether cuts need a V(s') bootstrap forward, and how a chunk is
//! closed (PPO records a bootstrap value; deterministic replay
//! algorithms append a normalized s' row) — lives behind the
//! [`AlgoSampler`] hooks, called
//! in a fixed per-env order so RNG consumption is deterministic. The
//! legacy entry points (`run_ppo_sampler*`, `run_ddpg_sampler*`) are
//! thin wrappers over [`run_algo_sampler`] and remain bit-for-bit
//! equivalent to the pre-trait loops.
//!
//! Chunk cuts follow two rules (see `plan_boundaries`): episode ends cut
//! only their own env, while full-buffer cuts happen for the whole worker
//! at a shared `chunk_steps` window edge — so the V(s') bootstrap forward
//! fires once per window plus once per mid-window truncation (not once
//! per env), and a policy refresh (which flushes every buffer to keep
//! chunks single-version) always lands on empty buffers.
//!
//! Vectorization amortizes policy inference M-fold per worker (the
//! WarpDrive/Spreeze observation); per-env RNG streams keep every env's
//! trajectory bitwise-independent of M. In async mode (the paper's
//! architecture) workers never wait for the learner except through queue
//! backpressure; in sync mode each worker produces its share of the
//! per-iteration budget under one policy version and then blocks for the
//! next publication (the ablation baseline).
//!
//! ## Inference placement
//!
//! The hot loop is generic over a [`PolicySource`]:
//!
//! * **Local** — the worker owns a private [`ActorBackend`] (built via
//!   [`Algorithm::make_local_actor`]) and normalizes observations itself
//!   under its current snapshot; policy refreshes piggyback on chunk
//!   boundaries (the PR 1 path, bit-for-bit).
//! * **Shared** — the worker submits its raw M-row slab to the shared
//!   inference server through an `ActorClient` and blocks on the
//!   response, which carries the rows' lanes, the server-normalized
//!   obs, and the `(epoch, version)` of the dispatch. Refresh is
//!   server-driven: when a response's pool epoch (or, gateless, its
//!   snapshot version) moves past that of the rows buffered so far, the
//!   worker cuts every non-empty chunk *before* appending the new tick
//!   (a `Continuation` closed through the algorithm hook), preserving
//!   one-policy-version-per-chunk without any worker-side store polling.
//!   Under `--infer-epoch pool` the epoch moves on the same dispatch
//!   boundary for every shard, so the cut tick is fleet-consistent even
//!   at S > 1.
//!
//! Under a fixed policy version the two modes produce bitwise-identical
//! per-env chunk streams (the MLP forward is row-independent; see the
//! shard-determinism tests below).

use crate::algo::api::{AlgoSampler, Algorithm, TickLanes};
use crate::algo::rollout::{ChunkBuf, ChunkEnd, ExperienceChunk};
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::coordinator::queue::Channel;
use crate::coordinator::supervisor::{WorkerCtl, WorkerLane};
use crate::env::vec_env::{VecEnv, VecStepInfo};
use crate::runtime::daemon::remote_client::RemoteActorClient;
use crate::runtime::inference_server::{ActResponse, ActorClient};
use crate::runtime::{ActResult, ActorBackend, DdpgActorBackend, DeterministicRowActor};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Where a sampler evaluates its policy each sim tick (any algorithm).
pub enum PolicySource {
    /// Private per-worker backend (N forwards per tick fleet-wide).
    Local(Box<dyn ActorBackend>),
    /// Shared inference-pool shard handle (cross-worker mega-batch
    /// forwards; see `runtime::inference_server`).
    Shared(ActorClient),
    /// Policy-daemon socket handle (`--fleet-mode procs`): the same
    /// shared-pool contract spoken over the wire, so the hot loop below
    /// is transport-blind (see `runtime::daemon`).
    Remote(RemoteActorClient),
}

impl PolicySource {
    /// Submit one tick's slab to whichever out-of-worker serving tier
    /// this source talks to. Both arms honor the `ActorClient::act`
    /// contract (same `ActResponse`, same retry-safety after `Err`), so
    /// the hot loop's shared path needs exactly one implementation —
    /// which is what keeps threads/procs chunk streams bitwise
    /// identical. Local sources never route here: the hot loop's Local
    /// arm owns them.
    fn shared_act(&mut self, obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResponse> {
        match self {
            PolicySource::Shared(client) => client.act(obs, noise),
            PolicySource::Remote(client) => client.act(obs, noise),
            PolicySource::Local(_) => unreachable!("local sources act in-worker"),
        }
    }
}

/// Legacy PPO spelling of [`PolicySource`] (kept for the pre-trait API;
/// `run_ppo_sampler_from` converts and delegates).
pub enum PpoPolicySource {
    /// Private per-worker backend.
    Local(Box<dyn ActorBackend>),
    /// Shared inference-pool shard handle.
    Shared(ActorClient),
}

/// Legacy DDPG spelling of [`PolicySource`]: local sources carry the
/// deterministic-actor backend, wrapped into the unified row interface
/// by `run_ddpg_sampler_from`.
pub enum DdpgPolicySource {
    /// Private per-worker backend.
    Local(Box<dyn DdpgActorBackend>),
    /// Shared inference-pool shard handle.
    Shared(ActorClient),
}

/// One tick's policy outputs: owned by the worker (local backend) or
/// held in the recycled shared-inference response. Drop it before the
/// next inference call so the shared buffers return to the client.
enum TickOut {
    Local(ActResult),
    Shared(ActResponse),
}

impl TickOut {
    fn action(&self) -> &[f32] {
        match self {
            TickOut::Local(r) => &r.action,
            TickOut::Shared(r) => r.action(),
        }
    }

    fn logp(&self) -> &[f32] {
        match self {
            TickOut::Local(r) => &r.logp,
            TickOut::Shared(r) => r.logp(),
        }
    }

    fn value(&self) -> &[f32] {
        match self {
            TickOut::Local(r) => &r.value,
            TickOut::Shared(r) => r.value(),
        }
    }
}

/// Static sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerCfg {
    pub id: usize,
    pub seed: u64,
    pub chunk_steps: usize,
    /// Some(budget) = sync mode: produce `budget` samples per policy
    /// version, then wait for the next version.
    pub sync_budget: Option<usize>,
    /// Learning-signal reward scale (reported episode returns stay raw).
    pub reward_scale: f32,
}

impl SamplerCfg {
    /// Global index of this worker's env slot `i` (workers hold `m` envs
    /// each, numbered contiguously). Noise streams derive from this, so a
    /// trajectory is pinned to its global slot, not to the worker layout.
    pub fn global_env(&self, m: usize, i: usize) -> u64 {
        (self.id * m + i) as u64
    }
}

/// What a sampler did before stopping (for logs/tests).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SamplerReport {
    pub steps: u64,
    pub episodes: u64,
    pub chunks: u64,
    pub policy_refreshes: u64,
}

fn wait_first_policy(store: &PolicyStore, stop: &AtomicBool) -> Option<Arc<PolicySnapshot>> {
    loop {
        if stop.load(Ordering::Relaxed) {
            return None;
        }
        if let Some(s) = store.wait_newer(0, Duration::from_millis(50)) {
            return Some(s);
        }
    }
}

/// Normalize `rows` raw observation rows from `src` into `dst` in place.
fn normalize_rows(
    dst: &mut [f32],
    src: &[f32],
    norm: &crate::algo::normalizer::NormSnapshot,
    rows: usize,
    dim: usize,
) {
    dst[..rows * dim].copy_from_slice(&src[..rows * dim]);
    for r in 0..rows {
        norm.apply(&mut dst[r * dim..(r + 1) * dim]);
    }
}

/// Decide this tick's chunk cuts (shared by every algorithm).
///
/// Cuts happen per env at episode ends, and for ALL envs together at the
/// worker's chunk window edge (`window_ticks >= chunk_steps`). Aligning
/// full-buffer cuts on one global window keeps buffers from drifting
/// apart after uneven episode ends, so the bootstrap forward fires at
/// most once per window instead of once per env.
///
/// A pending policy refresh forces every buffer to cut as well, keeping
/// the one-policy-version-per-chunk invariant. Sync mode evaluates its
/// budget against produced + currently-buffered samples every tick, so a
/// worker overshoots its per-version share by at most M-1 samples no
/// matter how large M is. With `server_refresh` (shared inference mode)
/// the async arm never fires: the server observes the store once per
/// dispatch and the worker cuts on the version it sees in responses
/// instead of polling the store itself. Returns (any_flush, do_refresh).
#[allow(clippy::too_many_arguments)]
fn plan_boundaries(
    infos: &[VecStepInfo],
    bufs: &[ChunkBuf],
    window_ticks: usize,
    chunk_steps: usize,
    produced_for_version: usize,
    sync_budget: Option<usize>,
    server_refresh: bool,
    store: &PolicyStore,
    policy_version: u64,
    flush: &mut [bool],
) -> (bool, bool) {
    let window_cut = window_ticks >= chunk_steps;
    for (f, info) in flush.iter_mut().zip(infos) {
        *f = info.ended() || window_cut;
    }
    let natural = flush.iter().any(|&f| f);
    let do_refresh = match sync_budget {
        Some(budget) => {
            let buffered: usize = bufs.iter().map(|b| b.len()).sum();
            produced_for_version + buffered >= budget
        }
        // async: refresh only piggybacks on a natural boundary (and in
        // shared mode not at all — the server drives it)
        None => !server_refresh && natural && store.newer_than(policy_version),
    };
    if do_refresh {
        for f in flush.iter_mut() {
            *f = true;
        }
    }
    (natural || do_refresh, do_refresh)
}

/// Take a fresher policy at a chunk boundary. Sync mode blocks until the
/// learner publishes the next version; async just swaps in the latest.
/// Returns false when `stop` was raised while blocking.
fn refresh_policy(
    policy: &mut Arc<PolicySnapshot>,
    sync: bool,
    store: &PolicyStore,
    stop: &AtomicBool,
    report: &mut SamplerReport,
) -> bool {
    if sync {
        loop {
            if stop.load(Ordering::Relaxed) {
                return false;
            }
            if let Some(p) = store.wait_newer(policy.version, Duration::from_millis(50)) {
                *policy = p;
                report.policy_refreshes += 1;
                return true;
            }
        }
    }
    if let Some(p) = store.latest() {
        if p.version > policy.version {
            *policy = p;
            report.policy_refreshes += 1;
        }
    }
    true
}

/// Chunk delivery gate for supervised replay. A respawned worker
/// regenerates the chunk sequence from its restored snapshot bitwise;
/// the first `skip` emissions were already delivered by the previous
/// incarnation, so they are counted (report/budget bookkeeping must run
/// identically to the fault-free schedule) but not pushed again. The
/// owning lane's `pushed` counter is advanced only after a successful
/// push, so a crash between emissions re-sends at most the in-flight
/// chunk's successors, never silently drops one (sync mode would
/// deadlock on a dropped chunk; a scripted fault always fires at a tick
/// boundary, where the two counters agree).
struct EmitGate<'a> {
    emitted: u64,
    skip: u64,
    lane: Option<&'a Arc<WorkerLane>>,
}

impl EmitGate<'_> {
    /// Deliver (or drop, during replay of already-delivered emissions)
    /// one chunk. Returns false when the queue closed.
    fn push(&mut self, queue: &Channel<ExperienceChunk>, chunk: ExperienceChunk) -> bool {
        self.emitted += 1;
        if self.emitted <= self.skip {
            return true; // regenerated chunk the learner already holds
        }
        if queue.push(chunk).is_err() {
            return false;
        }
        if let Some(lane) = self.lane {
            lane.pushed.store(self.emitted, Ordering::SeqCst);
        }
        true
    }

    /// A fresh snapshot was deposited: nothing is pending past it.
    fn reset(&mut self) {
        self.emitted = 0;
        self.skip = 0;
    }
}

/// Shared-mode version cut: the server's dispatch moved to a newer
/// policy version (or pool epoch), so every row buffered so far belongs
/// to the old snapshot and this tick's rows must not join them. Each
/// non-empty buffer is closed through the algorithm hook as a
/// `Continuation` — PPO bootstraps with V(s_t), the value this tick's
/// forward just produced for the pre-step observation (exactly the state
/// the cut chunk ends on); deterministic replay algorithms append that
/// pre-step observation as the chunk's s' row, normalized under the OLD
/// snapshot the chunk was collected with. Returns false if the queue
/// closed.
#[allow(clippy::too_many_arguments)]
fn flush_version_cut(
    hooks: &mut dyn AlgoSampler,
    cfg: &SamplerCfg,
    bufs: &mut [ChunkBuf],
    venv: &VecEnv,
    policy: &PolicySnapshot,
    values: &[f32],
    queue: &Channel<ExperienceChunk>,
    report: &mut SamplerReport,
    emit: &mut EmitGate<'_>,
) -> bool {
    for (i, buf) in bufs.iter_mut().enumerate() {
        if buf.is_empty() {
            continue;
        }
        let boot = hooks.close_chunk(
            buf,
            venv.obs_row(i),
            &policy.norm,
            ChunkEnd::Continuation,
            values[i],
        );
        let chunk = buf.take(cfg.id, i, policy.version, ChunkEnd::Continuation, boot);
        if !emit.push(queue, chunk) {
            return false;
        }
        report.chunks += 1;
    }
    true
}

/// Run the PPO sampler loop with a private per-worker backend (local
/// inference mode). Thin wrapper over [`run_ppo_sampler_from`].
pub fn run_ppo_sampler(
    cfg: SamplerCfg,
    venv: VecEnv,
    actor: Box<dyn ActorBackend>,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    run_ppo_sampler_from(cfg, venv, PpoPolicySource::Local(actor), store, queue, stop)
}

/// Run the PPO sampler loop until `stop` is set or the queue closes.
/// Thin wrapper over the generic [`run_algo_sampler`] with the PPO
/// algorithm hooks (the pre-trait behavior, bit-for-bit).
pub fn run_ppo_sampler_from(
    cfg: SamplerCfg,
    venv: VecEnv,
    source: PpoPolicySource,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let source = match source {
        PpoPolicySource::Local(actor) => PolicySource::Local(actor),
        PpoPolicySource::Shared(client) => PolicySource::Shared(client),
    };
    let algo = crate::algo::ppo::Ppo::default();
    run_algo_sampler(&algo, cfg, venv, source, store, queue, stop)
}

/// Run the DDPG sampler loop with a private per-worker backend (local
/// inference mode). Thin wrapper over [`run_ddpg_sampler_from`].
pub fn run_ddpg_sampler(
    cfg: SamplerCfg,
    venv: VecEnv,
    actor: Box<dyn DdpgActorBackend>,
    explore_noise: f32,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    run_ddpg_sampler_from(
        cfg,
        venv,
        DdpgPolicySource::Local(actor),
        explore_noise,
        store,
        queue,
        stop,
    )
}

/// Run the DDPG sampler loop (deterministic actor + per-env exploration
/// noise; chunks carry raw transitions for the replay buffer). Thin
/// wrapper over the generic [`run_algo_sampler`] with the DDPG algorithm
/// hooks (the pre-trait behavior, bit-for-bit).
pub fn run_ddpg_sampler_from(
    cfg: SamplerCfg,
    venv: VecEnv,
    source: DdpgPolicySource,
    explore_noise: f32,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    let (obs_dim, act_dim) = (venv.obs_dim(), venv.act_dim());
    let source = match source {
        DdpgPolicySource::Local(actor) => PolicySource::Local(Box::new(
            DeterministicRowActor::new(actor, obs_dim, act_dim),
        )),
        DdpgPolicySource::Shared(client) => PolicySource::Shared(client),
    };
    let algo = crate::algo::ddpg::Ddpg::with_explore_noise(explore_noise);
    run_algo_sampler(&algo, cfg, venv, source, store, queue, stop)
}

/// The generic sampler hot loop: run `algo`'s rollout worker until
/// `stop` is set or the queue closes.
///
/// `venv` holds this worker's M lockstep envs; a Local `source` must
/// accept at least M rows per call ([`Algorithm::make_local_actor`]
/// aligns the two so the forward carries no padding on the native path),
/// while a Shared source submits exactly M raw rows per tick to the
/// inference server. All algorithm-specific behavior goes through the
/// [`AlgoSampler`] hooks built once per worker — see the module docs for
/// the division of labor.
pub fn run_algo_sampler(
    algo: &dyn Algorithm,
    cfg: SamplerCfg,
    venv: VecEnv,
    source: PolicySource,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> SamplerReport {
    run_algo_sampler_supervised(algo, cfg, venv, source, store, queue, stop, None)
}

/// [`run_algo_sampler`] under fleet supervision: with a
/// [`WorkerCtl`] the incarnation restores the deposited snapshot
/// instead of resetting, replays already-delivered chunks without
/// re-pushing them, deposits fresh snapshots at every policy
/// version-adoption point, trips scripted fault cells on its lifetime
/// tick counter, and retries shared-inference calls instead of dying
/// with a temporarily-down shard (the supervisor is respawning it).
/// `ctl = None` is exactly the unsupervised legacy behavior.
#[allow(clippy::too_many_arguments)]
pub fn run_algo_sampler_supervised(
    algo: &dyn Algorithm,
    cfg: SamplerCfg,
    mut venv: VecEnv,
    mut source: PolicySource,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
    ctl: Option<&WorkerCtl>,
) -> SamplerReport {
    let mut report = SamplerReport::default();
    let fault_label = format!("sampler worker {}", cfg.id);
    let mut emit = EmitGate {
        emitted: 0,
        skip: ctl.map(|c| c.skip_chunks).unwrap_or(0),
        lane: ctl.map(|c| &c.lane),
    };
    let m = venv.num_envs();
    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    let mut hooks = algo.make_sampler(&cfg, m, act_dim);
    let shared = !matches!(source, PolicySource::Local(_));
    // a local backend may require a fixed batch > M (XLA artifacts): rows
    // past M are zero padding whose outputs are ignored. Native batched
    // actors advertise exactly M, so the forward is full. Shared mode
    // always submits exactly M rows (the server owns any padding).
    let backend_batch = match &source {
        PolicySource::Local(actor) if actor.batch() != 0 => actor.batch(),
        _ => m,
    };
    if backend_batch < m {
        crate::log_error!(
            "sampler {}: backend batch {} cannot hold {} envs",
            cfg.id,
            backend_batch,
            m
        );
        return report;
    }

    let mut policy = match wait_first_policy(store, stop) {
        Some(p) => p,
        None => return report,
    };
    let mut produced_for_version = 0usize;
    // pool epoch of the buffered rows (shared mode; 0 = not yet observed
    // or gateless server, where the snapshot version alone drives cuts)
    let mut policy_epoch = 0u64;

    // local-mode normalize staging ([backend_batch] rows). Shared mode
    // needs no staging: requests submit `venv.obs()` and the record loop
    // reads the normalized rows straight out of the response slab.
    let mut obs_in = if shared {
        Vec::new()
    } else {
        vec![0.0f32; backend_batch * obs_dim]
    };
    // policy-noise lanes: stochastic algorithms consume one
    // [act_dim] row per env (padding rows stay zero for fixed-batch
    // backends); deterministic algorithms submit an empty lane.
    let mut noise = if hooks.uses_policy_noise() {
        vec![0.0f32; backend_batch * act_dim]
    } else {
        Vec::new()
    };
    let mut actions = vec![0.0f32; m * act_dim];
    let mut infos = vec![VecStepInfo::default(); m];
    let mut flush = vec![false; m];
    let mut boot_values = vec![0.0f32; m];
    let mut bufs: Vec<ChunkBuf> = (0..m).map(|_| ChunkBuf::new(obs_dim)).collect();
    // ticks since the last whole-worker chunk cut (see plan_boundaries)
    let mut window_ticks = 0usize;

    // Supervised restore: a respawned (or resumed-from-checkpoint)
    // incarnation continues from the deposited snapshot instead of
    // resetting — same env dynamics, same per-env RNG cursors, same
    // exploration streams, so the regenerated chunk sequence is bitwise
    // identical. Restore failures end the worker cleanly: a shape
    // mismatch is a construction bug, not a transient fault, and
    // respawning would just repeat it.
    match ctl.and_then(|c| c.restore.as_ref()) {
        Some(snap) => {
            if let Err(e) = venv.load_state(&snap.venv) {
                crate::log_error!("sampler {}: env snapshot restore failed: {e:#}", cfg.id);
                return report;
            }
            if let Err(e) = hooks.load_state(&snap.hooks) {
                crate::log_error!("sampler {}: sampler state restore failed: {e:#}", cfg.id);
                return report;
            }
            report = snap.report.clone();
        }
        None => {
            venv.reset_all();
            if let Some(ctl) = ctl {
                // first recovery point: the freshly reset fleet state
                // under the first adopted policy version
                ctl.lane.deposit(policy.version, &venv, hooks.as_ref(), &report);
            }
        }
    }

    'outer: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if let Some(ctl) = ctl {
            // lifetime tick counter: the heartbeat the supervisor reads
            // and the progress clock scripted fault cells trigger on
            let tick_no = ctl.lane.ticks.fetch_add(1, Ordering::SeqCst) + 1;
            if let Some(cells) = &ctl.fault {
                crate::util::fault::trip(cells, tick_no, &ctl.faults_injected, &fault_label);
            }
        }

        // --- one lockstep sim tick under the current policy (busy-timed
        // with the per-thread CPU clock: preemption-immune)
        let busy_t0 = crate::util::timer::thread_cpu_secs();
        if !noise.is_empty() {
            hooks.fill_policy_noise(&mut noise[..m * act_dim]);
        }
        let (out, server_busy) = match &mut source {
            PolicySource::Local(actor) => {
                normalize_rows(&mut obs_in, venv.obs(), &policy.norm, m, obs_dim);
                match actor.act(&policy.params, &obs_in, &noise) {
                    Ok(r) => (TickOut::Local(r), 0.0),
                    Err(e) => {
                        crate::log_error!("sampler {}: act failed: {e:#}", cfg.id);
                        break;
                    }
                }
            }
            src => {
                let submit: &[f32] = if noise.is_empty() {
                    &[]
                } else {
                    &noise[..m * act_dim]
                };
                // supervised mode retries a down shard: `act` is
                // retry-safe after Err (fresh request slot per call) and
                // the supervisor is respawning the server concurrently.
                // The obs and noise rows are untouched across retries, so
                // the eventual dispatch is the tick that would have run.
                let resp = loop {
                    match src.shared_act(venv.obs(), submit) {
                        Ok(r) => break r,
                        Err(e) => {
                            if ctl.is_none()
                                || stop.load(Ordering::Relaxed)
                                || queue.is_closed()
                            {
                                crate::log_error!(
                                    "sampler {}: shared act failed: {e:#}",
                                    cfg.id
                                );
                                break 'outer;
                            }
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                };
                // epoch-driven cut: under the pool gate the epoch moves on
                // the same dispatch boundary for every shard; a gateless
                // server reports epoch 0 and the version comparison alone
                // decides (the pre-epoch behavior)
                let version_moved = resp.snapshot.version != policy.version;
                if version_moved || (policy_epoch != 0 && resp.epoch != policy_epoch) {
                    // server-driven refresh: cut buffered (old-version)
                    // chunks before this tick's rows join them
                    if !flush_version_cut(
                        hooks.as_mut(),
                        &cfg,
                        &mut bufs,
                        &venv,
                        &policy,
                        resp.value(),
                        queue,
                        &mut report,
                        &mut emit,
                    ) {
                        break 'outer;
                    }
                    window_ticks = 0;
                    produced_for_version = 0;
                    let moved_forward = resp.snapshot.version > policy.version;
                    policy = resp.snapshot.clone();
                    // an epoch flip whose version the worker already
                    // adopted from the store (sync-mode refresh) is not a
                    // second refresh — count only real version moves
                    if version_moved {
                        report.policy_refreshes += 1;
                    }
                    // async-only best-effort recovery point: this tick's
                    // noise lanes are already drawn, so a replay from
                    // here is not bitwise (sync mode deposits at the
                    // refresh_policy barrier below instead, which is)
                    if let Some(ctl) = ctl {
                        if cfg.sync_budget.is_none() && moved_forward {
                            ctl.lane
                                .deposit(policy.version, &venv, hooks.as_ref(), &report);
                            emit.reset();
                        }
                    }
                }
                policy_epoch = resp.epoch;
                let sb = resp.server_busy_secs;
                (TickOut::Shared(resp), sb)
            }
        };
        // the rows the policy actually saw: local mode normalized them
        // into `obs_in`; shared mode reads them straight out of the
        // response slab (the server normalized our request rows in place
        // under its dispatch snapshot — no staging copy)
        let norm_rows: &[f32] = match &out {
            TickOut::Shared(resp) => resp.norm_obs(),
            TickOut::Local(_) => &obs_in[..m * obs_dim],
        };
        for i in 0..m {
            let buf = &mut bufs[i];
            buf.obs
                .extend_from_slice(&norm_rows[i * obs_dim..(i + 1) * obs_dim]);
            buf.stats.update(venv.obs_row(i)); // raw pre-step obs feeds the normalizer
            let lanes = TickLanes {
                action: out.action(),
                logp: out.logp(),
                value: out.value(),
            };
            hooks.record_tick(
                i,
                &lanes,
                buf,
                &mut actions[i * act_dim..(i + 1) * act_dim],
            );
        }
        // recycle the shared-inference buffers BEFORE the bootstrap call
        // below may need them (keeps the steady-state tick allocation-free)
        drop(out);

        venv.step_all(&actions, &mut infos);
        for (buf, info) in bufs.iter_mut().zip(&infos) {
            buf.rew.push(info.reward * cfg.reward_scale);
        }
        report.steps += m as u64;
        // shared mode: fold in this slab's share of the server's forward
        // CPU time so virtual-core rollout timing stays comparable across
        // inference modes
        let tick_busy = crate::util::timer::thread_cpu_secs() - busy_t0 + server_busy;
        for buf in bufs.iter_mut() {
            buf.busy_secs += tick_busy / m as f64;
        }

        // --- chunk boundaries
        window_ticks += 1;
        let (any_flush, do_refresh) = plan_boundaries(
            &infos,
            &bufs,
            window_ticks,
            cfg.chunk_steps,
            produced_for_version,
            cfg.sync_budget,
            shared,
            store,
            policy.version,
            &mut flush,
        );
        if !any_flush {
            continue;
        }
        if flush.iter().all(|&f| f) {
            window_ticks = 0; // every buffer restarts together
        }

        // Bootstrap values V(s') for truncated/continuation cuts: one
        // batched forward over the post-step observations, zero noise.
        // Only algorithms that bootstrap (PPO) pay for it. An inference
        // failure here would silently corrupt GAE targets (V = 0 looks
        // like a terminal), so it terminates the worker exactly like the
        // main-loop path.
        let mut any_needs_boot = false;
        if hooks.needs_value_bootstrap() {
            for i in 0..m {
                any_needs_boot |= flush[i] && !infos[i].terminal;
            }
        }
        if any_needs_boot {
            let n_flush = flush.iter().filter(|&&f| f).count();
            let boot_t0 = crate::util::timer::thread_cpu_secs();
            for z in noise.iter_mut() {
                *z = 0.0;
            }
            let boot = match &mut source {
                PolicySource::Local(actor) => {
                    normalize_rows(&mut obs_in, venv.obs(), &policy.norm, m, obs_dim);
                    actor.act(&policy.params, &obs_in, &noise).map(|r| {
                        boot_values[..m].copy_from_slice(&r.value[..m]);
                        0.0
                    })
                }
                // snapshot of a bootstrap response is deliberately not
                // adopted: the buffers are being flushed right here, and
                // V(s') under the freshest params is the better target
                src => {
                    let submit: &[f32] = if noise.is_empty() {
                        &[]
                    } else {
                        &noise[..m * act_dim]
                    };
                    // same down-shard retry as the main act call above
                    loop {
                        match src.shared_act(venv.obs(), submit) {
                            Ok(r) => {
                                boot_values[..m].copy_from_slice(&r.value()[..m]);
                                break Ok(r.server_busy_secs);
                            }
                            Err(e) => {
                                if ctl.is_none()
                                    || stop.load(Ordering::Relaxed)
                                    || queue.is_closed()
                                {
                                    break Err(e);
                                }
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                }
            };
            let boot_server_busy = match boot {
                Ok(sb) => sb,
                Err(e) => {
                    crate::log_error!(
                        "sampler {}: bootstrap value inference failed: {e:#}",
                        cfg.id
                    );
                    break 'outer;
                }
            };
            let boot_busy =
                crate::util::timer::thread_cpu_secs() - boot_t0 + boot_server_busy;
            for (i, buf) in bufs.iter_mut().enumerate() {
                if flush[i] {
                    buf.busy_secs += boot_busy / n_flush as f64;
                }
            }
        }

        for i in 0..m {
            if !flush[i] {
                continue;
            }
            let (terminal, truncated) = (infos[i].terminal, infos[i].truncated);
            if terminal || truncated {
                bufs[i].episode_returns.push(venv.ep_return(i));
                bufs[i].episode_lengths.push(venv.ep_len(i));
                report.episodes += 1;
            }
            let end = if terminal {
                ChunkEnd::Terminal
            } else if truncated {
                ChunkEnd::Truncated
            } else {
                ChunkEnd::Continuation
            };
            let boot = hooks.close_chunk(
                &mut bufs[i],
                venv.obs_row(i),
                &policy.norm,
                end,
                boot_values[i],
            );
            let n = bufs[i].len();
            let chunk = bufs[i].take(cfg.id, i, policy.version, end, boot);
            if !emit.push(queue, chunk) {
                break 'outer; // queue closed: shutting down
            }
            report.chunks += 1;
            produced_for_version += n;
            if terminal || truncated {
                venv.reset_env(i);
                hooks.on_episode_end(i);
            }
        }

        // --- policy refresh (all buffers are empty now: flush-all above)
        if do_refresh {
            if !refresh_policy(&mut policy, cfg.sync_budget.is_some(), store, stop, &mut report)
            {
                break 'outer;
            }
            produced_for_version = 0;
            if let Some(ctl) = ctl {
                // version-adoption recovery point: buffers are empty and
                // the exploration RNG sits exactly at a chunk boundary,
                // so a replay from this snapshot is bitwise (the sync
                // checkpoint/respawn guarantee rides on this deposit)
                ctl.lane
                    .deposit(policy.version, &venv, hooks.as_ref(), &report);
                emit.reset();
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;
    use std::thread;

    fn pendulum_factory() -> NativeFactory {
        NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default())
    }

    fn pendulum_venv(id: usize, m: usize, seed: u64) -> VecEnv {
        VecEnv::from_registry("pendulum", m, seed, (id * m) as u64 + 1).unwrap()
    }

    fn spawn_ppo(
        cfg: SamplerCfg,
        m: usize,
        store: Arc<PolicyStore>,
        queue: Arc<Channel<ExperienceChunk>>,
        stop: Arc<AtomicBool>,
    ) -> thread::JoinHandle<SamplerReport> {
        thread::spawn(move || {
            let f = pendulum_factory();
            let venv = pendulum_venv(cfg.id, m, cfg.seed);
            let actor = f.make_actor_batched(m).unwrap();
            run_ppo_sampler(cfg, venv, actor, &store, &queue, &stop)
        })
    }

    #[test]
    fn sampler_produces_chunks_with_consistent_shapes() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(32));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 7,
                chunk_steps: 64,
                sync_budget: None,
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        let mut total = 0usize;
        let mut chunks = Vec::new();
        while total < 600 {
            let c = queue.pop().unwrap();
            total += c.len();
            chunks.push(c);
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();

        for c in &chunks {
            assert_eq!(c.obs.len(), c.len() * 3);
            assert_eq!(c.act.len(), c.len());
            assert_eq!(c.logp.len(), c.len());
            assert_eq!(c.value.len(), c.len());
            assert!(c.len() <= 64);
            assert!(c.rew.iter().all(|r| r.is_finite()));
            assert_eq!(c.policy_version, 1);
            assert_eq!(c.env_slot, 0);
            // pendulum never terminates: only Truncated (at 200) or
            // Continuation chunks
            assert_ne!(c.end, ChunkEnd::Terminal);
        }
        assert!(report.steps >= 600);
        // pendulum episodes are 200 steps; ~3 episodes in 600 samples
        assert!(report.episodes >= 2);
    }

    #[test]
    fn vectorized_sampler_fans_chunks_across_env_slots() {
        let m = 4;
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 21,
                chunk_steps: 50,
                sync_budget: None,
                reward_scale: 1.0,
            },
            m,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        let mut total = 0usize;
        let mut chunks = Vec::new();
        while total < 1600 {
            let c = queue.pop().unwrap();
            total += c.len();
            chunks.push(c);
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();

        for c in &chunks {
            assert!(c.env_slot < m);
            assert_eq!(c.obs.len(), c.len() * 3);
            assert!(c.len() <= 50);
            assert!(c
                .obs_stats
                .as_ref()
                .map(|s| s.count() as usize == c.len())
                .unwrap_or(false));
        }
        // all env slots contribute
        for slot in 0..m {
            assert!(
                chunks.iter().any(|c| c.env_slot == slot),
                "no chunks from env slot {slot}"
            );
        }
        assert!(report.steps >= 1600);
        // M envs in lockstep: first M chunks (one full chunk per env)
        // arrive within the same policy version
        assert!(report.chunks >= m as u64);
    }

    /// Vectorization must be observationally transparent: under a fixed
    /// policy, env slot 0's chunk stream from an M=4 worker is bitwise-
    /// identical to the chunk stream of an M=1 worker with the same
    /// dynamics + noise streams.
    #[test]
    fn env_slot_trajectories_independent_of_vector_width() {
        let collect = |m: usize, budget: usize| -> Vec<ExperienceChunk> {
            let store = Arc::new(PolicyStore::new());
            let queue = Arc::new(Channel::new(256));
            let stop = Arc::new(AtomicBool::new(false));
            let f = pendulum_factory();
            store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));
            let h = spawn_ppo(
                SamplerCfg {
                    id: 0,
                    seed: 33,
                    chunk_steps: 40,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                m,
                store.clone(),
                queue.clone(),
                stop.clone(),
            );
            let mut total = 0usize;
            let mut chunks = Vec::new();
            while total < budget {
                let c = queue.pop().unwrap();
                total += c.len();
                chunks.push(c);
            }
            stop.store(true, Ordering::Relaxed);
            queue.close();
            h.join().unwrap();
            chunks
        };

        let solo: Vec<_> = collect(1, 400);
        let vec4: Vec<_> = collect(4, 1600)
            .into_iter()
            .filter(|c| c.env_slot == 0)
            .collect();
        let n = solo.len().min(vec4.len());
        assert!(n >= 3, "not enough chunks to compare ({n})");
        for (a, b) in solo[..n].iter().zip(&vec4[..n]) {
            assert_eq!(a.obs, b.obs, "obs diverged between M=1 and M=4");
            assert_eq!(a.act, b.act, "actions diverged");
            assert_eq!(a.rew, b.rew, "rewards diverged");
            assert_eq!(a.logp, b.logp, "logp diverged");
            assert_eq!(a.value, b.value, "values diverged");
            assert_eq!(a.end, b.end, "chunk ends diverged");
            assert_eq!(a.bootstrap_value, b.bootstrap_value, "bootstraps diverged");
        }
    }

    /// Tentpole acceptance: `--inference-mode shared` must be
    /// observationally transparent at ANY shard count. Under a fixed
    /// policy version, every (worker, env slot) chunk stream produced
    /// through the sharded inference pool — S=1 or S=2 — is bitwise
    /// identical to the local-backend stream at N=4 workers x M=2 envs:
    /// the pool batches across workers but the row-independent forward,
    /// server-side normalization, and static worker->shard assignment
    /// leave every trajectory untouched.
    #[test]
    fn shard_count_does_not_change_ppo_chunk_streams() {
        use crate::runtime::epoch::EpochMode;
        use crate::runtime::inference_server::{InferencePool, InferencePoolCfg, WaitPolicy};
        use std::collections::BTreeMap;

        let n = 4usize;
        let m = 2usize;
        let budget = 2400usize;

        // None = local backends; Some(s) = shared pool with s shards
        let collect = |shards: Option<usize>| -> BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
            let store = Arc::new(PolicyStore::new());
            let queue = Arc::new(Channel::new(256));
            let stop = Arc::new(AtomicBool::new(false));
            let f = pendulum_factory();
            store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

            let pool = shards.map(|s| {
                Arc::new(InferencePool::new(InferencePoolCfg {
                    workers: n,
                    rows_per_worker: m,
                    shards: s,
                    wait: WaitPolicy::Fixed(Duration::from_millis(5)),
                    epoch: EpochMode::Pool,
                    obs_dim: 3,
                    act_dim: 1,
                }))
            });
            let mut clients: Vec<_> = (0..n)
                .map(|id| pool.as_ref().map(|p| p.client(id)))
                .collect();
            let mut handles = Vec::new();
            for id in 0..n {
                let scfg = SamplerCfg {
                    id,
                    seed: 33,
                    chunk_steps: 40,
                    sync_budget: None,
                    reward_scale: 1.0,
                };
                let store2 = store.clone();
                let queue2 = queue.clone();
                let stop2 = stop.clone();
                let client = clients[id].take();
                handles.push(thread::spawn(move || {
                    let f = pendulum_factory();
                    let venv = pendulum_venv(id, m, scfg.seed);
                    let source = match client {
                        Some(c) => PpoPolicySource::Shared(c),
                        None => PpoPolicySource::Local(f.make_actor_batched(m).unwrap()),
                    };
                    run_ppo_sampler_from(scfg, venv, source, &store2, &queue2, &stop2)
                }));
            }
            let server_hs: Vec<_> = pool
                .as_ref()
                .map(|p| {
                    p.shards()
                        .iter()
                        .map(|shard| {
                            let shard = shard.clone();
                            let store2 = store.clone();
                            thread::spawn(move || {
                                let f = pendulum_factory();
                                shard.serve_ppo(&f, &store2).unwrap();
                            })
                        })
                        .collect()
                })
                .unwrap_or_default();

            let mut total = 0usize;
            let mut streams: BTreeMap<(usize, usize), Vec<ExperienceChunk>> = BTreeMap::new();
            while total < budget {
                let c = queue.pop().unwrap();
                total += c.len();
                streams.entry((c.sampler_id, c.env_slot)).or_default().push(c);
            }
            stop.store(true, Ordering::Relaxed);
            queue.close();
            for h in handles {
                h.join().unwrap();
            }
            for h in server_hs {
                h.join().unwrap();
            }
            streams
        };

        let local = collect(None);
        let shard1 = collect(Some(1));
        let shard2 = collect(Some(2));
        for (label, shared) in [("S=1", &shard1), ("S=2", &shard2)] {
            assert_eq!(
                shared.len(),
                n * m,
                "{label}: every (worker, slot) must contribute"
            );
            for (key, lchunks) in &local {
                let schunks = &shared[key];
                let k = lchunks.len().min(schunks.len());
                assert!(k >= 3, "{label} stream {key:?}: only {k} comparable chunks");
                for (a, b) in lchunks[..k].iter().zip(&schunks[..k]) {
                    assert_eq!(a.policy_version, b.policy_version, "{label} {key:?}: version");
                    assert_eq!(a.obs, b.obs, "{label} {key:?}: obs diverged");
                    assert_eq!(a.act, b.act, "{label} {key:?}: actions diverged");
                    assert_eq!(a.rew, b.rew, "{label} {key:?}: rewards diverged");
                    assert_eq!(a.logp, b.logp, "{label} {key:?}: logp diverged");
                    assert_eq!(a.value, b.value, "{label} {key:?}: values diverged");
                    assert_eq!(a.end, b.end, "{label} {key:?}: chunk ends diverged");
                    assert_eq!(
                        a.bootstrap_value, b.bootstrap_value,
                        "{label} {key:?}: bootstraps diverged"
                    );
                }
            }
        }
    }

    /// DDPG counterpart of the shard-determinism acceptance test: the
    /// sharded pool (S=1 and S=2) must leave replay chunk streams
    /// (including the trailing normalized s' row and post-round-trip OU
    /// noise order) untouched at N=4 workers x M=2 envs under a fixed
    /// actor.
    #[test]
    fn shard_count_does_not_change_ddpg_chunk_streams() {
        use crate::runtime::epoch::EpochMode;
        use crate::runtime::inference_server::{InferencePool, InferencePoolCfg, WaitPolicy};
        use std::collections::BTreeMap;

        let n = 4usize;
        let m = 2usize;
        let budget = 1600usize;

        let collect = |shards: Option<usize>| -> BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
            let store = Arc::new(PolicyStore::new());
            let queue = Arc::new(Channel::new(256));
            let stop = Arc::new(AtomicBool::new(false));
            let f = pendulum_factory();
            let (actor_params, _) = f.init_ddpg_params(0);
            store.publish(actor_params, NormSnapshot::identity(3));

            let pool = shards.map(|s| {
                Arc::new(InferencePool::new(InferencePoolCfg {
                    workers: n,
                    rows_per_worker: m,
                    shards: s,
                    wait: WaitPolicy::Fixed(Duration::from_millis(5)),
                    epoch: EpochMode::Pool,
                    obs_dim: 3,
                    act_dim: 1,
                }))
            });
            let mut clients: Vec<_> = (0..n)
                .map(|id| pool.as_ref().map(|p| p.client(id)))
                .collect();
            let mut handles = Vec::new();
            for id in 0..n {
                let scfg = SamplerCfg {
                    id,
                    seed: 17,
                    chunk_steps: 32,
                    sync_budget: None,
                    reward_scale: 1.0,
                };
                let store2 = store.clone();
                let queue2 = queue.clone();
                let stop2 = stop.clone();
                let client = clients[id].take();
                handles.push(thread::spawn(move || {
                    let f = pendulum_factory();
                    let venv = pendulum_venv(id, m, scfg.seed);
                    let source = match client {
                        Some(c) => DdpgPolicySource::Shared(c),
                        None => {
                            DdpgPolicySource::Local(f.make_ddpg_actor_batched(m).unwrap())
                        }
                    };
                    run_ddpg_sampler_from(
                        scfg, venv, source, 0.1, &store2, &queue2, &stop2,
                    )
                }));
            }
            let server_hs: Vec<_> = pool
                .as_ref()
                .map(|p| {
                    p.shards()
                        .iter()
                        .map(|shard| {
                            let shard = shard.clone();
                            let store2 = store.clone();
                            thread::spawn(move || {
                                let f = pendulum_factory();
                                shard.serve_ddpg(&f, &store2).unwrap();
                            })
                        })
                        .collect()
                })
                .unwrap_or_default();

            let mut total = 0usize;
            let mut streams: BTreeMap<(usize, usize), Vec<ExperienceChunk>> = BTreeMap::new();
            while total < budget {
                let c = queue.pop().unwrap();
                total += c.len();
                streams.entry((c.sampler_id, c.env_slot)).or_default().push(c);
            }
            stop.store(true, Ordering::Relaxed);
            queue.close();
            for h in handles {
                h.join().unwrap();
            }
            for h in server_hs {
                h.join().unwrap();
            }
            streams
        };

        let local = collect(None);
        let shard1 = collect(Some(1));
        let shard2 = collect(Some(2));
        for (label, shared) in [("S=1", &shard1), ("S=2", &shard2)] {
            assert_eq!(
                shared.len(),
                n * m,
                "{label}: every (worker, slot) must contribute"
            );
            for (key, lchunks) in &local {
                let schunks = &shared[key];
                let k = lchunks.len().min(schunks.len());
                assert!(k >= 2, "{label} stream {key:?}: only {k} comparable chunks");
                for (a, b) in lchunks[..k].iter().zip(&schunks[..k]) {
                    assert_eq!(a.obs, b.obs, "{label} {key:?}: obs (incl. s' row) diverged");
                    assert_eq!(a.act, b.act, "{label} {key:?}: actions diverged");
                    assert_eq!(a.rew, b.rew, "{label} {key:?}: rewards diverged");
                    assert_eq!(a.end, b.end, "{label} {key:?}: chunk ends diverged");
                }
            }
        }
    }

    /// Shared mode must also track published policy versions (the server
    /// observes the store per dispatch; workers cut on version changes).
    #[test]
    fn shared_sampler_adopts_server_driven_refresh() {
        use crate::runtime::inference_server::{
            InferenceServer, InferenceServerCfg, WaitPolicy,
        };

        let store = Arc::new(PolicyStore::new());
        // small queue: bounds how many stale v1 chunks can pile up before
        // the publish below, so a short pop budget must reach v2
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let server = Arc::new(InferenceServer::new(InferenceServerCfg::single(
            WaitPolicy::Fixed(Duration::from_millis(2)),
            1,
            3,
            1,
        )));
        let client = server.client();
        let server_h = {
            let s = server.clone();
            let store2 = store.clone();
            thread::spawn(move || {
                let f = pendulum_factory();
                s.serve_ppo(&f, &store2).unwrap();
            })
        };
        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = thread::spawn(move || {
            let venv = pendulum_venv(0, 1, 8);
            run_ppo_sampler_from(
                SamplerCfg {
                    id: 0,
                    seed: 8,
                    chunk_steps: 50,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                venv,
                PpoPolicySource::Shared(client),
                &store2,
                &queue2,
                &stop2,
            )
        });

        for _ in 0..3 {
            assert_eq!(queue.pop().unwrap().policy_version, 1);
        }
        store.publish(f.init_ppo_params(1), NormSnapshot::identity(3));
        let mut saw_v2 = false;
        for _ in 0..30 {
            if queue.pop().unwrap().policy_version == 2 {
                saw_v2 = true;
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();
        server_h.join().unwrap();
        assert!(saw_v2, "shared sampler never produced v2 chunks");
        assert!(report.policy_refreshes >= 1);
    }

    #[test]
    fn sampler_tags_chunks_with_policy_version() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 1,
                seed: 8,
                chunk_steps: 50,
                sync_budget: None,
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // consume a few v1 chunks, then publish v2 and expect the tag to move
        for _ in 0..3 {
            assert_eq!(queue.pop().unwrap().policy_version, 1);
        }
        store.publish(f.init_ppo_params(1), NormSnapshot::identity(3));
        let mut saw_v2 = false;
        for _ in 0..10 {
            if queue.pop().unwrap().policy_version == 2 {
                saw_v2 = true;
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let report = h.join().unwrap();
        assert!(saw_v2, "sampler never picked up v2");
        assert!(report.policy_refreshes >= 1);
    }

    #[test]
    fn sync_mode_stops_at_budget() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let h = spawn_ppo(
            SamplerCfg {
                id: 0,
                seed: 9,
                chunk_steps: 40,
                sync_budget: Some(120),
                reward_scale: 1.0,
            },
            1,
            store.clone(),
            queue.clone(),
            stop.clone(),
        );

        // worker should produce exactly ceil-to-chunk >= 120 samples then stall
        thread::sleep(Duration::from_millis(300));
        let mut total = 0;
        while let Ok(Some(c)) = queue.try_pop() {
            assert_eq!(c.policy_version, 1);
            total += c.len();
        }
        assert!(
            (120..=160).contains(&total),
            "sync budget not respected: {total}"
        );
        // release the barrier with v2; more chunks must arrive
        store.publish(f.init_ppo_params(2), NormSnapshot::identity(3));
        let c = queue.pop_timeout(Duration::from_secs(5)).unwrap();
        assert!(c.is_some(), "sampler did not resume after publish");
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn ddpg_sampler_appends_next_obs_row() {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let f = pendulum_factory();
        let (actor_params, _) = f.init_ddpg_params(0);
        store.publish(actor_params, NormSnapshot::identity(3));

        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = thread::spawn(move || {
            let f = pendulum_factory();
            let venv = pendulum_venv(0, 2, 11);
            let actor = f.make_ddpg_actor_batched(2).unwrap();
            run_ddpg_sampler(
                SamplerCfg {
                    id: 0,
                    seed: 11,
                    chunk_steps: 32,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                venv,
                actor,
                0.1,
                &store2,
                &queue2,
                &stop2,
            )
        });

        let c = queue.pop().unwrap();
        stop.store(true, Ordering::Relaxed);
        queue.close();
        h.join().unwrap();
        // obs has len+1 rows (trailing next-obs row for replay)
        assert_eq!(c.obs.len(), (c.len() + 1) * 3);
        // actions are clipped
        assert!(c.act.iter().all(|a| a.abs() <= 1.0));
        assert!(c.env_slot < 2);
    }

    // ---------------------------------------------- cross-flip equivalence

    /// Run N sync-mode workers (local backends, or the sharded pool with
    /// its epoch gate) against a scripted sequence of policy publishes
    /// and collect every chunk keyed by (worker, env slot).
    ///
    /// The pseudo-learner publishes version k+1 only once EVERY worker
    /// has delivered its full per-version sample budget under version k —
    /// the sync-mode contract — which pins each version flip to a
    /// deterministic sim tick. That determinism is what lets the streams
    /// be compared bitwise across shard counts AND against local mode
    /// *across* publishes; async flips land on wall-clock-dependent ticks
    /// and can only ever be compared within one version.
    fn collect_across_flips(
        ddpg: bool,
        shards: Option<usize>,
        n: usize,
        m: usize,
        budget: usize,
        versions: usize,
    ) -> std::collections::BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
        use crate::runtime::epoch::EpochMode;
        use crate::runtime::inference_server::{InferencePool, InferencePoolCfg, WaitPolicy};
        use std::collections::BTreeMap;

        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::new(256));
        let stop = Arc::new(AtomicBool::new(false));
        // scripted parameter versions, fully predetermined by their seed
        let params_for = |v: usize| -> Vec<f32> {
            if ddpg {
                pendulum_factory().init_ddpg_params(v as u64).0
            } else {
                pendulum_factory().init_ppo_params(v as u64)
            }
        };
        store.publish(params_for(0), NormSnapshot::identity(3));

        let pool = shards.map(|s| {
            Arc::new(InferencePool::new(InferencePoolCfg {
                workers: n,
                rows_per_worker: m,
                shards: s,
                wait: WaitPolicy::Fixed(Duration::from_millis(2)),
                epoch: EpochMode::Pool,
                obs_dim: 3,
                act_dim: 1,
            }))
        });
        let mut clients: Vec<_> = (0..n)
            .map(|id| pool.as_ref().map(|p| p.client(id)))
            .collect();
        let mut handles = Vec::new();
        for id in 0..n {
            let scfg = SamplerCfg {
                id,
                seed: 29,
                chunk_steps: 40,
                sync_budget: Some(budget),
                reward_scale: 1.0,
            };
            let store2 = store.clone();
            let queue2 = queue.clone();
            let stop2 = stop.clone();
            let client = clients[id].take();
            handles.push(thread::spawn(move || {
                let f = pendulum_factory();
                let venv = pendulum_venv(id, m, scfg.seed);
                if ddpg {
                    let source = match client {
                        Some(c) => DdpgPolicySource::Shared(c),
                        None => DdpgPolicySource::Local(f.make_ddpg_actor_batched(m).unwrap()),
                    };
                    run_ddpg_sampler_from(scfg, venv, source, 0.1, &store2, &queue2, &stop2)
                } else {
                    let source = match client {
                        Some(c) => PpoPolicySource::Shared(c),
                        None => PpoPolicySource::Local(f.make_actor_batched(m).unwrap()),
                    };
                    run_ppo_sampler_from(scfg, venv, source, &store2, &queue2, &stop2)
                }
            }));
        }
        let server_hs: Vec<_> = pool
            .as_ref()
            .map(|p| {
                p.shards()
                    .iter()
                    .map(|shard| {
                        let shard = shard.clone();
                        let store2 = store.clone();
                        thread::spawn(move || {
                            let f = pendulum_factory();
                            if ddpg {
                                shard.serve_ddpg(&f, &store2).unwrap();
                            } else {
                                shard.serve_ppo(&f, &store2).unwrap();
                            }
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();

        // the pseudo-learner: advance the scripted publishes on exact
        // per-worker budgets
        let mut streams: BTreeMap<(usize, usize), Vec<ExperienceChunk>> = BTreeMap::new();
        for v in 1..=versions {
            let mut got = vec![0usize; n];
            while got.iter().any(|&g| g < budget) {
                let c = queue.pop().unwrap();
                assert_eq!(
                    c.policy_version, v as u64,
                    "chunk version drifted from the scripted schedule"
                );
                got[c.sampler_id] += c.len();
                streams
                    .entry((c.sampler_id, c.env_slot))
                    .or_default()
                    .push(c);
            }
            for (w, &g) in got.iter().enumerate() {
                assert_eq!(g, budget, "worker {w} overshot its sync budget");
            }
            if v < versions {
                store.publish(params_for(v), NormSnapshot::identity(3));
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        for h in handles {
            h.join().unwrap();
        }
        for h in server_hs {
            h.join().unwrap();
        }
        streams
    }

    fn assert_streams_equal(
        label: &str,
        a: &std::collections::BTreeMap<(usize, usize), Vec<ExperienceChunk>>,
        b: &std::collections::BTreeMap<(usize, usize), Vec<ExperienceChunk>>,
        versions: usize,
    ) {
        assert_eq!(a.len(), b.len(), "{label}: stream key sets differ");
        for (key, ac) in a {
            let bc = &b[key];
            assert_eq!(ac.len(), bc.len(), "{label} {key:?}: chunk counts differ");
            let seen: std::collections::BTreeSet<u64> =
                ac.iter().map(|c| c.policy_version).collect();
            let want: std::collections::BTreeSet<u64> = (1..=versions as u64).collect();
            assert_eq!(
                seen, want,
                "{label} {key:?}: stream must span every scripted version"
            );
            for (x, y) in ac.iter().zip(bc) {
                assert_eq!(x.policy_version, y.policy_version, "{label} {key:?}: version");
                assert_eq!(x.obs, y.obs, "{label} {key:?}: obs diverged");
                assert_eq!(x.act, y.act, "{label} {key:?}: actions diverged");
                assert_eq!(x.rew, y.rew, "{label} {key:?}: rewards diverged");
                assert_eq!(x.logp, y.logp, "{label} {key:?}: logp diverged");
                assert_eq!(x.value, y.value, "{label} {key:?}: values diverged");
                assert_eq!(x.end, y.end, "{label} {key:?}: chunk ends diverged");
                assert_eq!(
                    x.bootstrap_value, y.bootstrap_value,
                    "{label} {key:?}: bootstraps diverged"
                );
            }
        }
    }

    /// Tentpole acceptance: shard count is a pure performance knob even
    /// ACROSS policy version flips. With flips pinned to deterministic
    /// sim ticks (sync budgets driven by the scripted pseudo-learner),
    /// the per-(worker, env) chunk streams — spanning two mid-run
    /// publishes, v1 -> v2 -> v3, with an episode truncation inside the
    /// final segment — are bitwise identical for local inference and the
    /// epoch-gated pool at S = 1, 2 and 4 (N=4, M=2). This is exactly
    /// the case PR 3's frozen-policy tests could not cover.
    #[test]
    fn version_flips_do_not_change_ppo_chunk_streams_across_shard_counts() {
        let (n, m, budget, versions) = (4, 2, 160, 3);
        let local = collect_across_flips(false, None, n, m, budget, versions);
        for s in [1usize, 2, 4] {
            let sharded = collect_across_flips(false, Some(s), n, m, budget, versions);
            assert_streams_equal(&format!("ppo S={s}"), &local, &sharded, versions);
        }
    }

    /// DDPG counterpart of the cross-flip acceptance test: replay chunk
    /// streams (including the trailing normalized s' rows) are bitwise
    /// identical for local vs S ∈ {1, 2, 4} across two scripted actor
    /// publishes at N=4, M=2.
    #[test]
    fn version_flips_do_not_change_ddpg_chunk_streams_across_shard_counts() {
        let (n, m, budget, versions) = (4, 2, 160, 3);
        let local = collect_across_flips(true, None, n, m, budget, versions);
        for s in [1usize, 2, 4] {
            let sharded = collect_across_flips(true, Some(s), n, m, budget, versions);
            assert_streams_equal(&format!("ddpg S={s}"), &local, &sharded, versions);
        }
    }
}
