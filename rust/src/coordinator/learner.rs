//! The agent (learner) process: consumes experience chunks from the
//! experience queue, updates the policy, and publishes parameters through
//! the policy store — the center of the paper's Fig 2.
//!
//! Each iteration:
//!   1. **collect** — blockingly drain the queue until the per-iteration
//!      sample budget (paper: 20,000) is met; merge sampler-side obs
//!      statistics; track chunk staleness.
//!   2. **learn** — assemble the PPO dataset (GAE per chunk through the
//!      backend), run shuffled minibatch epochs, one Adam step each.
//!   3. **publish** — push the new flat parameters + normalization
//!      snapshot; async samplers pick them up at their next chunk
//!      boundary.

use crate::algo::api::LearnerDriver;
use crate::algo::ddpg::{ddpg_update, ddpg_update_grained};
use crate::algo::normalizer::RunningNorm;
use crate::algo::ppo::{annealed_lr, ppo_update, ppo_update_sharded};
use crate::algo::rollout::{ChunkEnd, ExperienceChunk, PpoDataset};
use crate::config::{ReplayStrategy, TrainConfig};
use crate::coordinator::metrics::IterationMetrics;
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::nn::adam::AdamCfg;
use crate::nn::layout::{actor_layout, critic_layout, ParamLayout};
use crate::nn::mlp::NetShape;
use crate::replay::shard::{ReplayRng, ShardedReplay};
use crate::runtime::{DdpgLearnerBackend, DdpgTrainState, PpoLearnerBackend, PpoTrainState};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Accumulated per-iteration episode statistics.
#[derive(Debug, Default)]
struct EpisodeStats {
    returns: Vec<f32>,
    lengths: Vec<usize>,
}

impl EpisodeStats {
    fn absorb(&mut self, c: &ExperienceChunk) {
        self.returns.extend_from_slice(&c.episode_returns);
        self.lengths.extend_from_slice(&c.episode_lengths);
    }

    fn mean_return(&self) -> f32 {
        crate::util::stats::mean_f32(&self.returns)
    }

    fn mean_len(&self) -> f32 {
        if self.lengths.is_empty() {
            f32::NAN
        } else {
            self.lengths.iter().sum::<usize>() as f32 / self.lengths.len() as f32
        }
    }
}

/// PPO learner driving one training run.
pub struct PpoLearner {
    pub state: PpoTrainState,
    backend: Box<dyn PpoLearnerBackend>,
    /// Extra backends for sharded learning (§6.2); empty = single learner.
    shard_backends: Vec<Box<dyn PpoLearnerBackend>>,
    norm: RunningNorm,
    rng: Pcg64,
    total_steps: u64,
    wall: Stopwatch,
    /// Carry-over chunks popped beyond the budget (async mode keeps
    /// producing while we learn).
    carry: Vec<ExperienceChunk>,
}

impl PpoLearner {
    pub fn new(
        backend: Box<dyn PpoLearnerBackend>,
        shard_backends: Vec<Box<dyn PpoLearnerBackend>>,
        init_params: Vec<f32>,
        obs_dim: usize,
        seed: u64,
    ) -> Self {
        Self {
            state: PpoTrainState::new(init_params),
            backend,
            shard_backends,
            norm: RunningNorm::new(obs_dim, 10.0),
            rng: Pcg64::with_stream(seed, 0xFEED),
            total_steps: 0,
            wall: Stopwatch::start(),
            carry: Vec::new(),
        }
    }

    /// Publish the initial policy so samplers can start.
    pub fn publish_initial(&self, store: &PolicyStore) {
        store.publish(self.state.flat.clone(), self.norm.snapshot());
    }

    /// Run one iteration; returns metrics, or Err when the queue closed.
    pub fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        let iter_sw = Stopwatch::start();
        let current_version = store.version();

        // ---- 1. collect -------------------------------------------------
        let collect_sw = Stopwatch::start();
        let mut chunks = std::mem::take(&mut self.carry);
        let mut n: usize = chunks.iter().map(|c| c.len()).sum();
        let mut staleness_sum = 0.0f32;
        let mut eps = EpisodeStats::default();
        for c in &chunks {
            staleness_sum += (current_version.saturating_sub(c.policy_version)) as f32;
            eps.absorb(c);
        }
        let mut dropped = 0usize;
        let mut busy_per_worker: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        for c in &chunks {
            *busy_per_worker.entry(c.sampler_id).or_default() += c.busy_secs;
        }
        while n < cfg.samples_per_iter {
            let c = queue
                .pop()
                .map_err(|_| anyhow::anyhow!("experience queue closed"))?;
            // episode stats and normalizer updates count even for chunks we
            // drop as too stale — only the *gradient* data must be fresh.
            eps.absorb(&c);
            let lag = current_version.saturating_sub(c.policy_version);
            if cfg.max_staleness > 0 && lag > cfg.max_staleness {
                // stats count even for dropped chunks — merged here since
                // the chunk never reaches the canonical-order pass below
                if let Some(stats) = &c.obs_stats {
                    self.norm.merge(stats);
                }
                dropped += 1;
                continue;
            }
            n += c.len();
            staleness_sum += lag as f32;
            *busy_per_worker.entry(c.sampler_id).or_default() += c.busy_secs;
            chunks.push(c);
        }
        if dropped > 0 {
            crate::log_debug!("iteration {iter}: dropped {dropped} stale chunks");
        }
        // Canonical chunk order: the queue interleaves workers by thread
        // timing, so arrival order is nondeterministic run-to-run. Sorting
        // by (version, worker, env slot) — stable, so one env's chunks
        // keep their FIFO generation order — before every float-order-
        // sensitive fold (normalizer merges, dataset assembly) makes the
        // learner's output a pure function of the chunk SET. This is what
        // lets a supervised respawn or a kill-then-resume reproduce a
        // fault-free sync run bitwise.
        chunks.sort_by_key(|c| (c.policy_version, c.sampler_id, c.env_slot));
        for c in &mut chunks {
            if let Some(stats) = c.obs_stats.take() {
                self.norm.merge(&stats);
            }
        }
        let collect_secs = collect_sw.elapsed_secs();
        // virtual-core rollout time: the slowest worker's measured busy time
        let virtual_collect_secs = busy_per_worker
            .values()
            .fold(0.0f64, |a, &b| a.max(b));

        // ---- 2. learn ---------------------------------------------------
        let learn_sw = Stopwatch::start();
        let mut dataset = PpoDataset::assemble(
            &chunks,
            self.norm.dim(),
            chunks
                .first()
                .map(|c| c.act.len() / c.len().max(1))
                .unwrap_or(1),
            |r, v, ct| self.backend.gae(r, v, ct),
        )?;
        let lr = annealed_lr(&cfg.ppo, iter, cfg.iterations);
        let update = if self.shard_backends.is_empty() {
            ppo_update(
                self.backend.as_mut(),
                &mut self.state,
                &mut dataset,
                &cfg.ppo,
                lr,
                &mut self.rng,
            )?
        } else {
            ppo_update_sharded(
                &mut self.shard_backends,
                &mut self.state,
                &mut dataset,
                &cfg.ppo,
                lr,
                &mut self.rng,
            )?
        };
        let learn_secs = learn_sw.elapsed_secs();

        // ---- 3. publish ---------------------------------------------
        store.publish(self.state.flat.clone(), self.norm.snapshot());

        self.total_steps += n as u64;
        Ok(IterationMetrics {
            iter,
            samples: n,
            collect_secs,
            virtual_collect_secs,
            learn_secs,
            total_secs: iter_sw.elapsed_secs(),
            mean_return: eps.mean_return(),
            episodes: eps.returns.len(),
            mean_ep_len: eps.mean_len(),
            total_steps: self.total_steps,
            wall_secs: self.wall.elapsed_secs(),
            pi_loss: update.stats.pi_loss,
            v_loss: update.stats.v_loss,
            entropy: update.stats.entropy,
            approx_kl: update.stats.approx_kl,
            clip_frac: update.stats.clip_frac,
            lr,
            staleness: staleness_sum / chunks.len().max(1) as f32,
        })
    }
}

/// The generic pipeline drives PPO through the [`LearnerDriver`] trait
/// (`algo::api::Algorithm::make_learner` constructs it); the inherent
/// methods above remain the concrete API for direct use and tests.
impl LearnerDriver for PpoLearner {
    fn publish_initial(&self, store: &PolicyStore) {
        PpoLearner::publish_initial(self, store)
    }

    fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        PpoLearner::iteration(self, iter, cfg, queue, store)
    }

    fn final_params(&self) -> Vec<f32> {
        self.state.flat.clone()
    }

    fn final_norm(&self) -> crate::algo::normalizer::NormSnapshot {
        self.norm.snapshot()
    }

    /// Full on-policy training state: parameters, Adam moments + step
    /// counter, update RNG, normalizer, and the sample counter. Taken at
    /// an iteration boundary (post-publish), where `carry` is empty in
    /// sync mode; any async carry-over chunks are deliberately NOT
    /// persisted — a resumed async run re-collects them (best-effort,
    /// like async timing itself).
    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.state.flat);
        w.put_f32s(&self.state.m);
        w.put_f32s(&self.state.v);
        w.put_u64(self.state.t);
        let (rs, ri) = self.rng.raw_state();
        w.put_u128(rs);
        w.put_u128(ri);
        self.norm.save_state(&mut w);
        w.put_u64(self.total_steps);
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let flat = r.read_f32s()?;
        anyhow::ensure!(
            flat.len() == self.state.flat.len(),
            "PPO learner state mismatch: snapshot has {} params, this run has {}",
            flat.len(),
            self.state.flat.len()
        );
        self.state.flat = flat;
        self.state.m = r.read_f32s()?;
        self.state.v = r.read_f32s()?;
        self.state.t = r.read_u64()?;
        let (rs, ri) = (r.read_u128()?, r.read_u128()?);
        self.rng = Pcg64::from_raw(rs, ri);
        self.norm = RunningNorm::load_state(&mut r)?;
        self.total_steps = r.read_u64()?;
        self.carry.clear();
        Ok(())
    }
}

/// Gradient engine of the [`DdpgLearner`].
enum DdpgEngine {
    /// Fused full-batch `DdpgLearnerBackend::train_step` — the XLA
    /// artifact path (its internal reduction order is the artifact's).
    Fused,
    /// Grain-decomposed native update
    /// ([`crate::algo::ddpg::ddpg_update_grained`]): bitwise identical
    /// for every `threads`, importance-weighted under prioritized replay.
    Grained {
        threads: usize,
        alayout: ParamLayout,
        clayout: ParamLayout,
        shape: NetShape,
        adam: AdamCfg,
    },
}

/// DDPG learner (further-work §6.1): sharded replay + off-policy updates
/// under the same parallel-collection architecture.
pub struct DdpgLearner {
    pub state: DdpgTrainState,
    backend: Box<dyn DdpgLearnerBackend>,
    replay: ShardedReplay,
    /// Seed-addressable minibatch draw stream: the sampled transition
    /// set is a pure function of (seed, draw index, buffer contents) —
    /// independent of shard count and checkpointable as two u64s.
    replay_rng: ReplayRng,
    engine: DdpgEngine,
    norm: RunningNorm,
    total_steps: u64,
    wall: Stopwatch,
    obs_dim: usize,
    act_dim: usize,
}

impl DdpgLearner {
    /// Single-shard, single-thread, fused-engine learner (the legacy
    /// construction; unit tests and the XLA path use it).
    pub fn new(
        backend: Box<dyn DdpgLearnerBackend>,
        actor: Vec<f32>,
        critic: Vec<f32>,
        obs_dim: usize,
        act_dim: usize,
        replay_capacity: usize,
        seed: u64,
    ) -> Self {
        Self::with_topology(
            backend,
            actor,
            critic,
            obs_dim,
            act_dim,
            replay_capacity,
            seed,
            1,
            ReplayStrategy::Uniform,
            1,
            None,
        )
    }

    /// Full topology constructor: `replay_shards` stripes the buffer's
    /// insert locks, `strategy` picks uniform vs prioritized draws, and
    /// `learner_threads` fans the gradient grains out (pure wall-clock
    /// knob — see [`ddpg_update_grained`]). `hidden = Some(widths)`
    /// selects the grained native engine; `None` keeps the fused
    /// `train_step` backend (XLA).
    #[allow(clippy::too_many_arguments)]
    pub fn with_topology(
        backend: Box<dyn DdpgLearnerBackend>,
        actor: Vec<f32>,
        critic: Vec<f32>,
        obs_dim: usize,
        act_dim: usize,
        replay_capacity: usize,
        seed: u64,
        replay_shards: usize,
        strategy: ReplayStrategy,
        learner_threads: usize,
        hidden: Option<&[usize]>,
    ) -> Self {
        let engine = match hidden {
            Some(h) => DdpgEngine::Grained {
                threads: learner_threads.max(1),
                alayout: actor_layout(obs_dim, act_dim, h),
                clayout: critic_layout(obs_dim, act_dim, h),
                shape: NetShape::new(obs_dim, act_dim, h),
                adam: AdamCfg::default(),
            },
            None => DdpgEngine::Fused,
        };
        Self {
            state: DdpgTrainState::new(actor, critic),
            backend,
            replay: ShardedReplay::new(replay_capacity, obs_dim, act_dim, replay_shards, strategy),
            replay_rng: ReplayRng::new(seed),
            engine,
            norm: RunningNorm::new(obs_dim, 10.0),
            total_steps: 0,
            wall: Stopwatch::start(),
            obs_dim,
            act_dim,
        }
    }

    pub fn publish_initial(&self, store: &PolicyStore) {
        store.publish(self.state.actor.clone(), self.norm.snapshot());
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Insert a DDPG chunk's transitions (chunk.obs has len+1 rows; the
    /// trailing row is s' of the final transition).
    fn absorb_chunk(&mut self, c: &ExperienceChunk) {
        let o = self.obs_dim;
        let a = self.act_dim;
        let len = c.len();
        debug_assert_eq!(c.obs.len(), (len + 1) * o, "ddpg chunk missing next-obs row");
        for i in 0..len {
            let obs = &c.obs[i * o..(i + 1) * o];
            let next = &c.obs[(i + 1) * o..(i + 2) * o];
            let act = &c.act[i * a..(i + 1) * a];
            let done = c.end == ChunkEnd::Terminal && i == len - 1;
            self.replay.push(obs, act, c.rew[i], next, done);
        }
        if let Some(stats) = &c.obs_stats {
            self.norm.merge(stats);
        }
    }

    pub fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        let iter_sw = Stopwatch::start();
        let collect_sw = Stopwatch::start();
        let mut n = 0usize;
        let mut eps = EpisodeStats::default();
        let mut busy_per_worker: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        let mut chunks: Vec<ExperienceChunk> = Vec::new();
        while n < cfg.samples_per_iter {
            let c = queue
                .pop()
                .map_err(|_| anyhow::anyhow!("experience queue closed"))?;
            n += c.len();
            eps.absorb(&c);
            *busy_per_worker.entry(c.sampler_id).or_default() += c.busy_secs;
            chunks.push(c);
        }
        // canonical order before replay insertion + normalizer merges —
        // same rationale as the PPO collect: the learner's state must be
        // a pure function of the chunk set, not of arrival interleaving
        chunks.sort_by_key(|c| (c.policy_version, c.sampler_id, c.env_slot));
        for c in &chunks {
            self.absorb_chunk(c);
        }
        let collect_secs = collect_sw.elapsed_secs();
        let virtual_collect_secs = busy_per_worker
            .values()
            .fold(0.0f64, |a, &b| a.max(b));

        let learn_sw = Stopwatch::start();
        let stats = match &self.engine {
            DdpgEngine::Fused => ddpg_update(
                self.backend.as_mut(),
                &mut self.state,
                &self.replay,
                &cfg.ddpg,
                &mut self.replay_rng,
            )?,
            DdpgEngine::Grained {
                threads,
                alayout,
                clayout,
                shape,
                adam,
            } => ddpg_update_grained(
                &mut self.state,
                &self.replay,
                &cfg.ddpg,
                &mut self.replay_rng,
                alayout,
                clayout,
                shape,
                *adam,
                *threads,
            )?,
        };
        let learn_secs = learn_sw.elapsed_secs();

        store.publish(self.state.actor.clone(), self.norm.snapshot());
        self.total_steps += n as u64;

        Ok(IterationMetrics {
            iter,
            samples: n,
            collect_secs,
            virtual_collect_secs,
            learn_secs,
            total_secs: iter_sw.elapsed_secs(),
            mean_return: eps.mean_return(),
            episodes: eps.returns.len(),
            mean_ep_len: eps.mean_len(),
            total_steps: self.total_steps,
            wall_secs: self.wall.elapsed_secs(),
            pi_loss: stats.pi_loss,
            v_loss: stats.q_loss,
            ..Default::default()
        })
    }
}

impl LearnerDriver for DdpgLearner {
    fn publish_initial(&self, store: &PolicyStore) {
        DdpgLearner::publish_initial(self, store)
    }

    fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        DdpgLearner::iteration(self, iter, cfg, queue, store)
    }

    fn final_params(&self) -> Vec<f32> {
        self.state.actor.clone()
    }

    fn final_norm(&self) -> crate::algo::normalizer::NormSnapshot {
        self.norm.snapshot()
    }

    /// Full off-policy training state: actor/critic + targets, both Adam
    /// moment pairs, normalizer, counters, the replay buffer *contents*
    /// (the versioned shard section — shard-count-portable), and the
    /// replay draw cursor. A resumed run therefore replays bitwise
    /// identical minibatches; `rust/tests/chaos.rs` enforces
    /// kill-then-resume == uninterrupted for DDPG end to end.
    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.state.actor);
        w.put_f32s(&self.state.critic);
        w.put_f32s(&self.state.targ_actor);
        w.put_f32s(&self.state.targ_critic);
        w.put_f32s(&self.state.am);
        w.put_f32s(&self.state.av);
        w.put_f32s(&self.state.cm);
        w.put_f32s(&self.state.cv);
        w.put_u64(self.state.t);
        self.norm.save_state(&mut w);
        w.put_u64(self.total_steps);
        self.replay.save_state(&mut w);
        self.replay_rng.save_state(&mut w);
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let actor = r.read_f32s()?;
        anyhow::ensure!(
            actor.len() == self.state.actor.len(),
            "DDPG learner state mismatch: snapshot has {} actor params, this run has {}",
            actor.len(),
            self.state.actor.len()
        );
        self.state.actor = actor;
        self.state.critic = r.read_f32s()?;
        self.state.targ_actor = r.read_f32s()?;
        self.state.targ_critic = r.read_f32s()?;
        self.state.am = r.read_f32s()?;
        self.state.av = r.read_f32s()?;
        self.state.cm = r.read_f32s()?;
        self.state.cv = r.read_f32s()?;
        self.state.t = r.read_u64()?;
        self.norm = RunningNorm::load_state(&mut r)?;
        self.total_steps = r.read_u64()?;
        self.replay.load_state(&mut r)?;
        self.replay_rng = ReplayRng::load_state(&mut r)?;
        Ok(())
    }
}
