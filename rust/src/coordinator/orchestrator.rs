//! Orchestrator: process topology and lifecycle for one training run —
//! spawns the N sampler workers (each driving `envs_per_sampler`
//! vectorized envs in lockstep), the learner, and — under
//! `--inference-mode shared` — the S inference-pool shard threads, each
//! owning a fleet-slice actor; wires the experience queue, policy store,
//! and inference request queues between them, runs the iteration loop,
//! and shuts everything down cleanly (the WALL-E launcher in Fig 2).
//!
//! Everything algorithm-specific is reached through ONE
//! [`Algorithm`] trait object: sampler hooks, local/shared policy
//! backends, and the learner driver. [`run`] resolves the trait object
//! from `cfg.algo` via the registry
//! (`algo::api::algorithm_from_config`); `session::Session` calls
//! [`run_with`] with the instance its builder carries. Either way, this
//! module never matches on a concrete algorithm — adding one touches
//! the registry, not the topology.
//!
//! ## Self-healing supervision
//!
//! Every sampler worker and inference shard runs inside a supervision
//! loop: a panic (a real defect, or a scripted [`crate::util::fault`]
//! cell) is caught, and the component is respawned with exponential
//! backoff under a bounded budget (`--max-restarts`, counted per
//! component). Workers restore the last clean
//! [`crate::coordinator::supervisor::WorkerSnapshot`] their lane holds
//! and replay already-delivered chunks without re-pushing them, so in
//! sync mode the merged per-env chunk streams are bitwise identical to a
//! fault-free run. Shards self-revive inside `serve_algo` (epoch-gate
//! rejoin + fresh fleet-slice actor). A component that exhausts its
//! budget aborts the whole fleet through the PR 4 shutdown paths: the
//! experience queue closes, the learner errors loudly, and every thread
//! joins.
//!
//! ## Checkpoint / resume
//!
//! `--checkpoint-every K` writes a durable [`Checkpoint`] after every
//! K-th iteration (learner state + one worker snapshot per lane), at the
//! barrier where every worker has adopted the just-published version;
//! `--resume <dir>` reloads the newest one, re-seats the policy-store
//! version, primes the lanes, and continues at the saved iteration. In
//! sync mode a kill-then-resume run reproduces the exact per-env chunk
//! streams of an uninterrupted run.

use crate::algo::api::{algorithm_from_config, Algorithm, LearnerDriver};
use crate::algo::normalizer::NormSnapshot;
use crate::algo::rollout::ExperienceChunk;
use crate::config::{FleetMode, InferEpoch, InferWait, InferenceMode, TrainConfig};
use crate::coordinator::metrics::{InferenceReport, IterationMetrics, MetricsLog};
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::{
    run_algo_sampler_supervised, PolicySource, SamplerCfg, SamplerReport,
};
use crate::coordinator::supervisor::{WorkerCtl, WorkerLane, WorkerSnapshot};
use crate::env::registry::make_env;
use crate::env::vec_env::VecEnv;
use crate::runtime::checkpoint::{self, Checkpoint, RunFingerprint};
use crate::runtime::epoch::EpochMode;
use crate::runtime::inference_server::{
    ActorClient, InferencePool, InferencePoolCfg, WaitPolicy,
};
use crate::runtime::BackendFactory;
use crate::util::fault::{CompiledFaults, FaultPlan};
use crate::util::plock;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Outcome of one full run.
pub struct RunResult {
    pub metrics: Vec<IterationMetrics>,
    pub sampler_reports: Vec<SamplerReport>,
    /// Final policy parameters (PPO flat vector, or the DDPG/TD3 actor).
    pub final_params: Vec<f32>,
    /// The observation-normalizer snapshot published with the final
    /// params — pass it to `Session::evaluate_with_norm` (or
    /// `eval::evaluate`) so evaluation applies the SAME input transform
    /// training did. Checkpoint files carry only the parameters.
    pub final_norm: NormSnapshot,
    /// (pushed, popped, producer blocked, consumer blocked).
    pub queue_stats: (u64, u64, Duration, Duration),
    /// Dispatch statistics of the shared inference server
    /// (`--inference-mode shared` only), including the fleet-health
    /// counters below folded in for the end-of-run report.
    pub infer: Option<InferenceReport>,
    /// Supervisor respawns across the whole fleet (workers + shards).
    pub restarts: u64,
    /// Scripted `--fault-inject` cells that actually fired.
    pub faults_injected: u64,
    /// Wall microseconds of each durable checkpoint write
    /// (`--checkpoint-every`; empty when checkpointing is off).
    pub checkpoint_write_us: Vec<u64>,
}

/// Run a full training session per `cfg`, reporting into `log`.
///
/// Callers choose the backend by passing the matching factory
/// (`NativeFactory` or `XlaFactory`); sampler threads each build their own
/// thread-local backend through it. The algorithm is resolved from
/// `cfg.algo` through the registry; use [`run_with`] to supply an
/// [`Algorithm`] instance directly (the `Session` path).
pub fn run(
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
) -> anyhow::Result<RunResult> {
    let algo = algorithm_from_config(cfg);
    run_with(algo.as_ref(), cfg, factory, log)
}

/// [`run`] with an explicit [`Algorithm`] instance. `cfg` remains the
/// source of truth for every hyper-parameter the learner reads per
/// iteration; `algo` must agree with `cfg.algo` (the `Session` builder
/// guarantees this by construction via `Algorithm::apply_to`).
pub fn run_with(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
) -> anyhow::Result<RunResult> {
    run_with_watched(algo, cfg, factory, log, None)
}

/// [`run_with`] plus an optional external shutdown flag (the CLI's
/// SIGINT/SIGTERM handler): when it flips mid-run, the fleet drains and
/// shuts down through the normal stop/queue-close paths and the run
/// returns the learner's resulting error. Also the `cfg.fleet_mode`
/// dispatch point: `procs` runs the sampler fleet as child PROCESSES
/// served by an in-process policy daemon ([`run_procs`]); `threads` is
/// the classic in-process topology.
pub fn run_with_watched(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
    external_stop: Option<&AtomicBool>,
) -> anyhow::Result<RunResult> {
    if cfg.fleet_mode == FleetMode::Procs {
        return run_procs(algo, cfg, factory, log, external_stop);
    }
    run_threads(algo, cfg, factory, log, external_stop)
}

fn run_threads(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
    external_stop: Option<&AtomicBool>,
) -> anyhow::Result<RunResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    algo.validate(cfg).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        make_env(&cfg.env).is_some(),
        "unknown env {:?} (known: {:?})",
        cfg.env,
        crate::env::registry::ENV_NAMES
    );
    // Kernel mode is process-global: every thread this run spawns
    // (samplers, shards, learner) must agree on exact-vs-fast before the
    // first forward pass. Same story for the env engine — every worker's
    // `VecEnv::from_registry` must pick the same stepping path before
    // the first reset.
    crate::nn::kernels::set_mode(cfg.kernels.mode());
    crate::env::batch::set_engine(cfg.env_engine.engine());

    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    if cfg.infer_precision == crate::config::InferPrecision::Int8 {
        let q = algo.quantizer(factory, cfg).ok_or_else(|| {
            anyhow::anyhow!(
                "--infer-precision int8 is not supported by algorithm {:?}",
                cfg.algo
            )
        })?;
        store.set_quantizer(q);
    }
    let stop = AtomicBool::new(false);
    let sync_budget = if cfg.async_mode {
        None
    } else {
        // ceil-divide: workers cut at their budget within M-1 samples, so
        // a floor here would undershoot the iteration total whenever
        // samplers does not divide samples_per_iter and deadlock the
        // learner's blocking collect against blocked samplers.
        Some((cfg.samples_per_iter + cfg.samplers - 1) / cfg.samplers)
    };

    // ---- supervision state --------------------------------------------
    let m = cfg.envs_per_sampler;
    let shard_count = match cfg.inference_mode {
        InferenceMode::Local => 0,
        InferenceMode::Shared => cfg.infer_shards.resolve(cfg.samplers),
    };
    let faults: Option<CompiledFaults> = if cfg.fault_inject.is_empty() {
        None
    } else {
        let plan = FaultPlan::parse(&cfg.fault_inject)?;
        Some(plan.compile(cfg.samplers, shard_count)?)
    };
    let faults_injected = Arc::new(AtomicU64::new(0));
    let restarts_total = Arc::new(AtomicU64::new(0));
    let lanes: Vec<Arc<WorkerLane>> = (0..cfg.samplers)
        .map(|_| Arc::new(WorkerLane::new()))
        .collect();

    // ---- resume -------------------------------------------------------
    let fingerprint = RunFingerprint {
        env: cfg.env.clone(),
        algo: cfg.algo.name().to_string(),
        samplers: cfg.samplers,
        envs_per_sampler: cfg.envs_per_sampler,
        seed: cfg.seed,
    };
    let resume_ck: Option<Checkpoint> = if cfg.resume.is_empty() {
        None
    } else {
        let ck = checkpoint::load_latest(Path::new(&cfg.resume))?;
        anyhow::ensure!(
            ck.fingerprint == fingerprint,
            "checkpoint fingerprint {:?} does not match this run {:?} — \
             resuming under a different topology or seed would corrupt \
             every RNG stream",
            ck.fingerprint,
            fingerprint
        );
        anyhow::ensure!(
            ck.workers.len() == cfg.samplers,
            "checkpoint holds {} worker blobs for {} samplers",
            ck.workers.len(),
            cfg.samplers
        );
        for (lane, blob) in lanes.iter().zip(&ck.workers) {
            if !blob.is_empty() {
                *plock(&lane.snapshot) = Some(WorkerSnapshot::from_bytes(blob)?);
            }
        }
        crate::log_info!(
            "resuming from iteration {} (policy version {})",
            ck.iteration,
            ck.version
        );
        Some(ck)
    };

    let mut ckpt_write_us: Vec<u64> = Vec::new();
    let mut result: Option<RunResult> = None;

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // ---- external shutdown monitor (optional) ---------------------
        // A signal handler can only flip an AtomicBool; this thread turns
        // that flip into the normal stop/queue-close drain. It exits on
        // its own once the run ends for any other reason.
        if let Some(ext) = external_stop {
            let stop = &stop;
            let queue = &queue;
            scope.spawn(move || loop {
                if stop.load(Ordering::Relaxed) || queue.is_closed() {
                    return;
                }
                if ext.load(Ordering::Relaxed) {
                    crate::log_info!("shutdown signal received; draining the fleet");
                    stop.store(true, Ordering::Relaxed);
                    queue.close();
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            });
        }

        // ---- sharded inference pool (one per run, optional) -----------
        // Clients are registered BEFORE any serve thread starts so no
        // shard can observe an empty fleet and exit early; each shard
        // thread builds its own fleet-slice backend on itself (PJRT is
        // not Send) and runs until every one of its workers has dropped
        // its handle.
        let pool = match cfg.inference_mode {
            InferenceMode::Local => None,
            InferenceMode::Shared => Some(Arc::new(InferencePool::with_flip_schedule(
                InferencePoolCfg {
                    workers: cfg.samplers,
                    rows_per_worker: m,
                    shards: shard_count,
                    wait: match cfg.infer_wait {
                        InferWait::Adaptive => WaitPolicy::Adaptive,
                        InferWait::Fixed(us) => WaitPolicy::Fixed(Duration::from_micros(us)),
                    },
                    epoch: match cfg.infer_epoch {
                        InferEpoch::Pool => EpochMode::Pool,
                        InferEpoch::Shard => EpochMode::Shard,
                    },
                    obs_dim: factory.obs_dim(),
                    act_dim: factory.act_dim(),
                },
                cfg.flip_schedule,
            ))),
        };
        if let (Some(p), Some(f)) = (&pool, &faults) {
            for (idx, shard) in p.shards().iter().enumerate() {
                if let Some(cells) = f.shard_cells(idx) {
                    shard.arm_faults(cells, faults_injected.clone());
                }
            }
        }
        let mut clients: Vec<_> = (0..cfg.samplers)
            .map(|id| pool.as_ref().map(|p| p.client(id)))
            .collect();
        let server_handles: Vec<_> = pool
            .as_ref()
            .map(|p| {
                p.shards()
                    .iter()
                    .enumerate()
                    .map(|(idx, shard)| {
                        let shard = shard.clone();
                        let store = &store;
                        let queue = &queue;
                        let stop = &stop;
                        let restarts_total = restarts_total.clone();
                        let max_restarts = cfg.max_restarts;
                        scope.spawn(move || -> anyhow::Result<()> {
                            // supervision loop: a panicked serve thread is
                            // respawned (serve_algo self-revives: epoch
                            // rejoin + fresh actor); a clean Err is not.
                            let mut attempts = 0usize;
                            loop {
                                match catch_unwind(AssertUnwindSafe(|| {
                                    shard.serve_algo(algo, factory, store)
                                })) {
                                    Ok(res) => break res,
                                    Err(payload) => {
                                        if stop.load(Ordering::Relaxed)
                                            || queue.is_closed()
                                            || attempts >= max_restarts
                                        {
                                            if attempts >= max_restarts && !queue.is_closed() {
                                                crate::log_error!(
                                                    "inference shard {idx} exhausted its \
                                                     restart budget ({max_restarts}); \
                                                     closing the experience queue"
                                                );
                                                queue.close();
                                            }
                                            resume_unwind(payload);
                                        }
                                        attempts += 1;
                                        restarts_total.fetch_add(1, Ordering::SeqCst);
                                        crate::log_error!(
                                            "inference shard {idx} panicked; respawning \
                                             (attempt {attempts}/{max_restarts})"
                                        );
                                        std::thread::sleep(backoff(attempts));
                                    }
                                }
                            }
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();

        // ---- sampler workers ------------------------------------------
        // Each worker drives `envs_per_sampler` envs in lockstep; env
        // dynamics streams are numbered globally (worker id * M + slot,
        // offset by 1), so a trajectory is pinned to its global slot
        // regardless of how envs are packed onto workers.
        let live_samplers = Arc::new(AtomicUsize::new(cfg.samplers));
        let mut handles = Vec::new();
        for id in 0..cfg.samplers {
            let scfg = SamplerCfg {
                id,
                seed: cfg.seed,
                chunk_steps: cfg.chunk_steps,
                sync_budget,
                reward_scale: cfg.reward_scale,
            };
            let queue = &queue;
            let store = &store;
            let stop = &stop;
            let env_name = cfg.env.clone();
            let client = clients[id].take();
            let live = live_samplers.clone();
            let lane = lanes[id].clone();
            let wcells = faults.as_ref().and_then(|f| f.worker_cells(id));
            let finj = faults_injected.clone();
            let restarts_total = restarts_total.clone();
            let pool_c = pool.clone();
            let max_restarts = cfg.max_restarts;
            handles.push(scope.spawn(move || -> anyhow::Result<SamplerReport> {
                // drop guard, NOT ordinary post-code: a worker that dies
                // for good (budget exhausted, or an error return) must
                // still decrement the live count and trip the queue
                // close, or the learner would inherit the very hang PR 4
                // closed
                let _guard = FleetGuard {
                    id,
                    live,
                    sync: sync_budget.is_some(),
                    queue,
                    stop,
                };
                // Keep this worker's shard alive across respawn gaps: a
                // dying incarnation drops its ActorClient during the
                // unwind, and without the hold the shard's serve loop
                // could observe zero active clients and exit before the
                // respawn re-registers.
                let _hold = pool_c.as_ref().map(|p| p.shard_for(id).hold());
                let mut client = client;
                let mut attempts = 0usize;
                loop {
                    let ctl = WorkerCtl {
                        lane: lane.clone(),
                        restore: lane.latest(),
                        skip_chunks: lane.pushed.load(Ordering::SeqCst),
                        fault: wcells.clone(),
                        faults_injected: finj.clone(),
                    };
                    // first incarnation uses the pre-registered client;
                    // respawns (and resume) re-home through the pool
                    let c = match client.take() {
                        Some(c) => Some(c),
                        None => pool_c.as_ref().map(|p| p.client(id)),
                    };
                    let scfg = scfg.clone();
                    let env_name = &env_name;
                    match catch_unwind(AssertUnwindSafe(|| {
                        run_sampler_worker(
                            scfg,
                            m,
                            env_name,
                            algo,
                            c,
                            factory,
                            store,
                            queue,
                            stop,
                            Some(&ctl),
                        )
                    })) {
                        Ok(res) => break res,
                        Err(payload) => {
                            if stop.load(Ordering::Relaxed)
                                || queue.is_closed()
                                || attempts >= max_restarts
                            {
                                if attempts >= max_restarts {
                                    crate::log_error!(
                                        "sampler worker {id} exhausted its restart \
                                         budget ({max_restarts}); giving up"
                                    );
                                }
                                // FleetGuard handles the queue close
                                resume_unwind(payload);
                            }
                            attempts += 1;
                            lane.restarts.fetch_add(1, Ordering::SeqCst);
                            restarts_total.fetch_add(1, Ordering::SeqCst);
                            crate::log_error!(
                                "sampler worker {id} panicked; respawning from its \
                                 lane snapshot (attempt {attempts}/{max_restarts})"
                            );
                            std::thread::sleep(backoff(attempts));
                        }
                    }
                }
            }));
        }

        // ---- learner (this thread) -------------------------------------
        let (final_params, final_norm) = match run_learner(
            algo,
            cfg,
            factory,
            &queue,
            &store,
            log,
            &lanes,
            resume_ck.as_ref(),
            &fingerprint,
            &mut ckpt_write_us,
        ) {
            Ok(p) => p,
            Err(e) => {
                // A learner failure must still release the samplers and
                // inference shards before propagating — the scope join
                // below would otherwise wait forever on workers that were
                // never told to stop (the hang class PR 4 closed).
                stop.store(true, Ordering::Relaxed);
                queue.close();
                // Join the scoped threads ourselves, discarding their
                // results: leaving a panicked serve thread to the scope's
                // implicit join would re-raise the panic and turn this
                // reported error into a process abort.
                for h in handles {
                    let _ = h.join();
                }
                for h in server_handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        };

        // ---- shutdown ---------------------------------------------------
        stop.store(true, Ordering::Relaxed);
        queue.close();
        // publish once more so sync-mode samplers blocked on wait_newer wake
        store.publish(final_params.clone(), final_norm.clone());
        // Join EVERY scoped thread before surfacing the first failure:
        // early-returning on the first bad join would leave later
        // panicked threads to the scope's implicit join, which re-raises
        // their panic and turns a reportable error into a process abort.
        let mut reports = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(report)) => reports.push(report),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow::anyhow!("sampler panicked"));
                }
            }
        }
        // each shard's serve loop exits once all ITS workers drop their
        // client handles
        for h in server_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err
                        .get_or_insert_with(|| anyhow::anyhow!("inference shard panicked"));
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        let restarts = restarts_total.load(Ordering::SeqCst);
        let injected = faults_injected.load(Ordering::SeqCst);
        result = Some(RunResult {
            metrics: log.iterations.clone(),
            sampler_reports: reports,
            final_params,
            final_norm,
            queue_stats: (
                queue.stats.pushed(),
                queue.stats.popped(),
                queue.stats.push_blocked(),
                queue.stats.pop_blocked(),
            ),
            infer: pool.map(|p| {
                let mut rep = p.report();
                rep.restarts = restarts;
                rep.faults_injected = injected;
                for &us in &ckpt_write_us {
                    rep.checkpoint_write_us.record(us as f64);
                }
                rep
            }),
            restarts,
            faults_injected: injected,
            checkpoint_write_us: ckpt_write_us.clone(),
        });
        Ok(())
    })?;

    Ok(result.expect("run result set"))
}

/// Exponential supervisor backoff: 10ms doubling per attempt, capped at
/// 320ms so a flapping component cannot stall shutdown for long.
fn backoff(attempt: usize) -> Duration {
    Duration::from_millis(10u64 << (attempt as u64 - 1).min(5))
}

/// `--fleet-mode procs`: the same run topology with the sampler fleet as
/// child PROCESSES. This process keeps the learner, the policy store,
/// the experience queue, and the shared inference pool, and runs the
/// policy daemon's accept loop on a Unix socket; each sampler becomes a
/// `walle sample --connect` child reading the run config from the
/// socket's sidecar file. Because the MLP forward is row-independent and
/// exploration noise is drawn inside each child from its own RNG
/// streams, per-(worker, env_slot) chunk streams are bitwise identical
/// to `threads` mode. Children that die are respawned under the same
/// `--max-restarts` budget the thread supervisor uses (fresh incarnation
/// — no lane snapshot travels across the process boundary, which is why
/// validation rejects checkpoint/resume in this mode).
fn run_procs(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
    external_stop: Option<&AtomicBool>,
) -> anyhow::Result<RunResult> {
    use crate::runtime::daemon;

    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    algo.validate(cfg).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        make_env(&cfg.env).is_some(),
        "unknown env {:?} (known: {:?})",
        cfg.env,
        crate::env::registry::ENV_NAMES
    );
    crate::nn::kernels::set_mode(cfg.kernels.mode());
    crate::env::batch::set_engine(cfg.env_engine.engine());

    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    if cfg.infer_precision == crate::config::InferPrecision::Int8 {
        let q = algo.quantizer(factory, cfg).ok_or_else(|| {
            anyhow::anyhow!(
                "--infer-precision int8 is not supported by algorithm {:?}",
                cfg.algo
            )
        })?;
        store.set_quantizer(q);
    }
    let stop = AtomicBool::new(false);
    let restarts_total = Arc::new(AtomicU64::new(0));
    let fingerprint = daemon::run_fingerprint(cfg);

    let sock = daemon::default_socket_path();
    let listener = daemon::bind_socket(&sock)?;
    let sidecar = daemon::config_sidecar(&sock);
    let sidecar_str = sidecar
        .to_str()
        .ok_or_else(|| anyhow::anyhow!("non-UTF8 sidecar path {}", sidecar.display()))?;
    cfg.save(sidecar_str)?;
    let bin = daemon::walle_binary()?;
    crate::log_info!(
        "fleet-mode procs: daemon on {}, spawning {} sampler process(es) from {}",
        sock.display(),
        cfg.samplers,
        bin.display()
    );

    let mut ckpt_write_us: Vec<u64> = Vec::new();
    let mut result: Option<RunResult> = None;
    let scope_res = std::thread::scope(|scope| -> anyhow::Result<()> {
        let pool = daemon::build_pool(cfg, factory);
        // MOVED into the accept loop below; the stash inside is what
        // keeps the pre-registered clients (and thus the shard serve
        // loops) alive, so no clone may outlive the scope — only the
        // metrics handle does.
        let ctx = daemon::DaemonCtx::new(cfg, pool.clone(), &store, &queue, &stop);
        let metrics = ctx.metrics.clone();

        // shard serve threads, supervised exactly like threads mode
        let server_handles: Vec<_> = pool
            .shards()
            .iter()
            .enumerate()
            .map(|(idx, shard)| {
                let shard = shard.clone();
                let store = &store;
                let queue = &queue;
                let stop = &stop;
                let restarts_total = restarts_total.clone();
                let max_restarts = cfg.max_restarts;
                scope.spawn(move || -> anyhow::Result<()> {
                    let mut attempts = 0usize;
                    loop {
                        match catch_unwind(AssertUnwindSafe(|| {
                            shard.serve_algo(algo, factory, store)
                        })) {
                            Ok(res) => break res,
                            Err(payload) => {
                                if stop.load(Ordering::Relaxed)
                                    || queue.is_closed()
                                    || attempts >= max_restarts
                                {
                                    if attempts >= max_restarts && !queue.is_closed() {
                                        crate::log_error!(
                                            "inference shard {idx} exhausted its \
                                             restart budget ({max_restarts}); \
                                             closing the experience queue"
                                        );
                                        queue.close();
                                    }
                                    resume_unwind(payload);
                                }
                                attempts += 1;
                                restarts_total.fetch_add(1, Ordering::SeqCst);
                                crate::log_error!(
                                    "inference shard {idx} panicked; respawning \
                                     (attempt {attempts}/{max_restarts})"
                                );
                                std::thread::sleep(backoff(attempts));
                            }
                        }
                    }
                })
            })
            .collect();
        scope.spawn(move || daemon::accept_loop(scope, listener, ctx));

        if let Some(ext) = external_stop {
            let stop = &stop;
            let queue = &queue;
            scope.spawn(move || loop {
                if stop.load(Ordering::Relaxed) || queue.is_closed() {
                    return;
                }
                if ext.load(Ordering::Relaxed) {
                    crate::log_info!("shutdown signal received; draining the fleet");
                    stop.store(true, Ordering::Relaxed);
                    queue.close();
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            });
        }

        // ---- sampler child processes + reapers ------------------------
        for id in 0..cfg.samplers {
            match daemon::spawn_sampler(&bin, &sock, &sidecar, id, true) {
                Ok(child) => {
                    let bin = &bin;
                    let sock = &sock;
                    let sidecar = &sidecar;
                    let queue = &queue;
                    let stop = &stop;
                    let restarts_total = restarts_total.clone();
                    let max_restarts = cfg.max_restarts;
                    scope.spawn(move || {
                        reap_sampler(
                            child,
                            id,
                            bin,
                            sock,
                            sidecar,
                            queue,
                            stop,
                            &restarts_total,
                            max_restarts,
                        )
                    });
                }
                Err(e) => {
                    // release everything already running before bailing,
                    // or the scope join would wait on threads that were
                    // never told to stop
                    stop.store(true, Ordering::Relaxed);
                    queue.close();
                    for h in server_handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }

        // ---- learner (this thread) ------------------------------------
        let (final_params, final_norm) = match run_learner(
            algo,
            cfg,
            factory,
            &queue,
            &store,
            log,
            &[],
            None,
            &fingerprint,
            &mut ckpt_write_us,
        ) {
            Ok(p) => p,
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                queue.close();
                for h in server_handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        };

        // ---- shutdown -------------------------------------------------
        stop.store(true, Ordering::Relaxed);
        queue.close();
        store.publish(final_params.clone(), final_norm.clone());
        // reapers SIGTERM their children; connection threads hang up on
        // `stop`, dropping their ctx clones; the accept loop drops the
        // stash, releasing every client, which lets the shard serve
        // loops exit — then the scope join completes
        let mut first_err: Option<anyhow::Error> = None;
        for h in server_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err
                        .get_or_insert_with(|| anyhow::anyhow!("inference shard panicked"));
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        let restarts = restarts_total.load(Ordering::SeqCst);
        result = Some(RunResult {
            metrics: log.iterations.clone(),
            sampler_reports: Vec::new(),
            final_params,
            final_norm,
            queue_stats: (
                queue.stats.pushed(),
                queue.stats.popped(),
                queue.stats.push_blocked(),
                queue.stats.pop_blocked(),
            ),
            infer: Some({
                let mut rep = pool.report();
                rep.restarts = restarts;
                metrics.merge_into(&mut rep);
                rep
            }),
            restarts,
            faults_injected: 0,
            checkpoint_write_us: Vec::new(),
        });
        Ok(())
    });
    let _ = std::fs::remove_file(&sock);
    let _ = std::fs::remove_file(&sidecar);
    scope_res?;
    Ok(result.expect("run result set"))
}

/// Child-process supervision, one reaper thread per sampler slot: a
/// mid-run death is respawned with the thread supervisor's backoff under
/// the same `--max-restarts` budget (the scripted
/// [`daemon::EXIT_AFTER_CHUNKS_ENV`] kill switch is stripped from
/// respawns so one scripted death cannot loop); an exhausted budget
/// closes the experience queue so the learner fails loudly. At shutdown
/// the surviving child gets SIGTERM, a bounded grace period, then
/// SIGKILL.
///
/// [`daemon::EXIT_AFTER_CHUNKS_ENV`]: crate::runtime::daemon::EXIT_AFTER_CHUNKS_ENV
#[allow(clippy::too_many_arguments)]
fn reap_sampler(
    mut child: std::process::Child,
    id: usize,
    bin: &Path,
    sock: &Path,
    sidecar: &Path,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
    restarts_total: &AtomicU64,
    max_restarts: usize,
) {
    use crate::runtime::daemon;
    let mut attempts = 0usize;
    loop {
        match child.try_wait() {
            Ok(Some(status)) => {
                if stop.load(Ordering::Relaxed) || queue.is_closed() {
                    return; // run is over; child exits are expected now
                }
                if attempts >= max_restarts {
                    crate::log_error!(
                        "sampler process {id} exhausted its restart budget \
                         ({max_restarts}); closing the experience queue"
                    );
                    queue.close();
                    return;
                }
                attempts += 1;
                restarts_total.fetch_add(1, Ordering::SeqCst);
                crate::log_error!(
                    "sampler process {id} died ({status}); respawning \
                     (attempt {attempts}/{max_restarts})"
                );
                std::thread::sleep(backoff(attempts));
                child = match daemon::spawn_sampler(bin, sock, sidecar, id, false) {
                    Ok(c) => c,
                    Err(e) => {
                        crate::log_error!(
                            "sampler process {id} respawn failed: {e:#}; \
                             closing the experience queue"
                        );
                        queue.close();
                        return;
                    }
                };
            }
            Ok(None) => {
                if stop.load(Ordering::Relaxed) || queue.is_closed() {
                    daemon::terminate_child(child, id);
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                crate::log_warn!("sampler process {id}: wait failed: {e}");
                return;
            }
        }
    }
}

/// Worker-exit supervision, armed as a drop guard so it fires on panics
/// too. A worker exiting before shutdown died on an error: the async
/// fleet can absorb losses until the LAST worker is gone, but in sync
/// mode ANY loss makes the per-iteration budget unreachable (survivors
/// park at their own budget waiting for a publish that needs the full
/// budget first) — so fail fast by closing the experience queue: the
/// learner's blocking collect errors loudly instead of waiting forever
/// for chunks that can never arrive. A worker that merely unwound
/// because the queue was ALREADY closed by a real failure stays silent.
struct FleetGuard<'a> {
    id: usize,
    live: Arc<AtomicUsize>,
    sync: bool,
    queue: &'a Channel<ExperienceChunk>,
    stop: &'a AtomicBool,
}

impl Drop for FleetGuard<'_> {
    fn drop(&mut self) {
        let last = self.live.fetch_sub(1, Ordering::SeqCst) == 1;
        if !self.stop.load(Ordering::Relaxed)
            && !self.queue.is_closed()
            && (last || self.sync)
        {
            crate::log_error!(
                "sampler worker {} terminated mid-run ({}); closing the experience queue",
                self.id,
                if last { "fleet empty" } else { "sync budget unreachable" }
            );
            self.queue.close();
        }
    }
}

/// One sampler worker body: build the env + policy source and run the
/// generic algorithm loop. Factored out of [`run_with`] so the spawn
/// closure can arm the [`FleetGuard`] + restart supervision around it.
/// `ctl` carries the supervision lane, the snapshot to restore (respawn
/// or resume), and any armed fault cells.
#[allow(clippy::too_many_arguments)]
fn run_sampler_worker(
    scfg: SamplerCfg,
    m: usize,
    env_name: &str,
    algo: &dyn Algorithm,
    client: Option<ActorClient>,
    factory: &dyn BackendFactory,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
    ctl: Option<&WorkerCtl>,
) -> anyhow::Result<SamplerReport> {
    let id = scfg.id;
    let venv = VecEnv::from_registry(env_name, m, scfg.seed, (id * m) as u64 + 1)?;
    let source = match client {
        Some(c) => PolicySource::Shared(c),
        None => PolicySource::Local(algo.make_local_actor(factory, m)?),
    };
    Ok(run_algo_sampler_supervised(
        algo, scfg, venv, source, store, queue, stop, ctl,
    ))
}

/// Build `algo`'s learner and drive every training iteration on the
/// calling thread, returning the final policy parameters. Factored out
/// of [`run_with`] so a learner failure can be intercepted to release
/// the worker fleet before the thread scope joins (otherwise the join
/// would wait forever on samplers that were never told to stop).
///
/// With `resume_ck` the learner restores its saved state, the policy
/// store is re-seated so `publish_initial` re-creates exactly the
/// checkpoint's version, and iteration resumes where the snapshot was
/// taken. With `cfg.checkpoint_every > 0` a durable [`Checkpoint`] is
/// written after every K-th iteration.
#[allow(clippy::too_many_arguments)]
fn run_learner(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    queue: &Channel<ExperienceChunk>,
    store: &PolicyStore,
    log: &mut MetricsLog,
    lanes: &[Arc<WorkerLane>],
    resume_ck: Option<&Checkpoint>,
    fingerprint: &RunFingerprint,
    ckpt_write_us: &mut Vec<u64>,
) -> anyhow::Result<(Vec<f32>, NormSnapshot)> {
    let mut learner = algo.make_learner(factory, cfg)?;
    let mut start_iter = 0usize;
    if let Some(ck) = resume_ck {
        learner.load_state(&ck.learner)?;
        // the restored learner's publish_initial must land at exactly the
        // checkpoint's version so chunk policy_version labels stay
        // bitwise-stable across the restart
        store.resume_at(ck.version.saturating_sub(1));
        start_iter = ck.iteration as usize;
    }
    learner.publish_initial(store);
    for iter in start_iter..cfg.iterations {
        let m = learner.iteration(iter, cfg, queue, store)?;
        log.push(m);
        if cfg.checkpoint_every != 0 && (iter + 1) % cfg.checkpoint_every == 0 {
            write_checkpoint(
                cfg,
                store,
                lanes,
                learner.as_ref(),
                fingerprint,
                (iter + 1) as u64,
                ckpt_write_us,
            )?;
        }
    }
    Ok((learner.final_params(), learner.final_norm()))
}

/// Write one durable checkpoint: wait (bounded) for every worker lane to
/// deposit a snapshot at the just-published policy version — the barrier
/// that makes the snapshot clean in sync mode (chunk buffers empty, RNG
/// cursors at a chunk boundary, nothing delivered past the deposit) —
/// then persist atomically via [`Checkpoint::write_to`]. In async mode
/// free-running workers may never align on one version; after the bounded
/// wait the freshest available snapshots are persisted best-effort
/// (resume is still valid, just not bitwise).
fn write_checkpoint(
    cfg: &TrainConfig,
    store: &PolicyStore,
    lanes: &[Arc<WorkerLane>],
    learner: &dyn LearnerDriver,
    fingerprint: &RunFingerprint,
    iteration: u64,
    ckpt_write_us: &mut Vec<u64>,
) -> anyhow::Result<()> {
    let version = store.version();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let aligned = lanes
            .iter()
            .all(|l| l.latest().map(|s| s.version == version).unwrap_or(false));
        if aligned {
            break;
        }
        if std::time::Instant::now() >= deadline {
            crate::log_warn!(
                "checkpoint barrier timed out at version {version}; persisting the \
                 freshest available worker snapshots (best-effort resume)"
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let t0 = std::time::Instant::now();
    let ck = Checkpoint {
        fingerprint: fingerprint.clone(),
        iteration,
        version,
        learner: learner.save_state(),
        workers: lanes
            .iter()
            .map(|l| l.latest().map(|s| s.to_bytes()).unwrap_or_default())
            .collect(),
    };
    let path = ck.write_to(Path::new(&cfg.checkpoint_dir))?;
    let us = t0.elapsed().as_micros() as u64;
    ckpt_write_us.push(us);
    crate::log_info!(
        "checkpoint written: {} ({us} us, version {version})",
        path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Backend};
    use crate::runtime::native_backend::NativeFactory;

    fn tiny_cfg(samplers: usize, async_mode: bool) -> TrainConfig {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.backend = Backend::Native;
        cfg.samplers = samplers;
        cfg.samples_per_iter = 600;
        cfg.iterations = 3;
        cfg.chunk_steps = 100;
        cfg.async_mode = async_mode;
        cfg.ppo.epochs = 2;
        cfg.ppo.minibatch = 128;
        cfg.hidden = vec![16, 16];
        cfg
    }

    fn factory(cfg: &TrainConfig) -> NativeFactory {
        NativeFactory::new(3, 1, &cfg.hidden, cfg.ppo.clone(), cfg.ddpg.clone())
    }

    #[test]
    fn async_run_completes_all_iterations() {
        let cfg = tiny_cfg(3, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
            assert!(m.collect_secs >= 0.0 && m.learn_secs > 0.0);
        }
        assert_eq!(r.sampler_reports.len(), 3);
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(total_steps >= 1800);
        assert_eq!(r.final_params.len(), f.ppo_param_count());
        let (pushed, popped, _, _) = r.queue_stats;
        assert!(pushed >= popped);
        // healthy run: the supervisor never fired
        assert_eq!(r.restarts, 0);
        assert_eq!(r.faults_injected, 0);
        assert!(r.checkpoint_write_us.is_empty());
    }

    #[test]
    fn sync_mode_budget_respected() {
        let cfg = tiny_cfg(2, false);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        // sync: samplers produce ~budget per version; samples per iteration
        // stay near the target (no unbounded overshoot)
        for m in &r.metrics {
            assert!(m.samples >= 600 && m.samples <= 1200, "samples {}", m.samples);
        }
    }

    #[test]
    fn vectorized_samplers_complete_all_iterations() {
        let mut cfg = tiny_cfg(2, true);
        cfg.envs_per_sampler = 4;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        // 2 workers x 4 envs stepping in lockstep: every tick adds 4
        // steps per worker, so totals are large and multiples of 4
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(total_steps >= 1800);
        for s in &r.sampler_reports {
            assert_eq!(s.steps % 4, 0, "lockstep tick must add M steps");
        }
    }

    #[test]
    fn sync_mode_terminates_when_samplers_do_not_divide_budget() {
        // 500 / 3 floors to 166 -> 3 workers would deliver 498 < 500 and
        // deadlock the learner; the ceil-divided budget must cover it
        let mut cfg = tiny_cfg(3, false);
        cfg.samples_per_iter = 500;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 500, "samples {}", m.samples);
        }
    }

    #[test]
    fn vectorized_sync_mode_respects_budget() {
        let mut cfg = tiny_cfg(2, false);
        cfg.envs_per_sampler = 2;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600 && m.samples <= 1400, "samples {}", m.samples);
        }
    }

    #[test]
    fn shared_inference_run_completes_and_reports_dispatch_stats() {
        let mut cfg = tiny_cfg(3, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        let rep = r.infer.expect("shared mode must produce an inference report");
        assert_eq!(rep.fleet_rows, 6);
        assert_eq!(rep.shards, 1, "3 workers resolve to one auto shard");
        assert!(rep.forwards > 0, "server never dispatched");
        // every sampled step went through the server exactly once: total
        // rows >= steps (bootstrap forwards add more)
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(rep.rows >= total_steps, "rows {} < steps {total_steps}", rep.rows);
        assert!(rep.mean_fill() > 0.0 && rep.mean_fill() <= 1.0 + 1e-9);
        assert_eq!(rep.forwards, rep.full_dispatches + rep.timeout_dispatches);
        // fleet-health counters ride the merged report
        assert_eq!(rep.restarts, 0);
        assert_eq!(rep.faults_injected, 0);
    }

    #[test]
    fn shared_inference_sync_mode_completes() {
        let mut cfg = tiny_cfg(2, false);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600, "samples {}", m.samples);
        }
        assert!(r.infer.is_some());
    }

    #[test]
    fn sharded_inference_run_completes_and_reports_per_shard() {
        let mut cfg = tiny_cfg(4, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        let rep = r.infer.expect("sharded run must produce a merged report");
        assert_eq!(rep.shards, 2);
        assert_eq!(rep.fleet_rows, 8, "capacities sum across shards");
        assert!(rep.forwards > 0);
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(rep.rows >= total_steps);
    }

    #[test]
    fn adaptive_wait_shared_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Adaptive; // the default, stated explicitly
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        let rep = r.infer.unwrap();
        assert!(rep.forwards > 0);
        // steady state must stop allocating on the slab transport path:
        // warmup is bounded by a small constant per client + shard
        assert!(
            rep.hot_allocs < 200,
            "hot-path allocations kept growing: {}",
            rep.hot_allocs
        );
    }

    #[test]
    fn local_mode_reports_no_inference_stats() {
        let cfg = tiny_cfg(1, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert!(r.infer.is_none());
    }

    #[test]
    fn shared_inference_ddpg_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.algo = Algo::Ddpg;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.samples_per_iter = 300;
        cfg.ddpg.warmup_steps = 100;
        cfg.ddpg.batch = 32;
        cfg.ddpg.updates_per_iter = 10;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert!(r.infer.unwrap().forwards > 0);
    }

    #[test]
    fn single_sampler_equals_baseline_shape() {
        // N = 1 is the paper's baseline configuration; must work identically
        let cfg = tiny_cfg(1, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert_eq!(r.sampler_reports.len(), 1);
    }

    #[test]
    fn ddpg_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.algo = Algo::Ddpg;
        cfg.samples_per_iter = 300;
        cfg.ddpg.warmup_steps = 100;
        cfg.ddpg.batch = 32;
        cfg.ddpg.updates_per_iter = 10;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        // final params are the DDPG actor
        let actor_len = crate::nn::layout::actor_layout(3, 1, &cfg.hidden).total();
        assert_eq!(r.final_params.len(), actor_len);
    }

    #[test]
    fn unknown_env_fails_fast() {
        let mut cfg = tiny_cfg(1, true);
        cfg.env = "mujoco".into();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        assert!(run(&cfg, &f, &mut log).is_err());
    }

    #[test]
    fn shard_epoch_escape_hatch_completes_without_gate() {
        let mut cfg = tiny_cfg(4, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        cfg.infer_epoch = crate::config::InferEpoch::Shard;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        let rep = r.infer.expect("shared run must carry a report");
        assert_eq!(rep.shards, 2);
        // gateless shards never park at a flip barrier
        assert_eq!(rep.flip_stall_us.count(), 0);
        // but observation staleness is still recorded per dispatch
        assert_eq!(rep.epoch_lag.count(), rep.forwards);
    }

    /// With the restart budget disabled (`max_restarts = 0`) a forced
    /// serve-thread panic at S=2 terminates the run with a logged error —
    /// the PR 4 fail-fast contract: the dead shard's workers unwind
    /// instead of deadlocking on their completion slots, and the
    /// orchestrator surfaces the dead shard as a run error.
    #[test]
    fn shard_panic_terminates_run_instead_of_deadlocking() {
        use crate::runtime::test_support::PanickingSharedFactory;

        let mut cfg = tiny_cfg(4, true);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        cfg.max_restarts = 0; // fail fast, no supervision
        // the first shard to build its shared actor dies after 25 forwards
        let f = PanickingSharedFactory::new(factory(&cfg), 25);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err(), "run must terminate with an error, not hang");
    }

    /// Sync-mode variant of the shard-panic fail-fast test: with half
    /// the fleet dead and no restart budget the per-iteration budget is
    /// unreachable, so the surviving workers' budget barrier + the
    /// learner's blocking collect would deadlock forever — any mid-run
    /// worker death in sync mode must close the queue and fail the run.
    #[test]
    fn shard_panic_terminates_sync_run_instead_of_deadlocking() {
        use crate::runtime::test_support::PanickingSharedFactory;

        let mut cfg = tiny_cfg(4, false); // sync barrier mode
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        cfg.max_restarts = 0; // fail fast, no supervision
        let f = PanickingSharedFactory::new(factory(&cfg), 25);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err(), "sync run must fail loudly, not deadlock");
    }

    /// Tentpole acceptance (shard leg): with the default restart budget
    /// the SAME one-poisoned-shard scenario now self-heals — the
    /// supervisor respawns the serve thread, serve_algo revives the
    /// shard (epoch rejoin + fresh healthy actor), the re-homed workers'
    /// retried requests go through, and the run completes.
    #[test]
    fn shard_panic_respawns_and_run_completes() {
        use crate::runtime::test_support::PanickingSharedFactory;

        let mut cfg = tiny_cfg(4, true);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        let f = PanickingSharedFactory::new(factory(&cfg), 25);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert!(r.restarts >= 1, "the supervisor must have respawned the shard");
        let rep = r.infer.expect("shared run must carry a report");
        assert_eq!(rep.restarts, r.restarts);
    }

    /// Tentpole acceptance (worker leg): a scripted worker kill mid-run
    /// is healed by the supervisor — the worker respawns from its lane
    /// snapshot with its original RNG lanes and the run completes, with
    /// the restart and fault counters reflecting exactly the plan.
    #[test]
    fn scripted_worker_fault_respawns_and_run_completes() {
        let mut cfg = tiny_cfg(3, true);
        cfg.fault_inject = "worker:1@tick:50".into();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert_eq!(r.faults_injected, 1, "the armed cell must have fired");
        assert_eq!(r.restarts, 1, "one kill, one respawn");
        assert_eq!(r.sampler_reports.len(), 3);
    }

    /// A worker that keeps dying past its restart budget aborts the
    /// fleet cleanly (run error, no hang) instead of looping forever.
    #[test]
    fn restart_budget_exhaustion_fails_the_run() {
        let mut cfg = tiny_cfg(2, true);
        cfg.max_restarts = 1;
        cfg.fault_inject = "worker:0@tick:20,worker:0@tick:40,worker:0@tick:60".into();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err(), "budget exhaustion must fail the run");
    }

    /// Sync-mode checkpointing writes one durable snapshot per iteration
    /// at the version barrier, and a resumed run continues to the same
    /// final parameters bitwise (the learner state + every worker RNG
    /// cursor survived the round trip).
    #[test]
    fn checkpoint_then_resume_reproduces_final_params() {
        let dir = std::env::temp_dir().join("walle_orch_resume_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tiny_cfg(2, false);
        cfg.checkpoint_every = 1;
        cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
        let f = factory(&cfg);

        // uninterrupted reference run
        let mut log = MetricsLog::quiet();
        let full = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(full.checkpoint_write_us.len(), 3);

        // killed-after-iteration-2 run: simulate by resuming from the
        // second checkpoint (delete the last one so load_latest picks it)
        std::fs::remove_file(dir.join("ckpt-000003.bin")).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.resume = cfg.checkpoint_dir.clone();
        cfg2.checkpoint_every = 0;
        let mut log2 = MetricsLog::quiet();
        let resumed = run(&cfg2, &f, &mut log2).unwrap();
        assert_eq!(resumed.metrics.len(), 1, "only the final iteration reruns");
        assert_eq!(
            resumed.final_params, full.final_params,
            "resumed run must reproduce the reference parameters bitwise"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Resume refuses a checkpoint whose fingerprint does not match the
    /// live config (different seed here) — restoring RNG cursors under a
    /// different identity would silently corrupt every stream.
    #[test]
    fn resume_rejects_fingerprint_mismatch() {
        let dir = std::env::temp_dir().join("walle_orch_fingerprint_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tiny_cfg(1, false);
        cfg.iterations = 1;
        cfg.checkpoint_every = 1;
        cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        run(&cfg, &f, &mut log).unwrap();

        let mut cfg2 = cfg.clone();
        cfg2.resume = cfg.checkpoint_dir.clone();
        cfg2.checkpoint_every = 0;
        cfg2.seed = cfg.seed + 1;
        let mut log2 = MetricsLog::quiet();
        let r = run(&cfg2, &f, &mut log2);
        assert!(r.is_err(), "fingerprint mismatch must abort resume");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A fault plan that targets a shard in local mode (no shards exist)
    /// is rejected at startup, not discovered mid-run.
    #[test]
    fn shard_fault_plan_rejected_in_local_mode() {
        let mut cfg = tiny_cfg(1, true);
        cfg.fault_inject = "shard:0@dispatch:10".into();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err());
    }
}
