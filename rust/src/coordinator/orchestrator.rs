//! Orchestrator: process topology and lifecycle for one training run —
//! spawns the N sampler workers (each driving `envs_per_sampler`
//! vectorized envs in lockstep), the learner, and — under
//! `--inference-mode shared` — the S inference-pool shard threads, each
//! owning a fleet-slice actor; wires the experience queue, policy store,
//! and inference request queues between them, runs the iteration loop,
//! and shuts everything down cleanly (the WALL-E launcher in Fig 2).
//!
//! Everything algorithm-specific is reached through ONE
//! [`Algorithm`] trait object: sampler hooks, local/shared policy
//! backends, and the learner driver. [`run`] resolves the trait object
//! from `cfg.algo` via the registry
//! (`algo::api::algorithm_from_config`); `session::Session` calls
//! [`run_with`] with the instance its builder carries. Either way, this
//! module never matches on a concrete algorithm — adding one touches
//! the registry, not the topology.

use crate::algo::api::{algorithm_from_config, Algorithm};
use crate::algo::normalizer::NormSnapshot;
use crate::algo::rollout::ExperienceChunk;
use crate::config::{InferEpoch, InferWait, InferenceMode, TrainConfig};
use crate::coordinator::metrics::{InferenceReport, IterationMetrics, MetricsLog};
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::{run_algo_sampler, PolicySource, SamplerCfg, SamplerReport};
use crate::env::registry::make_env;
use crate::env::vec_env::VecEnv;
use crate::runtime::epoch::EpochMode;
use crate::runtime::inference_server::{
    ActorClient, InferencePool, InferencePoolCfg, WaitPolicy,
};
use crate::runtime::BackendFactory;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Outcome of one full run.
pub struct RunResult {
    pub metrics: Vec<IterationMetrics>,
    pub sampler_reports: Vec<SamplerReport>,
    /// Final policy parameters (PPO flat vector, or the DDPG/TD3 actor).
    pub final_params: Vec<f32>,
    /// The observation-normalizer snapshot published with the final
    /// params — pass it to `Session::evaluate_with_norm` (or
    /// `eval::evaluate`) so evaluation applies the SAME input transform
    /// training did. Checkpoint files carry only the parameters.
    pub final_norm: NormSnapshot,
    /// (pushed, popped, producer blocked, consumer blocked).
    pub queue_stats: (u64, u64, Duration, Duration),
    /// Dispatch statistics of the shared inference server
    /// (`--inference-mode shared` only).
    pub infer: Option<InferenceReport>,
}

/// Run a full training session per `cfg`, reporting into `log`.
///
/// Callers choose the backend by passing the matching factory
/// (`NativeFactory` or `XlaFactory`); sampler threads each build their own
/// thread-local backend through it. The algorithm is resolved from
/// `cfg.algo` through the registry; use [`run_with`] to supply an
/// [`Algorithm`] instance directly (the `Session` path).
pub fn run(
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
) -> anyhow::Result<RunResult> {
    let algo = algorithm_from_config(cfg);
    run_with(algo.as_ref(), cfg, factory, log)
}

/// [`run`] with an explicit [`Algorithm`] instance. `cfg` remains the
/// source of truth for every hyper-parameter the learner reads per
/// iteration; `algo` must agree with `cfg.algo` (the `Session` builder
/// guarantees this by construction via `Algorithm::apply_to`).
pub fn run_with(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    log: &mut MetricsLog,
) -> anyhow::Result<RunResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    algo.validate(cfg).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        make_env(&cfg.env).is_some(),
        "unknown env {:?} (known: {:?})",
        cfg.env,
        crate::env::registry::ENV_NAMES
    );
    // Kernel mode is process-global: every thread this run spawns
    // (samplers, shards, learner) must agree on exact-vs-fast before the
    // first forward pass.
    crate::nn::kernels::set_mode(cfg.kernels.mode());

    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    if cfg.infer_precision == crate::config::InferPrecision::Int8 {
        let q = algo.quantizer(factory, cfg).ok_or_else(|| {
            anyhow::anyhow!(
                "--infer-precision int8 is not supported by algorithm {:?}",
                cfg.algo
            )
        })?;
        store.set_quantizer(q);
    }
    let stop = AtomicBool::new(false);
    let sync_budget = if cfg.async_mode {
        None
    } else {
        // ceil-divide: workers cut at their budget within M-1 samples, so
        // a floor here would undershoot the iteration total whenever
        // samplers does not divide samples_per_iter and deadlock the
        // learner's blocking collect against blocked samplers.
        Some((cfg.samples_per_iter + cfg.samplers - 1) / cfg.samplers)
    };

    let mut result: Option<RunResult> = None;

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // ---- sharded inference pool (one per run, optional) -----------
        // Clients are registered BEFORE any serve thread starts so no
        // shard can observe an empty fleet and exit early; each shard
        // thread builds its own fleet-slice backend on itself (PJRT is
        // not Send) and runs until every one of its workers has dropped
        // its handle.
        let m = cfg.envs_per_sampler;
        let pool = match cfg.inference_mode {
            InferenceMode::Local => None,
            InferenceMode::Shared => Some(Arc::new(InferencePool::new(InferencePoolCfg {
                workers: cfg.samplers,
                rows_per_worker: m,
                shards: cfg.infer_shards.resolve(cfg.samplers),
                wait: match cfg.infer_wait {
                    InferWait::Adaptive => WaitPolicy::Adaptive,
                    InferWait::Fixed(us) => WaitPolicy::Fixed(Duration::from_micros(us)),
                },
                epoch: match cfg.infer_epoch {
                    InferEpoch::Pool => EpochMode::Pool,
                    InferEpoch::Shard => EpochMode::Shard,
                },
                obs_dim: factory.obs_dim(),
                act_dim: factory.act_dim(),
            }))),
        };
        let mut clients: Vec<_> = (0..cfg.samplers)
            .map(|id| pool.as_ref().map(|p| p.client(id)))
            .collect();
        let server_handles: Vec<_> = pool
            .as_ref()
            .map(|p| {
                p.shards()
                    .iter()
                    .map(|shard| {
                        let shard = shard.clone();
                        let store = &store;
                        scope.spawn(move || shard.serve_algo(algo, factory, store))
                    })
                    .collect()
            })
            .unwrap_or_default();

        // ---- sampler workers ------------------------------------------
        // Each worker drives `envs_per_sampler` envs in lockstep; env
        // dynamics streams are numbered globally (worker id * M + slot,
        // offset by 1), so a trajectory is pinned to its global slot
        // regardless of how envs are packed onto workers.
        let live_samplers = Arc::new(AtomicUsize::new(cfg.samplers));
        let mut handles = Vec::new();
        for id in 0..cfg.samplers {
            let scfg = SamplerCfg {
                id,
                seed: cfg.seed,
                chunk_steps: cfg.chunk_steps,
                sync_budget,
                reward_scale: cfg.reward_scale,
            };
            let queue = &queue;
            let store = &store;
            let stop = &stop;
            let env_name = cfg.env.clone();
            let client = clients[id].take();
            let live = live_samplers.clone();
            handles.push(scope.spawn(move || -> anyhow::Result<SamplerReport> {
                // drop guard, NOT ordinary post-code: a worker that
                // panics (instead of returning an error) must still
                // decrement the live count and trip the queue close, or
                // the learner would inherit the very hang this PR closes
                let _guard = FleetGuard {
                    id,
                    live,
                    sync: sync_budget.is_some(),
                    queue,
                    stop,
                };
                run_sampler_worker(
                    scfg, m, &env_name, algo, client, factory, store, queue, stop,
                )
            }));
        }

        // ---- learner (this thread) -------------------------------------
        let (final_params, final_norm) = match run_learner(algo, cfg, factory, &queue, &store, log)
        {
            Ok(p) => p,
            Err(e) => {
                // A learner failure must still release the samplers and
                // inference shards before propagating — the scope join
                // below would otherwise wait forever on workers that were
                // never told to stop (the hang class this PR closes).
                stop.store(true, Ordering::Relaxed);
                queue.close();
                // Join the scoped threads ourselves, discarding their
                // results: leaving a panicked serve thread to the scope's
                // implicit join would re-raise the panic and turn this
                // reported error into a process abort.
                for h in handles {
                    let _ = h.join();
                }
                for h in server_handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        };

        // ---- shutdown ---------------------------------------------------
        stop.store(true, Ordering::Relaxed);
        queue.close();
        // publish once more so sync-mode samplers blocked on wait_newer wake
        store.publish(final_params.clone(), final_norm.clone());
        // Join EVERY scoped thread before surfacing the first failure:
        // early-returning on the first bad join would leave later
        // panicked threads to the scope's implicit join, which re-raises
        // their panic and turns a reportable error into a process abort.
        let mut reports = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(report)) => reports.push(report),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow::anyhow!("sampler panicked"));
                }
            }
        }
        // each shard's serve loop exits once all ITS workers drop their
        // client handles
        for h in server_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err
                        .get_or_insert_with(|| anyhow::anyhow!("inference shard panicked"));
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        result = Some(RunResult {
            metrics: log.iterations.clone(),
            sampler_reports: reports,
            final_params,
            final_norm,
            queue_stats: (
                queue.stats.pushed(),
                queue.stats.popped(),
                queue.stats.push_blocked(),
                queue.stats.pop_blocked(),
            ),
            infer: pool.map(|p| p.report()),
        });
        Ok(())
    })?;

    Ok(result.expect("run result set"))
}

/// Worker-exit supervision, armed as a drop guard so it fires on panics
/// too. A worker exiting before shutdown died on an error: the async
/// fleet can absorb losses until the LAST worker is gone, but in sync
/// mode ANY loss makes the per-iteration budget unreachable (survivors
/// park at their own budget waiting for a publish that needs the full
/// budget first) — so fail fast by closing the experience queue: the
/// learner's blocking collect errors loudly instead of waiting forever
/// for chunks that can never arrive. A worker that merely unwound
/// because the queue was ALREADY closed by a real failure stays silent.
struct FleetGuard<'a> {
    id: usize,
    live: Arc<AtomicUsize>,
    sync: bool,
    queue: &'a Channel<ExperienceChunk>,
    stop: &'a AtomicBool,
}

impl Drop for FleetGuard<'_> {
    fn drop(&mut self) {
        let last = self.live.fetch_sub(1, Ordering::SeqCst) == 1;
        if !self.stop.load(Ordering::Relaxed)
            && !self.queue.is_closed()
            && (last || self.sync)
        {
            crate::log_error!(
                "sampler worker {} terminated mid-run ({}); closing the experience queue",
                self.id,
                if last { "fleet empty" } else { "sync budget unreachable" }
            );
            self.queue.close();
        }
    }
}

/// One sampler worker body: build the env + policy source and run the
/// generic algorithm loop. Factored out of [`run_with`] so the spawn
/// closure can arm the [`FleetGuard`] supervision around it.
#[allow(clippy::too_many_arguments)]
fn run_sampler_worker(
    scfg: SamplerCfg,
    m: usize,
    env_name: &str,
    algo: &dyn Algorithm,
    client: Option<ActorClient>,
    factory: &dyn BackendFactory,
    store: &PolicyStore,
    queue: &Channel<ExperienceChunk>,
    stop: &AtomicBool,
) -> anyhow::Result<SamplerReport> {
    let id = scfg.id;
    let venv = VecEnv::from_registry(env_name, m, scfg.seed, (id * m) as u64 + 1)?;
    let source = match client {
        Some(c) => PolicySource::Shared(c),
        None => PolicySource::Local(algo.make_local_actor(factory, m)?),
    };
    Ok(run_algo_sampler(algo, scfg, venv, source, store, queue, stop))
}

/// Build `algo`'s learner and drive every training iteration on the
/// calling thread, returning the final policy parameters. Factored out
/// of [`run_with`] so a learner failure can be intercepted to release
/// the worker fleet before the thread scope joins (otherwise the join
/// would wait forever on samplers that were never told to stop).
fn run_learner(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    queue: &Channel<ExperienceChunk>,
    store: &PolicyStore,
    log: &mut MetricsLog,
) -> anyhow::Result<(Vec<f32>, NormSnapshot)> {
    let mut learner = algo.make_learner(factory, cfg)?;
    learner.publish_initial(store);
    for iter in 0..cfg.iterations {
        let m = learner.iteration(iter, cfg, queue, store)?;
        log.push(m);
    }
    Ok((learner.final_params(), learner.final_norm()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Backend};
    use crate::runtime::native_backend::NativeFactory;

    fn tiny_cfg(samplers: usize, async_mode: bool) -> TrainConfig {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.backend = Backend::Native;
        cfg.samplers = samplers;
        cfg.samples_per_iter = 600;
        cfg.iterations = 3;
        cfg.chunk_steps = 100;
        cfg.async_mode = async_mode;
        cfg.ppo.epochs = 2;
        cfg.ppo.minibatch = 128;
        cfg.hidden = vec![16, 16];
        cfg
    }

    fn factory(cfg: &TrainConfig) -> NativeFactory {
        NativeFactory::new(3, 1, &cfg.hidden, cfg.ppo.clone(), cfg.ddpg.clone())
    }

    #[test]
    fn async_run_completes_all_iterations() {
        let cfg = tiny_cfg(3, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
            assert!(m.collect_secs >= 0.0 && m.learn_secs > 0.0);
        }
        assert_eq!(r.sampler_reports.len(), 3);
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(total_steps >= 1800);
        assert_eq!(r.final_params.len(), f.ppo_param_count());
        let (pushed, popped, _, _) = r.queue_stats;
        assert!(pushed >= popped);
    }

    #[test]
    fn sync_mode_budget_respected() {
        let cfg = tiny_cfg(2, false);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        // sync: samplers produce ~budget per version; samples per iteration
        // stay near the target (no unbounded overshoot)
        for m in &r.metrics {
            assert!(m.samples >= 600 && m.samples <= 1200, "samples {}", m.samples);
        }
    }

    #[test]
    fn vectorized_samplers_complete_all_iterations() {
        let mut cfg = tiny_cfg(2, true);
        cfg.envs_per_sampler = 4;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        // 2 workers x 4 envs stepping in lockstep: every tick adds 4
        // steps per worker, so totals are large and multiples of 4
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(total_steps >= 1800);
        for s in &r.sampler_reports {
            assert_eq!(s.steps % 4, 0, "lockstep tick must add M steps");
        }
    }

    #[test]
    fn sync_mode_terminates_when_samplers_do_not_divide_budget() {
        // 500 / 3 floors to 166 -> 3 workers would deliver 498 < 500 and
        // deadlock the learner; the ceil-divided budget must cover it
        let mut cfg = tiny_cfg(3, false);
        cfg.samples_per_iter = 500;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 500, "samples {}", m.samples);
        }
    }

    #[test]
    fn vectorized_sync_mode_respects_budget() {
        let mut cfg = tiny_cfg(2, false);
        cfg.envs_per_sampler = 2;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600 && m.samples <= 1400, "samples {}", m.samples);
        }
    }

    #[test]
    fn shared_inference_run_completes_and_reports_dispatch_stats() {
        let mut cfg = tiny_cfg(3, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        let rep = r.infer.expect("shared mode must produce an inference report");
        assert_eq!(rep.fleet_rows, 6);
        assert_eq!(rep.shards, 1, "3 workers resolve to one auto shard");
        assert!(rep.forwards > 0, "server never dispatched");
        // every sampled step went through the server exactly once: total
        // rows >= steps (bootstrap forwards add more)
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(rep.rows >= total_steps, "rows {} < steps {total_steps}", rep.rows);
        assert!(rep.mean_fill() > 0.0 && rep.mean_fill() <= 1.0 + 1e-9);
        assert_eq!(rep.forwards, rep.full_dispatches + rep.timeout_dispatches);
    }

    #[test]
    fn shared_inference_sync_mode_completes() {
        let mut cfg = tiny_cfg(2, false);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600, "samples {}", m.samples);
        }
        assert!(r.infer.is_some());
    }

    #[test]
    fn sharded_inference_run_completes_and_reports_per_shard() {
        let mut cfg = tiny_cfg(4, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
        }
        let rep = r.infer.expect("sharded run must produce a merged report");
        assert_eq!(rep.shards, 2);
        assert_eq!(rep.fleet_rows, 8, "capacities sum across shards");
        assert!(rep.forwards > 0);
        let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
        assert!(rep.rows >= total_steps);
    }

    #[test]
    fn adaptive_wait_shared_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Adaptive; // the default, stated explicitly
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        let rep = r.infer.unwrap();
        assert!(rep.forwards > 0);
        // steady state must stop allocating on the slab transport path:
        // warmup is bounded by a small constant per client + shard
        assert!(
            rep.hot_allocs < 200,
            "hot-path allocations kept growing: {}",
            rep.hot_allocs
        );
    }

    #[test]
    fn local_mode_reports_no_inference_stats() {
        let cfg = tiny_cfg(1, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert!(r.infer.is_none());
    }

    #[test]
    fn shared_inference_ddpg_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.algo = Algo::Ddpg;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.samples_per_iter = 300;
        cfg.ddpg.warmup_steps = 100;
        cfg.ddpg.batch = 32;
        cfg.ddpg.updates_per_iter = 10;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert!(r.infer.unwrap().forwards > 0);
    }

    #[test]
    fn single_sampler_equals_baseline_shape() {
        // N = 1 is the paper's baseline configuration; must work identically
        let cfg = tiny_cfg(1, true);
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert_eq!(r.sampler_reports.len(), 1);
    }

    #[test]
    fn ddpg_run_completes() {
        let mut cfg = tiny_cfg(2, true);
        cfg.algo = Algo::Ddpg;
        cfg.samples_per_iter = 300;
        cfg.ddpg.warmup_steps = 100;
        cfg.ddpg.batch = 32;
        cfg.ddpg.updates_per_iter = 10;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        // final params are the DDPG actor
        let actor_len = crate::nn::layout::actor_layout(3, 1, &cfg.hidden).total();
        assert_eq!(r.final_params.len(), actor_len);
    }

    #[test]
    fn unknown_env_fails_fast() {
        let mut cfg = tiny_cfg(1, true);
        cfg.env = "mujoco".into();
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        assert!(run(&cfg, &f, &mut log).is_err());
    }

    #[test]
    fn shard_epoch_escape_hatch_completes_without_gate() {
        let mut cfg = tiny_cfg(4, true);
        cfg.envs_per_sampler = 2;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        cfg.infer_epoch = crate::config::InferEpoch::Shard;
        let f = factory(&cfg);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        let rep = r.infer.expect("shared run must carry a report");
        assert_eq!(rep.shards, 2);
        // gateless shards never park at a flip barrier
        assert_eq!(rep.flip_stall_us.count(), 0);
        // but observation staleness is still recorded per dispatch
        assert_eq!(rep.epoch_lag.count(), rep.forwards);
    }

    /// Acceptance criterion: a forced serve-thread panic at S=2
    /// terminates the run with a logged error — the dead shard's workers
    /// unwind instead of deadlocking on their completion slots, the
    /// surviving shard keeps feeding the learner to completion, and the
    /// orchestrator surfaces the dead shard as a run error.
    #[test]
    fn shard_panic_terminates_run_instead_of_deadlocking() {
        use crate::runtime::test_support::PanickingSharedFactory;

        let mut cfg = tiny_cfg(4, true);
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        // the first shard to build its shared actor dies after 25 forwards
        let f = PanickingSharedFactory::new(factory(&cfg), 25);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err(), "run must terminate with an error, not hang");
    }

    /// Sync-mode variant of the shard-panic acceptance test: with half
    /// the fleet dead the per-iteration budget is unreachable, so the
    /// surviving workers' budget barrier + the learner's blocking collect
    /// would deadlock forever — any mid-run worker death in sync mode
    /// must close the queue and fail the run instead.
    #[test]
    fn shard_panic_terminates_sync_run_instead_of_deadlocking() {
        use crate::runtime::test_support::PanickingSharedFactory;

        let mut cfg = tiny_cfg(4, false); // sync barrier mode
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = crate::config::InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(500);
        let f = PanickingSharedFactory::new(factory(&cfg), 25);
        let mut log = MetricsLog::quiet();
        let r = run(&cfg, &f, &mut log);
        assert!(r.is_err(), "sync run must fail loudly, not deadlock");
    }
}
