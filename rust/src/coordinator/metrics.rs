//! Per-iteration metrics: the numbers behind every figure in the paper —
//! collection (rollout) time, learning time, their fractions (Figs 4, 6,
//! 7), and average return (Fig 3). Collected by the learner, logged to
//! stdout, and written as CSV/JSON for the bench harness.
//!
//! Also home to the shared-inference instrumentation: a fixed-bucket
//! [`Histogram`] and the [`InferenceReport`] the inference server fills
//! with dispatch-size, batch-fill-ratio and queue-wait distributions,
//! surfaced in the end-of-run report.

use crate::util::json::Json;
use std::io::Write;

/// Fixed-bucket histogram (upper-edge buckets plus an overflow bucket).
/// Cheap enough to update once per inference dispatch / request.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive upper edges, ascending; values above the last edge land
    /// in the overflow bucket.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (bucket-wise). Both must use
    /// identical bounds — the inference pool guarantees this by sizing
    /// every shard's report with the same bucket edges.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "cannot merge histograms with different bucket bounds"
        );
        for (c, oc) in self.counts.iter_mut().zip(&other.counts) {
            *c += oc;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// (upper_edge, count) pairs; the final entry is (+inf, overflow).
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
            .collect()
    }

    /// One-line summary: `n=.. mean=.. min=.. max=.. | <=1:3 <=4:10 inf:0`.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "n={} mean={:.2} min={:.2} max={:.2} |",
            self.count,
            self.mean(),
            self.min(),
            self.max()
        );
        for (edge, n) in self.buckets() {
            if edge.is_finite() {
                s.push_str(&format!(" <={edge:.0}:{n}"));
            } else {
                s.push_str(&format!(" inf:{n}"));
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", Json::Num(self.mean())),
            ("min", Json::Num(self.min())),
            ("max", Json::Num(self.max())),
            (
                "buckets",
                Json::Arr(
                    self.buckets()
                        .into_iter()
                        .map(|(edge, n)| {
                            Json::obj(vec![
                                (
                                    "le",
                                    if edge.is_finite() {
                                        Json::Num(edge)
                                    } else {
                                        Json::Str("inf".into())
                                    },
                                ),
                                ("count", Json::Num(n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// End-of-run statistics from the shared inference pool (`--inference-mode
/// shared`): how well cross-worker coalescing filled the mega-batch. One
/// report per shard at collection time; [`InferenceReport::merge`] folds
/// them into the pool-wide report surfaced to the user (so `fleet_rows`
/// sums to N*M and `shards` counts the pool size).
#[derive(Debug, Clone)]
pub struct InferenceReport {
    /// Total batched forwards the server executed.
    pub forwards: u64,
    /// Total real rows served across all forwards.
    pub rows: u64,
    /// Row capacity: this shard's workers x M envs (after merging: the
    /// whole fleet, N x M).
    pub fleet_rows: usize,
    /// Number of shard reports folded into this one (1 for a single
    /// shard's own report).
    pub shards: usize,
    /// Hot-path buffer-growth events (slab transport, client + server
    /// side). Flat after warmup == zero allocations per steady-state tick;
    /// see `runtime::inference_server`.
    pub hot_allocs: u64,
    /// Dispatches that went out with every active worker's slab on board.
    pub full_dispatches: u64,
    /// Partial dispatches forced by the straggler cut (`--infer-wait`).
    pub timeout_dispatches: u64,
    /// Real rows per dispatch.
    pub dispatch_rows: Histogram,
    /// rows / shard capacity per dispatch (1.0 = perfectly coalesced).
    pub fill_ratio: Histogram,
    /// Per-request microseconds between submit and dispatch.
    pub queue_wait_us: Histogram,
    /// Straggler-cut budget (microseconds) in effect at each timeout
    /// dispatch — shows what the adaptive policy converged to (constant
    /// under `--infer-wait fixed:<us>`).
    pub cut_us: Histogram,
    /// Per dispatch: how many versions the served snapshot lagged the
    /// newest publish (0 = fresh). Under `--infer-epoch pool` a non-zero
    /// entry means a publish was parked behind the flip barrier for that
    /// dispatch; under `--infer-epoch shard` it is raw observation
    /// staleness.
    pub epoch_lag: Histogram,
    /// Microseconds a shard spent parked at the pool epoch barrier while
    /// waiting for its peers to drain (recorded only on acquires that
    /// actually stalled; empty in `--infer-epoch shard` mode). Bounded
    /// per flip by one straggler-cut window, or the serve loop's ~5ms
    /// idle poll when a peer shard happens to be idle.
    pub flip_stall_us: Histogram,
    /// Supervisor respawns across the whole fleet (sampler workers +
    /// inference shards). 0 on a healthy run.
    pub restarts: u64,
    /// Scripted fault cells (`--fault-inject`) that actually fired.
    pub faults_injected: u64,
    /// Wall microseconds per durable checkpoint write
    /// (`--checkpoint-every`; empty when checkpointing is off).
    pub checkpoint_write_us: Histogram,
    /// Daemon wire traffic (`walle serve` / `--fleet-mode procs`):
    /// frames received from remote clients (act requests, chunk pushes,
    /// version long-polls). All-zero wire counters mean no daemon was
    /// involved and the render omits the wire lines entirely.
    pub wire_frames_in: u64,
    /// Frames sent to remote clients (act responses, version pushes,
    /// handshake replies).
    pub wire_frames_out: u64,
    /// Bytes received over daemon sockets (length prefixes included).
    pub wire_bytes_in: u64,
    /// Bytes sent over daemon sockets.
    pub wire_bytes_out: u64,
    /// Completed client handshakes (actor + subscriber connections).
    pub wire_handshakes: u64,
    /// Remote-client disconnects: clean EOFs and mid-frame failures
    /// alike (a SIGKILLed sampler child shows up here).
    pub wire_disconnects: u64,
    /// Per-frame wire size in bytes, both directions.
    pub wire_frame_bytes: Histogram,
}

/// Bucket bounds for [`InferenceReport::wire_frame_bytes`]. The daemon's
/// live wire counters build their histogram from the SAME bounds so the
/// end-of-run merge (which asserts equal bucket edges) always succeeds.
pub const WIRE_FRAME_BYTE_BOUNDS: &[f64] =
    &[64.0, 256.0, 1024.0, 4096.0, 16_384.0, 65_536.0, 1_048_576.0];

impl InferenceReport {
    pub fn new(fleet_rows: usize) -> InferenceReport {
        Self::with_bounds(fleet_rows, fleet_rows)
    }

    /// Report for a shard of capacity `fleet_rows`, with dispatch-size
    /// buckets derived from `bounds_rows` (the max shard capacity
    /// pool-wide) so reports from unevenly-sized shards stay mergeable.
    pub fn with_bounds(fleet_rows: usize, bounds_rows: usize) -> InferenceReport {
        let f = bounds_rows as f64;
        InferenceReport {
            forwards: 0,
            rows: 0,
            fleet_rows,
            shards: 1,
            hot_allocs: 0,
            full_dispatches: 0,
            timeout_dispatches: 0,
            dispatch_rows: Histogram::new(&[
                1.0,
                (f / 8.0).max(2.0),
                (f / 4.0).max(3.0),
                (f / 2.0).max(4.0),
                f.max(5.0),
            ]),
            fill_ratio: Histogram::new(&[0.125, 0.25, 0.5, 0.75, 0.9, 1.0]),
            queue_wait_us: Histogram::new(&[10.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 5000.0]),
            cut_us: Histogram::new(&[10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 10_000.0]),
            epoch_lag: Histogram::new(&[0.0, 1.0, 2.0, 4.0, 8.0, 16.0]),
            flip_stall_us: Histogram::new(&[
                10.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 5000.0, 10_000.0,
            ]),
            restarts: 0,
            faults_injected: 0,
            checkpoint_write_us: Histogram::new(&[
                100.0, 250.0, 500.0, 1000.0, 2500.0, 10_000.0, 50_000.0, 250_000.0,
            ]),
            wire_frames_in: 0,
            wire_frames_out: 0,
            wire_bytes_in: 0,
            wire_bytes_out: 0,
            wire_handshakes: 0,
            wire_disconnects: 0,
            wire_frame_bytes: Histogram::new(WIRE_FRAME_BYTE_BOUNDS),
        }
    }

    /// Fold another shard's report into this one (capacities sum, shard
    /// count accumulates, histograms merge bucket-wise).
    pub fn merge(&mut self, other: &InferenceReport) {
        self.forwards += other.forwards;
        self.rows += other.rows;
        self.fleet_rows += other.fleet_rows;
        self.shards += other.shards;
        self.hot_allocs += other.hot_allocs;
        self.full_dispatches += other.full_dispatches;
        self.timeout_dispatches += other.timeout_dispatches;
        self.dispatch_rows.merge(&other.dispatch_rows);
        self.fill_ratio.merge(&other.fill_ratio);
        self.queue_wait_us.merge(&other.queue_wait_us);
        self.cut_us.merge(&other.cut_us);
        self.epoch_lag.merge(&other.epoch_lag);
        self.flip_stall_us.merge(&other.flip_stall_us);
        self.restarts += other.restarts;
        self.faults_injected += other.faults_injected;
        self.checkpoint_write_us.merge(&other.checkpoint_write_us);
        self.wire_frames_in += other.wire_frames_in;
        self.wire_frames_out += other.wire_frames_out;
        self.wire_bytes_in += other.wire_bytes_in;
        self.wire_bytes_out += other.wire_bytes_out;
        self.wire_handshakes += other.wire_handshakes;
        self.wire_disconnects += other.wire_disconnects;
        self.wire_frame_bytes.merge(&other.wire_frame_bytes);
    }

    /// Mean fraction of the shard batch filled per forward.
    pub fn mean_fill(&self) -> f64 {
        self.fill_ratio.mean()
    }

    /// Mean real rows per forward.
    pub fn mean_dispatch_rows(&self) -> f64 {
        self.dispatch_rows.mean()
    }

    /// Whether any daemon wire traffic was recorded (all-zero counters
    /// mean the run never crossed a process boundary).
    pub fn has_wire_traffic(&self) -> bool {
        self.wire_frames_in + self.wire_frames_out + self.wire_handshakes + self.wire_disconnects
            > 0
    }

    /// Multi-line end-of-run report block.
    pub fn render(&self) -> String {
        let mut s = format!(
            "shared inference: {} forwards, {} rows ({} fleet rows, {} shard{}), \
             {} full / {} timeout cuts, mean fill {:.1}%, {} hot-path allocs\n\
             dispatch rows: {}\n\
             batch fill:    {}\n\
             queue wait us: {}\n\
             cut budget us: {}\n\
             epoch lag:     {}\n\
             flip stall us: {}\n\
             fleet health:  {} restart{}, {} scripted fault{} fired\n\
             checkpoint us: {}",
            self.forwards,
            self.rows,
            self.fleet_rows,
            self.shards,
            if self.shards == 1 { "" } else { "s" },
            self.full_dispatches,
            self.timeout_dispatches,
            100.0 * self.mean_fill(),
            self.hot_allocs,
            self.dispatch_rows.summary(),
            self.fill_ratio.summary(),
            self.queue_wait_us.summary(),
            self.cut_us.summary(),
            self.epoch_lag.summary(),
            self.flip_stall_us.summary(),
            self.restarts,
            if self.restarts == 1 { "" } else { "s" },
            self.faults_injected,
            if self.faults_injected == 1 { "" } else { "s" },
            self.checkpoint_write_us.summary()
        );
        if self.has_wire_traffic() {
            s.push_str(&format!(
                "\nwire traffic:  {} frames in / {} out, {} B in / {} B out, \
                 {} handshake{}, {} remote disconnect{}\n\
                 frame bytes:   {}",
                self.wire_frames_in,
                self.wire_frames_out,
                self.wire_bytes_in,
                self.wire_bytes_out,
                self.wire_handshakes,
                if self.wire_handshakes == 1 { "" } else { "s" },
                self.wire_disconnects,
                if self.wire_disconnects == 1 { "" } else { "s" },
                self.wire_frame_bytes.summary()
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("forwards", Json::Num(self.forwards as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("fleet_rows", Json::Num(self.fleet_rows as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("hot_allocs", Json::Num(self.hot_allocs as f64)),
            ("full_dispatches", Json::Num(self.full_dispatches as f64)),
            (
                "timeout_dispatches",
                Json::Num(self.timeout_dispatches as f64),
            ),
            ("mean_fill", Json::Num(self.mean_fill())),
            ("dispatch_rows", self.dispatch_rows.to_json()),
            ("fill_ratio", self.fill_ratio.to_json()),
            ("queue_wait_us", self.queue_wait_us.to_json()),
            ("cut_us", self.cut_us.to_json()),
            ("epoch_lag", self.epoch_lag.to_json()),
            ("flip_stall_us", self.flip_stall_us.to_json()),
            ("restarts", Json::Num(self.restarts as f64)),
            ("faults_injected", Json::Num(self.faults_injected as f64)),
            ("checkpoint_write_us", self.checkpoint_write_us.to_json()),
            ("wire_frames_in", Json::Num(self.wire_frames_in as f64)),
            ("wire_frames_out", Json::Num(self.wire_frames_out as f64)),
            ("wire_bytes_in", Json::Num(self.wire_bytes_in as f64)),
            ("wire_bytes_out", Json::Num(self.wire_bytes_out as f64)),
            ("wire_handshakes", Json::Num(self.wire_handshakes as f64)),
            ("wire_disconnects", Json::Num(self.wire_disconnects as f64)),
            ("wire_frame_bytes", self.wire_frame_bytes.to_json()),
        ])
    }
}

/// One training iteration's record.
#[derive(Debug, Clone, Default)]
pub struct IterationMetrics {
    pub iter: usize,
    /// Samples consumed this iteration.
    pub samples: usize,
    /// Wall-clock spent gathering the sample budget (rollout time, Fig 4).
    pub collect_secs: f64,
    /// Virtual-core rollout time: max over workers of their measured CPU
    /// busy time this iteration. Equals wall collect time on a testbed
    /// with >= N cores; on fewer cores it projects the paper's multi-core
    /// rollout time from real single-core work measurements (DESIGN.md §3).
    pub virtual_collect_secs: f64,
    /// Wall-clock spent in the policy update (learn time, Fig 7).
    pub learn_secs: f64,
    /// Wall-clock of the whole iteration.
    pub total_secs: f64,
    /// Mean return of episodes completed this iteration (Fig 3).
    pub mean_return: f32,
    pub episodes: usize,
    /// Mean episode length.
    pub mean_ep_len: f32,
    /// Cumulative environment steps at the end of this iteration.
    pub total_steps: u64,
    /// Cumulative wall-clock since training start.
    pub wall_secs: f64,
    // learner diagnostics
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub clip_frac: f32,
    pub lr: f32,
    /// Mean policy-version staleness of consumed chunks (async lag).
    pub staleness: f32,
}

impl IterationMetrics {
    /// Fraction of the iteration spent collecting (Fig 6 numerator),
    /// using virtual-core rollout time (== wall collect on >= N cores).
    pub fn collect_frac(&self) -> f64 {
        let denom = self.virtual_collect_secs + self.learn_secs;
        if denom > 0.0 {
            self.virtual_collect_secs / denom
        } else {
            0.0
        }
    }

    pub fn learn_frac(&self) -> f64 {
        let denom = self.virtual_collect_secs + self.learn_secs;
        if denom > 0.0 {
            self.learn_secs / denom
        } else {
            0.0
        }
    }

    pub const CSV_HEADER: &'static str = "iter,samples,collect_secs,virtual_collect_secs,\
        learn_secs,total_secs,mean_return,episodes,mean_ep_len,total_steps,wall_secs,\
        pi_loss,v_loss,entropy,approx_kl,clip_frac,lr,staleness";

    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.4},{},{:.1},{},{:.3},{:.5},{:.5},{:.4},{:.5},{:.4},{:.6},{:.2}",
            self.iter,
            self.samples,
            self.collect_secs,
            self.virtual_collect_secs,
            self.learn_secs,
            self.total_secs,
            self.mean_return,
            self.episodes,
            self.mean_ep_len,
            self.total_steps,
            self.wall_secs,
            self.pi_loss,
            self.v_loss,
            self.entropy,
            self.approx_kl,
            self.clip_frac,
            self.lr,
            self.staleness,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iter", Json::Num(self.iter as f64)),
            ("samples", Json::Num(self.samples as f64)),
            ("collect_secs", Json::Num(self.collect_secs)),
            ("learn_secs", Json::Num(self.learn_secs)),
            ("total_secs", Json::Num(self.total_secs)),
            ("mean_return", Json::Num(self.mean_return as f64)),
            ("episodes", Json::Num(self.episodes as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

/// Collected metrics for a whole run + optional CSV sink.
pub struct MetricsLog {
    pub iterations: Vec<IterationMetrics>,
    csv: Option<std::io::BufWriter<std::fs::File>>,
    quiet: bool,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self {
            iterations: Vec::new(),
            csv: None,
            quiet: false,
        }
    }

    pub fn quiet() -> Self {
        Self {
            iterations: Vec::new(),
            csv: None,
            quiet: true,
        }
    }

    /// Also mirror rows into a CSV file (header written immediately).
    pub fn with_csv(mut self, path: &str) -> anyhow::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(w, "{}", IterationMetrics::CSV_HEADER)?;
        self.csv = Some(w);
        Ok(self)
    }

    pub fn push(&mut self, m: IterationMetrics) {
        if !self.quiet {
            crate::log_info!(
                "iter {:>4} | ret {:>9.2} | eps {:>3} | collect {:>6.2}s | learn {:>6.2}s | kl {:.4}",
                m.iter,
                m.mean_return,
                m.episodes,
                m.collect_secs,
                m.learn_secs,
                m.approx_kl
            );
        }
        if let Some(w) = &mut self.csv {
            let _ = writeln!(w, "{}", m.to_csv_row());
            let _ = w.flush();
        }
        self.iterations.push(m);
    }

    /// Mean collection seconds over the last `k` iterations (steady state).
    pub fn mean_collect_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.collect_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }

    pub fn mean_virtual_collect_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.virtual_collect_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }

    pub fn mean_learn_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.learn_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }
}

impl Default for MetricsLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(iter: usize, collect: f64, learn: f64) -> IterationMetrics {
        IterationMetrics {
            iter,
            samples: 100,
            collect_secs: collect,
            virtual_collect_secs: collect,
            learn_secs: learn,
            total_secs: collect + learn,
            ..Default::default()
        }
    }

    #[test]
    fn fractions_sum_to_one() {
        let x = m(0, 3.0, 1.0);
        assert!((x.collect_frac() - 0.75).abs() < 1e-12);
        assert!((x.collect_frac() + x.learn_frac() - 1.0).abs() < 1e-12);
        let zero = IterationMetrics::default();
        assert_eq!(zero.collect_frac(), 0.0);
    }

    #[test]
    fn csv_row_matches_header_width() {
        let row = m(3, 1.0, 2.0).to_csv_row();
        assert_eq!(
            row.split(',').count(),
            IterationMetrics::CSV_HEADER.split(',').count()
        );
    }

    #[test]
    fn csv_file_written() {
        let path = std::env::temp_dir().join("walle_metrics_test.csv");
        let path_s = path.to_str().unwrap();
        let mut log = MetricsLog::quiet().with_csv(path_s).unwrap();
        log.push(m(0, 1.0, 0.5));
        log.push(m(1, 1.1, 0.4));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("iter,"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn histogram_buckets_and_stats() {
        let mut h = Histogram::new(&[1.0, 4.0, 8.0]);
        for v in [0.5, 1.0, 3.0, 9.0, 100.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        let buckets = h.buckets();
        assert_eq!(buckets.len(), 4);
        assert_eq!(buckets[0], (1.0, 2)); // 0.5 and 1.0 (inclusive edge)
        assert_eq!(buckets[1], (4.0, 1)); // 3.0
        assert_eq!(buckets[2], (8.0, 0));
        assert_eq!(buckets[3].1, 2); // 9.0, 100.0 overflow
        assert!((h.mean() - 113.5 / 5.0).abs() < 1e-12);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 100.0);
        assert!(h.summary().contains("n=5"));
        let empty = Histogram::new(&[1.0]);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.min(), 0.0);
    }

    #[test]
    fn inference_report_renders_and_serializes() {
        let mut r = InferenceReport::new(16);
        r.forwards = 2;
        r.rows = 24;
        r.full_dispatches = 1;
        r.timeout_dispatches = 1;
        r.dispatch_rows.record(16.0);
        r.dispatch_rows.record(8.0);
        r.fill_ratio.record(1.0);
        r.fill_ratio.record(0.5);
        assert!((r.mean_fill() - 0.75).abs() < 1e-12);
        assert!((r.mean_dispatch_rows() - 12.0).abs() < 1e-12);
        let text = r.render();
        assert!(text.contains("2 forwards"));
        assert!(text.contains("mean fill 75.0%"));
        assert!(text.contains("1 shard)"));
        r.restarts = 2;
        r.faults_injected = 1;
        r.checkpoint_write_us.record(900.0);
        let text = r.render();
        assert!(text.contains("2 restarts"));
        assert!(text.contains("1 scripted fault fired"));
        assert!(text.contains("checkpoint us:"));
        let j = r.to_json().to_string();
        assert!(j.contains("\"fleet_rows\""));
        assert!(j.contains("\"mean_fill\""));
        assert!(j.contains("\"shards\""));
        assert!(j.contains("\"hot_allocs\""));
        assert!(j.contains("\"cut_us\""));
        assert!(j.contains("\"epoch_lag\""));
        assert!(j.contains("\"flip_stall_us\""));
        assert!(j.contains("\"restarts\":2"));
        assert!(j.contains("\"faults_injected\":1"));
        assert!(j.contains("\"checkpoint_write_us\""));
    }

    /// The fleet-health counters fold across shard reports like every
    /// other field, so the pool-wide report carries fleet totals.
    #[test]
    fn fleet_health_counters_merge() {
        let mut a = InferenceReport::with_bounds(6, 6);
        let mut b = InferenceReport::with_bounds(4, 6);
        a.restarts = 1;
        a.faults_injected = 2;
        a.checkpoint_write_us.record(400.0);
        b.restarts = 3;
        b.checkpoint_write_us.record(12_000.0);
        a.merge(&b);
        assert_eq!(a.restarts, 4);
        assert_eq!(a.faults_injected, 2);
        assert_eq!(a.checkpoint_write_us.count(), 2);
    }

    /// Wire counters fold across reports like every other field, the
    /// render shows them only when a daemon actually moved traffic, and
    /// the JSON always carries them.
    #[test]
    fn wire_metrics_merge_and_render_conditionally() {
        let mut a = InferenceReport::with_bounds(6, 6);
        assert!(!a.has_wire_traffic());
        assert!(!a.render().contains("wire traffic"), "zero counters must stay silent");
        assert!(a.to_json().to_string().contains("\"wire_frames_in\":0"));

        let mut b = InferenceReport::with_bounds(4, 6);
        b.wire_frames_in = 10;
        b.wire_frames_out = 9;
        b.wire_bytes_in = 2_048;
        b.wire_bytes_out = 4_096;
        b.wire_handshakes = 2;
        b.wire_disconnects = 1;
        b.wire_frame_bytes.record(128.0);
        b.wire_frame_bytes.record(512.0);
        a.merge(&b);
        assert!(a.has_wire_traffic());
        assert_eq!(a.wire_frames_in, 10);
        assert_eq!(a.wire_bytes_out, 4_096);
        assert_eq!(a.wire_frame_bytes.count(), 2);
        let text = a.render();
        assert!(text.contains("10 frames in / 9 out"), "{text}");
        assert!(text.contains("2 handshakes, 1 remote disconnect"), "{text}");
        assert!(text.contains("frame bytes:"), "{text}");
        let j = a.to_json().to_string();
        assert!(j.contains("\"wire_disconnects\":1"));
        assert!(j.contains("\"wire_frame_bytes\""));
    }

    /// The epoch histograms merge across shards like every other report
    /// field (identical fixed bounds regardless of shard capacity).
    #[test]
    fn epoch_histograms_merge_across_uneven_shards() {
        let mut a = InferenceReport::with_bounds(6, 6);
        let mut b = InferenceReport::with_bounds(4, 6);
        a.epoch_lag.record(0.0);
        a.flip_stall_us.record(120.0);
        b.epoch_lag.record(1.0);
        a.merge(&b);
        assert_eq!(a.epoch_lag.count(), 2);
        assert_eq!(a.flip_stall_us.count(), 1);
        assert!(a.render().contains("epoch lag:"));
        assert!(a.render().contains("flip stall us:"));
    }

    /// An empty histogram (e.g. cut_us when no timeout dispatch ever
    /// fired) must serialize finite numbers, never inf/-inf tokens that
    /// would corrupt inference.json.
    #[test]
    fn empty_histogram_serializes_finite_json() {
        let h = Histogram::new(&[1.0, 4.0]);
        let j = h.to_json().to_string();
        // the guarded min()/max() accessors put 0, not the raw ±inf
        // sentinels, into the serialization
        assert!(j.contains("\"min\":0") && j.contains("\"max\":0"), "{j}");
        // and the whole thing round-trips through our own parser
        crate::util::json::Json::parse(&j).unwrap();
    }

    #[test]
    fn histogram_merge_folds_counts_and_extremes() {
        let mut a = Histogram::new(&[1.0, 4.0]);
        a.record(0.5);
        a.record(3.0);
        let mut b = Histogram::new(&[1.0, 4.0]);
        b.record(10.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 10.0);
        assert!((a.mean() - 13.5 / 3.0).abs() < 1e-12);
        let buckets = a.buckets();
        assert_eq!(buckets[0].1, 1);
        assert_eq!(buckets[1].1, 1);
        assert_eq!(buckets[2].1, 1);
        // merging into an empty histogram keeps extremes sane
        let mut empty = Histogram::new(&[1.0, 4.0]);
        empty.merge(&a);
        assert_eq!(empty.min(), 0.5);
        assert_eq!(empty.max(), 10.0);
    }

    #[test]
    #[should_panic(expected = "different bucket bounds")]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::new(&[1.0]);
        a.merge(&Histogram::new(&[2.0]));
    }

    #[test]
    fn inference_report_merge_sums_shards() {
        // uneven shards share bucket bounds via with_bounds
        let mut a = InferenceReport::with_bounds(6, 6);
        let mut b = InferenceReport::with_bounds(4, 6);
        a.forwards = 10;
        a.rows = 50;
        a.full_dispatches = 8;
        a.timeout_dispatches = 2;
        a.hot_allocs = 7;
        a.fill_ratio.record(1.0);
        b.forwards = 5;
        b.rows = 20;
        b.full_dispatches = 5;
        b.hot_allocs = 3;
        b.fill_ratio.record(0.5);
        a.merge(&b);
        assert_eq!(a.forwards, 15);
        assert_eq!(a.rows, 70);
        assert_eq!(a.fleet_rows, 10);
        assert_eq!(a.shards, 2);
        assert_eq!(a.hot_allocs, 10);
        assert_eq!(a.full_dispatches, 13);
        assert_eq!(a.timeout_dispatches, 2);
        assert!((a.mean_fill() - 0.75).abs() < 1e-12);
        assert!(a.render().contains("2 shards"));
    }

    #[test]
    fn tail_means() {
        let mut log = MetricsLog::quiet();
        for i in 0..10 {
            log.push(m(i, i as f64, 2.0 * i as f64));
        }
        // last 2: collect 8,9 -> 8.5
        assert!((log.mean_collect_secs(2) - 8.5).abs() < 1e-12);
        assert!((log.mean_learn_secs(2) - 17.0).abs() < 1e-12);
    }
}
