//! Per-iteration metrics: the numbers behind every figure in the paper —
//! collection (rollout) time, learning time, their fractions (Figs 4, 6,
//! 7), and average return (Fig 3). Collected by the learner, logged to
//! stdout, and written as CSV/JSON for the bench harness.

use crate::util::json::Json;
use std::io::Write;

/// One training iteration's record.
#[derive(Debug, Clone, Default)]
pub struct IterationMetrics {
    pub iter: usize,
    /// Samples consumed this iteration.
    pub samples: usize,
    /// Wall-clock spent gathering the sample budget (rollout time, Fig 4).
    pub collect_secs: f64,
    /// Virtual-core rollout time: max over workers of their measured CPU
    /// busy time this iteration. Equals wall collect time on a testbed
    /// with >= N cores; on fewer cores it projects the paper's multi-core
    /// rollout time from real single-core work measurements (DESIGN.md §3).
    pub virtual_collect_secs: f64,
    /// Wall-clock spent in the policy update (learn time, Fig 7).
    pub learn_secs: f64,
    /// Wall-clock of the whole iteration.
    pub total_secs: f64,
    /// Mean return of episodes completed this iteration (Fig 3).
    pub mean_return: f32,
    pub episodes: usize,
    /// Mean episode length.
    pub mean_ep_len: f32,
    /// Cumulative environment steps at the end of this iteration.
    pub total_steps: u64,
    /// Cumulative wall-clock since training start.
    pub wall_secs: f64,
    // learner diagnostics
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub clip_frac: f32,
    pub lr: f32,
    /// Mean policy-version staleness of consumed chunks (async lag).
    pub staleness: f32,
}

impl IterationMetrics {
    /// Fraction of the iteration spent collecting (Fig 6 numerator),
    /// using virtual-core rollout time (== wall collect on >= N cores).
    pub fn collect_frac(&self) -> f64 {
        let denom = self.virtual_collect_secs + self.learn_secs;
        if denom > 0.0 {
            self.virtual_collect_secs / denom
        } else {
            0.0
        }
    }

    pub fn learn_frac(&self) -> f64 {
        let denom = self.virtual_collect_secs + self.learn_secs;
        if denom > 0.0 {
            self.learn_secs / denom
        } else {
            0.0
        }
    }

    pub const CSV_HEADER: &'static str = "iter,samples,collect_secs,virtual_collect_secs,\
        learn_secs,total_secs,mean_return,episodes,mean_ep_len,total_steps,wall_secs,\
        pi_loss,v_loss,entropy,approx_kl,clip_frac,lr,staleness";

    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.4},{},{:.1},{},{:.3},{:.5},{:.5},{:.4},{:.5},{:.4},{:.6},{:.2}",
            self.iter,
            self.samples,
            self.collect_secs,
            self.virtual_collect_secs,
            self.learn_secs,
            self.total_secs,
            self.mean_return,
            self.episodes,
            self.mean_ep_len,
            self.total_steps,
            self.wall_secs,
            self.pi_loss,
            self.v_loss,
            self.entropy,
            self.approx_kl,
            self.clip_frac,
            self.lr,
            self.staleness,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iter", Json::Num(self.iter as f64)),
            ("samples", Json::Num(self.samples as f64)),
            ("collect_secs", Json::Num(self.collect_secs)),
            ("learn_secs", Json::Num(self.learn_secs)),
            ("total_secs", Json::Num(self.total_secs)),
            ("mean_return", Json::Num(self.mean_return as f64)),
            ("episodes", Json::Num(self.episodes as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

/// Collected metrics for a whole run + optional CSV sink.
pub struct MetricsLog {
    pub iterations: Vec<IterationMetrics>,
    csv: Option<std::io::BufWriter<std::fs::File>>,
    quiet: bool,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self {
            iterations: Vec::new(),
            csv: None,
            quiet: false,
        }
    }

    pub fn quiet() -> Self {
        Self {
            iterations: Vec::new(),
            csv: None,
            quiet: true,
        }
    }

    /// Also mirror rows into a CSV file (header written immediately).
    pub fn with_csv(mut self, path: &str) -> anyhow::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(w, "{}", IterationMetrics::CSV_HEADER)?;
        self.csv = Some(w);
        Ok(self)
    }

    pub fn push(&mut self, m: IterationMetrics) {
        if !self.quiet {
            crate::log_info!(
                "iter {:>4} | ret {:>9.2} | eps {:>3} | collect {:>6.2}s | learn {:>6.2}s | kl {:.4}",
                m.iter,
                m.mean_return,
                m.episodes,
                m.collect_secs,
                m.learn_secs,
                m.approx_kl
            );
        }
        if let Some(w) = &mut self.csv {
            let _ = writeln!(w, "{}", m.to_csv_row());
            let _ = w.flush();
        }
        self.iterations.push(m);
    }

    /// Mean collection seconds over the last `k` iterations (steady state).
    pub fn mean_collect_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.collect_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }

    pub fn mean_virtual_collect_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.virtual_collect_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }

    pub fn mean_learn_secs(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .iterations
            .iter()
            .rev()
            .take(k)
            .map(|m| m.learn_secs)
            .collect();
        crate::util::stats::mean(&tail)
    }
}

impl Default for MetricsLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(iter: usize, collect: f64, learn: f64) -> IterationMetrics {
        IterationMetrics {
            iter,
            samples: 100,
            collect_secs: collect,
            virtual_collect_secs: collect,
            learn_secs: learn,
            total_secs: collect + learn,
            ..Default::default()
        }
    }

    #[test]
    fn fractions_sum_to_one() {
        let x = m(0, 3.0, 1.0);
        assert!((x.collect_frac() - 0.75).abs() < 1e-12);
        assert!((x.collect_frac() + x.learn_frac() - 1.0).abs() < 1e-12);
        let zero = IterationMetrics::default();
        assert_eq!(zero.collect_frac(), 0.0);
    }

    #[test]
    fn csv_row_matches_header_width() {
        let row = m(3, 1.0, 2.0).to_csv_row();
        assert_eq!(
            row.split(',').count(),
            IterationMetrics::CSV_HEADER.split(',').count()
        );
    }

    #[test]
    fn csv_file_written() {
        let path = std::env::temp_dir().join("walle_metrics_test.csv");
        let path_s = path.to_str().unwrap();
        let mut log = MetricsLog::quiet().with_csv(path_s).unwrap();
        log.push(m(0, 1.0, 0.5));
        log.push(m(1, 1.1, 0.4));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("iter,"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tail_means() {
        let mut log = MetricsLog::quiet();
        for i in 0..10 {
            log.push(m(i, i as f64, 2.0 * i as f64));
        }
        // last 2: collect 8,9 -> 8.5
        assert!((log.mean_collect_secs(2) - 8.5).abs() < 1e-12);
        assert!((log.mean_learn_secs(2) - 17.0).abs() < 1e-12);
    }
}
