//! The WALL-E coordinator — the paper's system contribution (Fig 2).
//!
//! * [`queue`] — bounded MPMC **experience queue** (samplers → learner)
//!   with backpressure and block-time accounting.
//! * [`policy_store`] — versioned **policy queue** (learner → samplers):
//!   single-slot broadcast; samplers always read the freshest parameters.
//! * [`sampler`] — the N parallel rollout workers, each **vectorized**
//!   over `envs_per_sampler` lockstep envs: one batched `act` call with M
//!   real rows per sim tick drives all M envs (amortizing inference
//!   M-fold per worker), scattering per-env transitions into per-env
//!   chunk buffers so GAE segment semantics are preserved exactly.
//!   Inference runs either on a private per-worker backend
//!   (`--inference-mode local`) or through the shared inference server
//!   (`--inference-mode shared`): one `runtime::inference_server` thread
//!   owns an N*M-row backend, coalesces every worker's slab into a
//!   single mega-batch forward per sim tick (straggler-cut after
//!   `--infer-max-wait-us`), observes the policy store once per dispatch
//!   so all rows share a version, and hands back normalized obs +
//!   per-row outputs. Per-env trajectories are bitwise identical across
//!   modes. Measure the amortization curve with `cargo bench --bench
//!   micro` (act batch sweep B=1..32, plus shared-vs-private fleet
//!   throughput) and the end-to-end per-worker steps/sec with
//!   `cargo bench --bench fig4_rollout_time` (M=1 vs M=8, local vs
//!   shared); both write machine-readable `BENCH_*.json` results.
//! * [`learner`] — the asynchronous agent process (collect → GAE →
//!   minibatch epochs → publish), PPO and DDPG variants.
//! * [`learn_pool`] — deterministic parallel gradient pool for the
//!   off-policy learners: fixed-size minibatch grains fanned over
//!   `--learner-threads` workers, combined by a fixed-order tree
//!   reduction so published parameters are bitwise identical for any L.
//! * [`orchestrator`] — spawn/join lifecycle, sync/async modes, and the
//!   self-healing supervisor loops (respawn with restored state under a
//!   bounded restart budget).
//! * [`supervisor`] — per-worker heartbeat lanes, restorable worker
//!   snapshots, and the supervised-sampler control block.
//! * [`metrics`] — per-iteration collect/learn timing and returns (the
//!   data behind the paper's Figs 3–7).
//! * [`eval`] — deterministic policy evaluation.

pub mod eval;
pub mod learn_pool;
pub mod learner;
pub mod metrics;
pub mod orchestrator;
pub mod policy_store;
pub mod queue;
pub mod sampler;
pub mod supervisor;
