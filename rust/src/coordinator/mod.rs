//! The WALL-E coordinator — the paper's system contribution (Fig 2).
//!
//! * [`queue`] — bounded MPMC **experience queue** (samplers → learner)
//!   with backpressure and block-time accounting.
//! * [`policy_store`] — versioned **policy queue** (learner → samplers):
//!   single-slot broadcast; samplers always read the freshest parameters.
//! * [`sampler`] — the N parallel rollout workers.
//! * [`learner`] — the asynchronous agent process (collect → GAE →
//!   minibatch epochs → publish), PPO and DDPG variants.
//! * [`orchestrator`] — spawn/join lifecycle, sync/async modes.
//! * [`metrics`] — per-iteration collect/learn timing and returns (the
//!   data behind the paper's Figs 3–7).
//! * [`eval`] — deterministic policy evaluation.

pub mod eval;
pub mod learner;
pub mod metrics;
pub mod orchestrator;
pub mod policy_store;
pub mod queue;
pub mod sampler;
