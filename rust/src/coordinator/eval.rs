//! Policy evaluation: deterministic (mean-action) rollouts used by the
//! examples, the figure harness, and `walle eval`.

use crate::env::{clip_action, Env};
use crate::runtime::ActorBackend;
use crate::util::rng::Pcg64;

/// Evaluation outcome over `episodes` deterministic rollouts.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub mean_return: f32,
    pub std_return: f32,
    pub mean_len: f32,
    pub returns: Vec<f32>,
}

/// Roll `episodes` episodes with the mean action (no exploration noise).
/// `norm` is the observation normalizer snapshot the policy was trained
/// with (identity if training ran without normalization).
pub fn evaluate(
    env: &mut dyn Env,
    actor: &mut dyn ActorBackend,
    params: &[f32],
    norm: &crate::algo::normalizer::NormSnapshot,
    episodes: usize,
    seed: u64,
) -> anyhow::Result<EvalResult> {
    let obs_dim = env.obs_dim();
    let act_dim = env.act_dim();
    let b = actor.batch().max(1);
    let mut rng = Pcg64::with_stream(seed, 0xE7A1);
    let mut raw = vec![0.0f32; obs_dim];
    let mut obs_in = vec![0.0f32; b * obs_dim];
    let noise = vec![0.0f32; b * act_dim];
    let mut returns = Vec::with_capacity(episodes);
    let mut lengths = Vec::with_capacity(episodes);

    for _ in 0..episodes {
        env.reset(&mut rng, &mut raw);
        let mut total = 0.0f32;
        let mut len = 0usize;
        loop {
            let mut norm_obs = raw.clone();
            norm.apply(&mut norm_obs);
            obs_in[..obs_dim].copy_from_slice(&norm_obs);
            let out = actor.act(params, &obs_in, &noise)?;
            let mut action = out.mean[..act_dim].to_vec();
            clip_action(&mut action);
            let step = env.step(&action, &mut raw);
            total += step.reward;
            len += 1;
            if step.done || len >= env.max_episode_steps() {
                break;
            }
        }
        returns.push(total);
        lengths.push(len as f32);
    }
    Ok(EvalResult {
        mean_return: crate::util::stats::mean_f32(&returns),
        std_return: crate::util::stats::std_f32(&returns),
        mean_len: crate::util::stats::mean_f32(&lengths),
        returns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::env::registry::make_env;
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;

    #[test]
    fn eval_is_deterministic_given_seed() {
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let params = f.init_ppo_params(0);
        let mut env = make_env("pendulum").unwrap();
        let mut actor = f.make_actor().unwrap();
        let norm = NormSnapshot::identity(3);
        let r1 = evaluate(env.as_mut(), actor.as_mut(), &params, &norm, 3, 42).unwrap();
        let r2 = evaluate(env.as_mut(), actor.as_mut(), &params, &norm, 3, 42).unwrap();
        assert_eq!(r1.returns, r2.returns);
        assert_eq!(r1.returns.len(), 3);
        // pendulum returns are negative costs
        assert!(r1.mean_return < 0.0);
        assert_eq!(r1.mean_len, 200.0);
    }

    #[test]
    fn different_params_usually_differ() {
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let mut env = make_env("pendulum").unwrap();
        let mut actor = f.make_actor().unwrap();
        let norm = NormSnapshot::identity(3);
        let r1 = evaluate(env.as_mut(), actor.as_mut(), &f.init_ppo_params(0), &norm, 2, 7)
            .unwrap();
        let r2 = evaluate(env.as_mut(), actor.as_mut(), &f.init_ppo_params(99), &norm, 2, 7)
            .unwrap();
        assert_ne!(r1.returns, r2.returns);
    }
}
