//! Policy evaluation: deterministic (mean-action) rollouts used by the
//! examples, the figure harness, and `walle eval`.
//!
//! [`evaluate_algo`] is the canonical entry point: it builds the actor
//! through [`Algorithm::make_eval_actor`] — the SAME construction the
//! training path uses at M = 1 — so evaluation can never silently drift
//! from the train-time forward (the pre-trait code built its own
//! single-row path per call site). The lower-level [`evaluate`] takes an
//! already-built actor and applies the normalizer exactly once per
//! observation.
//!
//! Evaluation is panic-contained: a backend or env that panics
//! mid-rollout surfaces as a failed evaluation (`Err`), never as a
//! poisoned caller — figure sweeps and `Session::evaluate` keep their
//! remaining work.

use crate::algo::api::Algorithm;
use crate::env::registry::make_env;
use crate::env::vec_env::{VecEnv, VecStepInfo};
use crate::env::{clip_action, Env};
use crate::runtime::{ActorBackend, BackendFactory};
use crate::util::rng::Pcg64;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// RNG stream id of evaluation rollouts — distinct from every sampler
/// stream (`worker_id * M + lane + 1`), so eval draws never collide with
/// training dynamics streams. [`evaluate`] seeds its own env RNG from it;
/// [`evaluate_algo`] hands it to the `VecEnv` lane, making the two paths
/// draw-for-draw identical.
pub const EVAL_STREAM: u64 = 0xE7A1;

/// Evaluation outcome over `episodes` deterministic rollouts.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub mean_return: f32,
    pub std_return: f32,
    pub mean_len: f32,
    pub returns: Vec<f32>,
}

/// Roll `episodes` episodes with the mean action (no exploration noise).
/// `norm` is the observation normalizer snapshot the policy was trained
/// with (identity if training ran without normalization).
pub fn evaluate(
    env: &mut dyn Env,
    actor: &mut dyn ActorBackend,
    params: &[f32],
    norm: &crate::algo::normalizer::NormSnapshot,
    episodes: usize,
    seed: u64,
) -> anyhow::Result<EvalResult> {
    let obs_dim = env.obs_dim();
    let act_dim = env.act_dim();
    let b = actor.batch().max(1);
    let mut rng = Pcg64::with_stream(seed, EVAL_STREAM);
    let mut raw = vec![0.0f32; obs_dim];
    let mut obs_in = vec![0.0f32; b * obs_dim];
    let noise = vec![0.0f32; b * act_dim];
    let mut returns = Vec::with_capacity(episodes);
    let mut lengths = Vec::with_capacity(episodes);

    for ep in 0..episodes {
        // Panic containment: a backend defect killing one rollout must
        // fail THIS evaluation with an error, not unwind through the
        // caller (which may hold locks or a half-finished figure sweep).
        let episode = catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<(f32, usize)> {
            env.reset(&mut rng, &mut raw);
            let mut total = 0.0f32;
            let mut len = 0usize;
            loop {
                let mut norm_obs = raw.clone();
                norm.apply(&mut norm_obs);
                obs_in[..obs_dim].copy_from_slice(&norm_obs);
                let out = actor.act(params, &obs_in, &noise)?;
                // deterministic actors leave the mean lane empty: their
                // action IS the mean. (For stochastic actors the zero noise
                // above makes action == mean as well; the mean lane is kept
                // for exactness.)
                let mut action = if out.mean.is_empty() {
                    out.action[..act_dim].to_vec()
                } else {
                    out.mean[..act_dim].to_vec()
                };
                clip_action(&mut action);
                let step = env.step(&action, &mut raw);
                total += step.reward;
                len += 1;
                if step.done || len >= env.max_episode_steps() {
                    return Ok((total, len));
                }
            }
        }));
        match episode {
            Ok(Ok((total, len))) => {
                returns.push(total);
                lengths.push(len as f32);
            }
            Ok(Err(e)) => return Err(e),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                anyhow::bail!("evaluation panicked during episode {ep}: {msg}");
            }
        }
    }
    Ok(EvalResult {
        mean_return: crate::util::stats::mean_f32(&returns),
        std_return: crate::util::stats::std_f32(&returns),
        mean_len: crate::util::stats::mean_f32(&lengths),
        returns,
    })
}

/// [`evaluate`] over a one-lane [`VecEnv`] — the rollout substrate the
/// training samplers use, so evaluation exercises the SAME env engine
/// (batched or scalar) as training. The `VecEnv` must have M = 1 with
/// its lane on the [`EVAL_STREAM`] RNG stream; episode accounting (raw
/// return accumulation, time-limit truncation at the cap) is the
/// adapter's own, which matches [`evaluate`]'s loop bitwise.
pub fn evaluate_vec(
    venv: &mut VecEnv,
    actor: &mut dyn ActorBackend,
    params: &[f32],
    norm: &crate::algo::normalizer::NormSnapshot,
    episodes: usize,
) -> anyhow::Result<EvalResult> {
    anyhow::ensure!(
        venv.num_envs() == 1,
        "evaluate_vec drives exactly one lane, got {}",
        venv.num_envs()
    );
    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    let b = actor.batch().max(1);
    let mut obs_in = vec![0.0f32; b * obs_dim];
    let noise = vec![0.0f32; b * act_dim];
    let mut infos = vec![VecStepInfo::default(); 1];
    let mut returns = Vec::with_capacity(episodes);
    let mut lengths = Vec::with_capacity(episodes);

    for ep in 0..episodes {
        // same panic containment as `evaluate` (see above)
        let episode = catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<(f32, usize)> {
            venv.reset_env(0);
            loop {
                let mut norm_obs = venv.obs_row(0).to_vec();
                norm.apply(&mut norm_obs);
                obs_in[..obs_dim].copy_from_slice(&norm_obs);
                let out = actor.act(params, &obs_in, &noise)?;
                let mut action = if out.mean.is_empty() {
                    out.action[..act_dim].to_vec()
                } else {
                    out.mean[..act_dim].to_vec()
                };
                clip_action(&mut action);
                venv.step_all(&action, &mut infos);
                if infos[0].ended() {
                    return Ok((venv.ep_return(0), venv.ep_len(0)));
                }
            }
        }));
        match episode {
            Ok(Ok((total, len))) => {
                returns.push(total);
                lengths.push(len as f32);
            }
            Ok(Err(e)) => return Err(e),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                anyhow::bail!("evaluation panicked during episode {ep}: {msg}");
            }
        }
    }
    Ok(EvalResult {
        mean_return: crate::util::stats::mean_f32(&returns),
        std_return: crate::util::stats::std_f32(&returns),
        mean_len: crate::util::stats::mean_f32(&lengths),
        returns,
    })
}

/// Evaluate `params` on `env_name` through `algo`'s trait-constructed
/// eval actor — one code path with training (same batched-actor
/// construction at M = 1, same single normalizer application), shared by
/// `walle eval`, `Session::evaluate`, and the examples. Rollouts run
/// through the `VecEnv` adapter at M = 1 under the process-wide active
/// env engine; the lane rides the [`EVAL_STREAM`] RNG stream, so returns
/// are identical to the direct scalar [`evaluate`] path (asserted by
/// `vec_adapter_eval_matches_scalar_env_path` below).
pub fn evaluate_algo(
    algo: &dyn Algorithm,
    factory: &dyn BackendFactory,
    env_name: &str,
    params: &[f32],
    norm: &crate::algo::normalizer::NormSnapshot,
    episodes: usize,
    seed: u64,
) -> anyhow::Result<EvalResult> {
    let mut venv = VecEnv::from_registry(env_name, 1, seed, EVAL_STREAM)
        .map_err(|e| anyhow::anyhow!("unknown env {env_name:?} for evaluation: {e}"))?;
    let mut actor = algo.make_eval_actor(factory)?;
    evaluate_vec(&mut venv, actor.as_mut(), params, norm, episodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::env::registry::make_env;
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;

    #[test]
    fn eval_is_deterministic_given_seed() {
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let params = f.init_ppo_params(0);
        let mut env = make_env("pendulum").unwrap();
        let mut actor = f.make_actor().unwrap();
        let norm = NormSnapshot::identity(3);
        let r1 = evaluate(env.as_mut(), actor.as_mut(), &params, &norm, 3, 42).unwrap();
        let r2 = evaluate(env.as_mut(), actor.as_mut(), &params, &norm, 3, 42).unwrap();
        assert_eq!(r1.returns, r2.returns);
        assert_eq!(r1.returns.len(), 3);
        // pendulum returns are negative costs
        assert!(r1.mean_return < 0.0);
        assert_eq!(r1.mean_len, 200.0);
    }

    /// Satellite regression: every algorithm evaluates through its OWN
    /// trait-constructed actor (correct param count and lane semantics),
    /// not a hard-coded PPO path.
    #[test]
    fn evaluate_algo_routes_every_algorithm_through_its_trait_actor() {
        use crate::algo::api::algorithm_from_config;
        use crate::config::{Algo, TrainConfig};

        let mut cfg = TrainConfig::preset("pendulum");
        cfg.hidden = vec![8, 8];
        let f = NativeFactory::new(3, 1, &[8, 8], cfg.ppo.clone(), cfg.ddpg.clone());
        let norm = NormSnapshot::identity(3);
        for algo_id in [Algo::Ppo, Algo::Ddpg, Algo::Td3, Algo::Sac] {
            cfg.algo = algo_id;
            let algo = algorithm_from_config(&cfg);
            let params = vec![0.01f32; algo.policy_param_count(&f, &cfg)];
            let r =
                evaluate_algo(algo.as_ref(), &f, "pendulum", &params, &norm, 2, 11).unwrap();
            assert_eq!(r.returns.len(), 2, "{}", algo.name());
            assert!(r.mean_return.is_finite(), "{}", algo.name());
            // deterministic given seed regardless of algorithm
            let r2 =
                evaluate_algo(algo.as_ref(), &f, "pendulum", &params, &norm, 2, 11).unwrap();
            assert_eq!(r.returns, r2.returns, "{}", algo.name());
        }
    }

    /// Satellite 2: a panicking eval actor produces a failed evaluation
    /// (`Err` naming the episode), never an unwind through the caller.
    #[test]
    fn panicking_actor_fails_evaluation_instead_of_unwinding() {
        struct PanickingActor {
            calls: usize,
        }
        impl crate::runtime::ActorBackend for PanickingActor {
            fn batch(&self) -> usize {
                1
            }
            fn obs_dim(&self) -> usize {
                3
            }
            fn act_dim(&self) -> usize {
                1
            }
            fn act(
                &mut self,
                _flat: &[f32],
                _obs: &[f32],
                _noise: &[f32],
            ) -> anyhow::Result<crate::runtime::ActResult> {
                self.calls += 1;
                if self.calls > 5 {
                    panic!("injected eval actor fault");
                }
                Ok(crate::runtime::ActResult {
                    action: vec![0.1],
                    logp: vec![0.0],
                    value: vec![0.0],
                    mean: vec![0.1],
                })
            }
        }

        let mut env = make_env("pendulum").unwrap();
        let mut actor = PanickingActor { calls: 0 };
        let norm = NormSnapshot::identity(3);
        let err = evaluate(env.as_mut(), &mut actor, &[], &norm, 2, 42).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("panicked during episode"),
            "error must name the panic, got: {msg}"
        );
        assert!(msg.contains("injected eval actor fault"), "got: {msg}");
    }

    /// PR 9 satellite: the VecEnv-adapter rollout path (either engine)
    /// must produce bitwise-identical returns to the direct scalar-`Env`
    /// eval loop — same RNG stream, same episode accounting, same actor.
    #[test]
    fn vec_adapter_eval_matches_scalar_env_path() {
        use crate::env::batch::EnvEngine;
        use crate::env::vec_env::VecEnv;

        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let params = f.init_ppo_params(3);
        let norm = NormSnapshot::identity(3);
        let (seed, episodes) = (42u64, 3usize);

        let mut env = make_env("pendulum").unwrap();
        let mut actor = f.make_actor().unwrap();
        let want = evaluate(env.as_mut(), actor.as_mut(), &params, &norm, episodes, seed)
            .unwrap();

        for engine in [EnvEngine::Batched, EnvEngine::Scalar] {
            let mut venv =
                VecEnv::from_registry_with("pendulum", 1, seed, EVAL_STREAM, engine).unwrap();
            let got =
                evaluate_vec(&mut venv, actor.as_mut(), &params, &norm, episodes).unwrap();
            let want_bits: Vec<u32> = want.returns.iter().map(|r| r.to_bits()).collect();
            let got_bits: Vec<u32> = got.returns.iter().map(|r| r.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "{engine:?}: returns diverged");
            assert_eq!(got.mean_len, want.mean_len, "{engine:?}: lengths diverged");
        }
    }

    #[test]
    fn different_params_usually_differ() {
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let mut env = make_env("pendulum").unwrap();
        let mut actor = f.make_actor().unwrap();
        let norm = NormSnapshot::identity(3);
        let r1 = evaluate(env.as_mut(), actor.as_mut(), &f.init_ppo_params(0), &norm, 2, 7)
            .unwrap();
        let r2 = evaluate(env.as_mut(), actor.as_mut(), &f.init_ppo_params(99), &norm, 2, 7)
            .unwrap();
        assert_ne!(r1.returns, r2.returns);
    }
}
