//! Fleet supervision state: per-worker heartbeat lanes, restorable
//! worker snapshots, and the control block a supervised sampler runs
//! under.
//!
//! The orchestrator arms one [`WorkerLane`] per sampler worker. The
//! worker deposits a [`WorkerSnapshot`] into its lane at every policy
//! **version adoption** point — the only moments when its state is
//! clean: chunk buffers are empty (adoption always follows a flush-all)
//! and the exploration RNG streams sit exactly at a chunk boundary.
//! Between deposits the lane's `pushed` counter tracks how many chunks
//! the worker has already delivered to the experience queue under the
//! deposited snapshot.
//!
//! When a worker panics (a real defect, or a scripted
//! [`crate::util::fault`] cell), the supervisor catches the unwind,
//! rebuilds the worker from the deposited snapshot, and replays it with
//! `skip_chunks = pushed`: the restored worker regenerates the exact
//! same chunk sequence (same RNG cursors, same env state) and drops the
//! prefix the learner already received, so the queue sees each chunk
//! exactly once and — in sync mode — the merged per-env streams are
//! bitwise identical to a fault-free run.
//!
//! The same [`WorkerSnapshot`] bytes are what `runtime::checkpoint`
//! persists per worker: at a checkpoint barrier every lane holds a
//! snapshot at the just-published version with `pushed == 0`, so resume
//! is respawn-from-disk with nothing to skip.

use crate::algo::api::AlgoSampler;
use crate::coordinator::sampler::SamplerReport;
use crate::env::vec_env::{VecEnv, VecEnvState};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::fault::FaultCell;
use crate::util::plock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Everything needed to rebuild a sampler worker mid-run: the policy
/// version its state is clean at, the full [`VecEnvState`] (dynamics +
/// per-env RNG cursors + episode counters), the algorithm sampler's
/// opaque exploration-state blob, and the progress report so counters
/// survive the respawn.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSnapshot {
    /// Policy version the worker had adopted when the snapshot was taken.
    pub version: u64,
    /// Complete vec-env state ([`VecEnv::save_state`]).
    pub venv: VecEnvState,
    /// Opaque [`AlgoSampler::save_state`] blob (exploration RNG cursors).
    pub hooks: Vec<u8>,
    /// Progress counters carried across the respawn.
    pub report: SamplerReport,
}

impl WorkerSnapshot {
    /// Serialize into a checkpoint worker blob (see `util::bytes`).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.version);
        self.venv.write(&mut w);
        w.put_bytes(&self.hooks);
        w.put_u64(self.report.steps);
        w.put_u64(self.report.episodes);
        w.put_u64(self.report.chunks);
        w.put_u64(self.report.policy_refreshes);
        w.into_vec()
    }

    /// Parse a blob produced by [`WorkerSnapshot::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<WorkerSnapshot> {
        let mut r = ByteReader::new(bytes);
        let version = r.read_u64()?;
        let venv = VecEnvState::read(&mut r)?;
        let hooks = r.read_bytes()?.to_vec();
        let report = SamplerReport {
            steps: r.read_u64()?,
            episodes: r.read_u64()?,
            chunks: r.read_u64()?,
            policy_refreshes: r.read_u64()?,
        };
        Ok(WorkerSnapshot {
            version,
            venv,
            hooks,
            report,
        })
    }
}

/// One worker's supervision lane, shared between the supervisor thread
/// loop and the running worker. Lives across respawns: `ticks` is the
/// worker's *lifetime* sim-tick counter (fault cells trigger on it, so a
/// respawned worker does not re-arm a spent cell), `restarts` counts
/// respawns, and `snapshot`/`pushed` together describe the most recent
/// clean state and how far past it the worker has published.
#[derive(Debug, Default)]
pub struct WorkerLane {
    /// Lifetime sim ticks across all incarnations (fault counter).
    pub ticks: AtomicU64,
    /// Chunks delivered to the queue since the last deposit.
    pub pushed: AtomicU64,
    /// Times this worker was respawned after a panic.
    pub restarts: AtomicU64,
    /// Latest clean snapshot (None until the first deposit).
    pub snapshot: Mutex<Option<WorkerSnapshot>>,
}

impl WorkerLane {
    /// Empty lane (no snapshot yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Deposit a clean snapshot taken at a version-adoption point and
    /// reset the delivered-chunk counter — the worker's recovery point
    /// moves forward and nothing is pending past it.
    pub fn deposit(
        &self,
        version: u64,
        venv: &VecEnv,
        hooks: &dyn AlgoSampler,
        report: &SamplerReport,
    ) {
        let snap = WorkerSnapshot {
            version,
            venv: venv.save_state(),
            hooks: hooks.save_state(),
            report: report.clone(),
        };
        *plock(&self.snapshot) = Some(snap);
        self.pushed.store(0, Ordering::SeqCst);
    }

    /// Clone the latest deposited snapshot (None before the first).
    pub fn latest(&self) -> Option<WorkerSnapshot> {
        plock(&self.snapshot).clone()
    }
}

/// Control block a supervised sampler incarnation runs under: its lane,
/// the snapshot to restore from (None on a fresh start), the number of
/// already-delivered chunks to regenerate-and-drop, and the armed fault
/// cells for this worker id.
pub struct WorkerCtl {
    /// This worker's supervision lane.
    pub lane: Arc<WorkerLane>,
    /// Snapshot to restore before the hot loop (respawn / resume).
    pub restore: Option<WorkerSnapshot>,
    /// Chunks already delivered under the restored snapshot: regenerate
    /// them (identical RNG consumption) but do not push them again.
    pub skip_chunks: u64,
    /// Armed fault cells for this worker (None ⇒ zero-cost path).
    pub fault: Option<Vec<Arc<FaultCell>>>,
    /// Fleet-wide injected-fault counter (bumped by `fault::trip`).
    pub faults_injected: Arc<AtomicU64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> WorkerSnapshot {
        let venv = VecEnv::from_registry("pendulum", 2, 7, 1).unwrap();
        WorkerSnapshot {
            version: 3,
            venv: venv.save_state(),
            hooks: vec![1, 2, 3, 4],
            report: SamplerReport {
                steps: 400,
                episodes: 2,
                chunks: 10,
                policy_refreshes: 2,
            },
        }
    }

    #[test]
    fn snapshot_bytes_round_trip_is_identity() {
        let snap = sample_snapshot();
        let back = WorkerSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn truncated_snapshot_blob_is_rejected() {
        let bytes = sample_snapshot().to_bytes();
        assert!(WorkerSnapshot::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn deposit_moves_the_recovery_point_and_clears_pushed() {
        let lane = WorkerLane::new();
        assert!(lane.latest().is_none());
        lane.pushed.store(5, Ordering::SeqCst);

        let venv = VecEnv::from_registry("pendulum", 2, 7, 1).unwrap();
        let algo = crate::algo::ppo::Ppo::default();
        let cfg = crate::coordinator::sampler::SamplerCfg {
            id: 0,
            seed: 7,
            chunk_steps: 40,
            sync_budget: None,
            reward_scale: 1.0,
        };
        let hooks = crate::algo::api::Algorithm::make_sampler(&algo, &cfg, 2, 1);
        let report = SamplerReport::default();
        lane.deposit(4, &venv, hooks.as_ref(), &report);

        let snap = lane.latest().expect("deposited");
        assert_eq!(snap.version, 4);
        assert_eq!(snap.venv, venv.save_state());
        assert_eq!(lane.pushed.load(Ordering::SeqCst), 0);
    }
}
