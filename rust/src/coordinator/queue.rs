//! The experience queue: a bounded MPMC channel (Mutex + Condvar) carrying
//! experience chunks from the N sampler workers to the learner — the left
//! half of the paper's Fig 2. Bounded capacity gives natural backpressure:
//! when the learner falls behind, samplers block instead of filling memory
//! with stale experience.
//!
//! Hand-rolled because the offline crate set has no crossbeam-channel; the
//! implementation also exports occupancy/block statistics that feed the
//! Fig 6 time-accounting.

use crate::util::{cv_wait, cv_wait_untimed, plock};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push/pop did not deliver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelClosed {
    Closed,
}

/// Channel statistics (monotonic counters; nanoseconds for blocked time).
#[derive(Debug, Default)]
pub struct ChannelStats {
    pub pushed: AtomicU64,
    pub popped: AtomicU64,
    pub push_blocked_ns: AtomicU64,
    pub pop_blocked_ns: AtomicU64,
}

impl ChannelStats {
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    pub fn popped(&self) -> u64 {
        self.popped.load(Ordering::Relaxed)
    }

    pub fn push_blocked(&self) -> Duration {
        Duration::from_nanos(self.push_blocked_ns.load(Ordering::Relaxed))
    }

    pub fn pop_blocked(&self) -> Duration {
        Duration::from_nanos(self.pop_blocked_ns.load(Ordering::Relaxed))
    }
}

struct Inner<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC channel.
pub struct Channel<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    pub stats: ChannelStats,
}

impl<T> Channel<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(Inner {
                buf: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            stats: ChannelStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        plock(&self.inner).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; returns Err once the channel is closed.
    pub fn push(&self, item: T) -> Result<(), ChannelClosed> {
        let t0 = Instant::now();
        let mut g = plock(&self.inner);
        while g.buf.len() >= self.capacity && !g.closed {
            g = cv_wait_untimed(&self.not_full, g);
        }
        if g.closed {
            return Err(ChannelClosed::Closed);
        }
        g.buf.push_back(item);
        drop(g);
        let waited = t0.elapsed().as_nanos() as u64;
        self.stats.push_blocked_ns.fetch_add(waited, Ordering::Relaxed);
        self.stats.pushed.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push; Ok(false) when full.
    pub fn try_push(&self, item: T) -> Result<bool, ChannelClosed> {
        let mut g = plock(&self.inner);
        if g.closed {
            return Err(ChannelClosed::Closed);
        }
        if g.buf.len() >= self.capacity {
            return Ok(false);
        }
        g.buf.push_back(item);
        drop(g);
        self.stats.pushed.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(true)
    }

    /// Blocking pop; returns Err once the channel is closed *and* drained.
    pub fn pop(&self) -> Result<T, ChannelClosed> {
        let t0 = Instant::now();
        let mut g = plock(&self.inner);
        loop {
            if let Some(item) = g.buf.pop_front() {
                drop(g);
                let waited = t0.elapsed().as_nanos() as u64;
                self.stats.pop_blocked_ns.fetch_add(waited, Ordering::Relaxed);
                self.stats.popped.fetch_add(1, Ordering::Relaxed);
                self.not_full.notify_one();
                return Ok(item);
            }
            if g.closed {
                return Err(ChannelClosed::Closed);
            }
            g = cv_wait_untimed(&self.not_empty, g);
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Result<Option<T>, ChannelClosed> {
        let mut g = plock(&self.inner);
        match g.buf.pop_front() {
            Some(item) => {
                drop(g);
                self.stats.popped.fetch_add(1, Ordering::Relaxed);
                self.not_full.notify_one();
                Ok(Some(item))
            }
            None if g.closed => Err(ChannelClosed::Closed),
            None => Ok(None),
        }
    }

    /// Pop with a timeout; Ok(None) on timeout.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ChannelClosed> {
        let deadline = Instant::now() + timeout;
        let mut g = plock(&self.inner);
        loop {
            if let Some(item) = g.buf.pop_front() {
                drop(g);
                self.stats.popped.fetch_add(1, Ordering::Relaxed);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(ChannelClosed::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            g = cv_wait(&self.not_empty, g, deadline - now);
        }
    }

    /// Close the channel: producers fail immediately; consumers drain the
    /// remaining items, then get Err.
    pub fn close(&self) {
        plock(&self.inner).closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Whether the channel has been closed (supervision probe: lets an
    /// exiting producer tell "I hit a fresh failure" apart from "I
    /// unwound because someone else already closed the channel").
    pub fn is_closed(&self) -> bool {
        plock(&self.inner).closed
    }

    /// Discard all queued items (used when a fresh policy makes queued
    /// experience stale in sync mode). Returns the number dropped.
    pub fn drain(&self) -> usize {
        let mut g = plock(&self.inner);
        let n = g.buf.len();
        g.buf.clear();
        drop(g);
        self.not_full.notify_all();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order_single_thread() {
        let ch = Channel::new(8);
        for i in 0..5 {
            ch.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(ch.pop().unwrap(), i);
        }
    }

    #[test]
    fn close_unblocks_and_drains() {
        let ch = Arc::new(Channel::new(2));
        ch.push(1).unwrap();
        ch.push(2).unwrap();
        let ch2 = ch.clone();
        let h = thread::spawn(move || ch2.push(3)); // blocks: full
        thread::sleep(Duration::from_millis(20));
        assert!(!ch.is_closed());
        ch.close();
        assert!(ch.is_closed());
        assert_eq!(h.join().unwrap(), Err(ChannelClosed::Closed));
        // consumers drain remaining items then see Closed
        assert_eq!(ch.pop().unwrap(), 1);
        assert_eq!(ch.pop().unwrap(), 2);
        assert!(ch.pop().is_err());
    }

    #[test]
    fn backpressure_blocks_producer() {
        let ch = Arc::new(Channel::new(1));
        ch.push(0u32).unwrap();
        let ch2 = ch.clone();
        let t0 = Instant::now();
        let h = thread::spawn(move || {
            ch2.push(1).unwrap();
            Instant::now()
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(ch.pop().unwrap(), 0);
        let pushed_at = h.join().unwrap();
        assert!(
            pushed_at.duration_since(t0) >= Duration::from_millis(45),
            "producer did not block"
        );
        assert!(ch.stats.push_blocked() >= Duration::from_millis(40));
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let ch = Arc::new(Channel::new(16));
        let producers = 4;
        let per = 500;
        let mut handles = Vec::new();
        for p in 0..producers {
            let ch = ch.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    ch.push(p * per + i).unwrap();
                }
            }));
        }
        let consumers = 3;
        let mut consumer_handles = Vec::new();
        for _ in 0..consumers {
            let ch = ch.clone();
            consumer_handles.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = ch.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        ch.close();
        let mut all: Vec<usize> = Vec::new();
        for h in consumer_handles {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..producers * per).collect::<Vec<_>>());
        assert_eq!(ch.stats.pushed(), (producers * per) as u64);
        assert_eq!(ch.stats.popped(), (producers * per) as u64);
    }

    #[test]
    fn try_variants_do_not_block() {
        let ch: Channel<u8> = Channel::new(1);
        assert_eq!(ch.try_pop().unwrap(), None);
        assert!(ch.try_push(1).unwrap());
        assert!(!ch.try_push(2).unwrap()); // full
        assert_eq!(ch.try_pop().unwrap(), Some(1));
        ch.close();
        assert!(ch.try_push(3).is_err());
        assert!(ch.try_pop().is_err());
    }

    #[test]
    fn pop_timeout_times_out() {
        let ch: Channel<u8> = Channel::new(1);
        let t0 = Instant::now();
        let r = ch.pop_timeout(Duration::from_millis(30)).unwrap();
        assert_eq!(r, None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn drain_discards_queued() {
        let ch = Channel::new(8);
        for i in 0..5 {
            ch.push(i).unwrap();
        }
        assert_eq!(ch.drain(), 5);
        assert!(ch.is_empty());
    }
}
