//! `Session`: the library's single entry point for training, evaluation,
//! and benches.
//!
//! ```no_run
//! use walle::algo::ppo::Ppo;
//! use walle::session::{Infer, Session};
//! use walle::config::InferShards;
//!
//! let result = Session::builder()
//!     .env("halfcheetah")
//!     .samplers(10)
//!     .algo(Ppo::default())
//!     .infer(Infer::Shared { shards: InferShards::Auto })
//!     .build()?
//!     .run()?;
//! # anyhow::Ok(())
//! ```
//!
//! The builder collects knobs in call order on top of the env preset
//! (`.env(name)` picks `TrainConfig::preset(name)` unless an explicit
//! `.config(...)` base was given), folds a *customized* algorithm
//! instance's hyper-parameters into the config via
//! [`Algorithm::apply_to`] (a plain `X::default()` only selects the
//! algorithm, preserving preset-tuned sections), and
//! validates the combination at [`SessionBuilder::build`] — invalid
//! combos (PPO-only knobs under DDPG/TD3/SAC, off-policy replay knobs
//! under PPO, more inference shards than samplers, zero-env specs) fail
//! there with actionable errors instead of deep inside the run. The built [`Session`] exposes:
//!
//! * [`Session::run`] — the full coordinator (N samplers, optional
//!   sharded inference pool, learner), writing `metrics.csv`,
//!   `config.json`, `params.bin`, and `inference.json` when an
//!   `.out_dir(..)` was configured;
//! * [`Session::evaluate`] — deterministic rollouts through the SAME
//!   trait-constructed actor the training path uses;
//! * [`Session::spec`] — the resolved [`SessionSpec`] (`walle info`
//!   renders it; it round-trips to JSON).
//!
//! `main.rs` is a thin CLI adapter over this module; tests and benches
//! can drive identical runs programmatically.

use crate::algo::api::{algorithm_from_config, Algorithm};
use crate::config::{Backend, InferEpoch, InferShards, InferWait, InferenceMode, TrainConfig};
use crate::coordinator::eval::{self, EvalResult};
use crate::coordinator::metrics::MetricsLog;
use crate::coordinator::orchestrator::{self, RunResult};
use crate::runtime::make_factory;
use crate::util::json::Json;

/// Inference placement for the builder (`.infer(...)`): mirrors
/// `--inference-mode` + `--infer-shards`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Infer {
    /// One private backend per worker (the default).
    Local,
    /// The sharded inference pool batches all workers' rows into
    /// fleet-wide forwards; `shards` sizes it (`InferShards::Auto` =
    /// one shard per ~8 workers, capped at half the cores).
    Shared { shards: InferShards },
}

type ConfigOp = Box<dyn FnOnce(&mut TrainConfig)>;

/// Builder for a [`Session`]. Knobs apply in call order; `build()`
/// validates the resolved combination.
#[derive(Default)]
pub struct SessionBuilder {
    preset_env: Option<String>,
    base: Option<TrainConfig>,
    algo: Option<Box<dyn Algorithm>>,
    ops: Vec<ConfigOp>,
    /// PPO-only knobs the caller set explicitly (rejected at build time
    /// when the session algorithm is not PPO).
    ppo_only_knobs: Vec<&'static str>,
    out_dir: Option<String>,
    quiet: bool,
}

impl SessionBuilder {
    fn set(mut self, op: impl FnOnce(&mut TrainConfig) + 'static) -> Self {
        self.ops.push(Box::new(op));
        self
    }

    /// Environment name; without an explicit `.config(...)` base this
    /// also selects `TrainConfig::preset(name)` as the starting point.
    pub fn env(mut self, name: &str) -> Self {
        self.preset_env = Some(name.to_string());
        let n = name.to_string();
        self.set(move |c| c.env = n)
    }

    /// Start from an explicit config instead of the env preset (the CLI
    /// path: flags have already been folded in).
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.base = Some(cfg);
        self
    }

    /// The algorithm instance. Selects the algorithm for the session;
    /// if the instance carries non-default hyper-parameters (e.g.
    /// `Td3 { cfg: Td3Cfg { policy_delay: 3, .. } }`) they are folded
    /// into the config, overriding the preset/`.config` section for
    /// that algorithm. A plain `X::default()` only selects the
    /// algorithm and leaves the base config's (possibly preset-tuned)
    /// hyper-parameter section untouched.
    pub fn algo<A: Algorithm + 'static>(mut self, algo: A) -> Self {
        self.algo = Some(Box::new(algo));
        self
    }

    /// Compute backend (`Backend::Native` is the artifact-free default).
    pub fn backend(self, b: Backend) -> Self {
        self.set(move |c| c.backend = b)
    }

    /// Root RNG seed.
    pub fn seed(self, seed: u64) -> Self {
        self.set(move |c| c.seed = seed)
    }

    /// Parallel sampler workers (the paper's N).
    pub fn samplers(self, n: usize) -> Self {
        self.set(move |c| c.samplers = n)
    }

    /// Vectorized envs per sampler worker (M).
    pub fn envs_per_sampler(self, m: usize) -> Self {
        self.set(move |c| c.envs_per_sampler = m)
    }

    /// Training iterations.
    pub fn iterations(self, n: usize) -> Self {
        self.set(move |c| c.iterations = n)
    }

    /// Samples collected per iteration (paper: 20,000).
    pub fn samples_per_iter(self, n: usize) -> Self {
        self.set(move |c| c.samples_per_iter = n)
    }

    /// Steps per experience chunk.
    pub fn chunk_steps(self, n: usize) -> Self {
        self.set(move |c| c.chunk_steps = n)
    }

    /// Experience-queue capacity in chunks.
    pub fn queue_capacity(self, n: usize) -> Self {
        self.set(move |c| c.queue_capacity = n)
    }

    /// Hidden-layer widths of the policy/value MLPs.
    pub fn hidden(self, widths: &[usize]) -> Self {
        let w = widths.to_vec();
        self.set(move |c| c.hidden = w)
    }

    /// Learning-signal reward scale.
    pub fn reward_scale(self, s: f32) -> Self {
        self.set(move |c| c.reward_scale = s)
    }

    /// Synchronous barrier mode (the ablation baseline; async is the
    /// paper's architecture and the default).
    pub fn sync(self) -> Self {
        self.set(|c| c.async_mode = false)
    }

    /// Inference placement (local per-worker backends vs the sharded
    /// shared pool).
    pub fn infer(self, infer: Infer) -> Self {
        self.set(move |c| match infer {
            Infer::Local => c.inference_mode = InferenceMode::Local,
            Infer::Shared { shards } => {
                c.inference_mode = InferenceMode::Shared;
                c.infer_shards = shards;
            }
        })
    }

    /// Shared-mode straggler-cut policy.
    pub fn infer_wait(self, wait: InferWait) -> Self {
        self.set(move |c| c.infer_wait = wait)
    }

    /// Shared-mode policy-version adoption (pool-wide epoch gate vs
    /// per-shard observation).
    pub fn infer_epoch(self, epoch: InferEpoch) -> Self {
        self.set(move |c| c.infer_epoch = epoch)
    }

    /// Inference-path numeric precision. `InferPrecision::Int8` ships an
    /// int8-quantized copy of each published actor snapshot to the
    /// shared inference pool (the learner stays f32); requires the
    /// native backend and shared inference mode.
    pub fn infer_precision(self, p: crate::config::InferPrecision) -> Self {
        self.set(move |c| c.infer_precision = p)
    }

    /// Kernel determinism mode: `KernelsCfg::Exact` (default) keeps the
    /// SIMD microkernels bitwise-identical to the scalar reference;
    /// `KernelsCfg::Fast` enables FMA register tiling (~1e-6 relative
    /// drift, higher throughput).
    pub fn kernels(self, k: crate::config::KernelsCfg) -> Self {
        self.set(move |c| c.kernels = k)
    }

    /// Env stepping engine: `EnvEngineCfg::Auto` (default) resolves to
    /// the structure-of-arrays batched `step_all` sweep;
    /// `EnvEngineCfg::Scalar` forces the legacy per-env loop. The two
    /// are bitwise interchangeable under exact kernels, so this is a
    /// throughput knob.
    pub fn env_engine(self, e: crate::config::EnvEngineCfg) -> Self {
        self.set(move |c| c.env_engine = e)
    }

    /// Data-parallel PPO learner shards (§6.2). PPO-only: rejected at
    /// build time under any other algorithm.
    pub fn learner_shards(mut self, n: usize) -> Self {
        self.ppo_only_knobs.push("learner_shards");
        self.set(move |c| c.learner_shards = n)
    }

    /// Async-mode staleness bound on PPO gradient data. PPO-only: the
    /// replay-based learners (DDPG, TD3) consume every chunk.
    pub fn max_staleness(mut self, n: u64) -> Self {
        self.ppo_only_knobs.push("max_staleness");
        self.set(move |c| c.max_staleness = n)
    }

    /// Replay-buffer shards (one striped-lock lane per sampler is the
    /// intended shape). Off-policy only: the sampled minibatch SET is a
    /// pure function of (seed, draw index, contents) and independent of
    /// the shard count, so this is a throughput knob, not a semantics
    /// knob. Rejected at build time under PPO.
    pub fn replay_shards(self, n: usize) -> Self {
        self.set(move |c| c.replay_shards = n)
    }

    /// Parallel learner threads L for the off-policy minibatch gradient.
    /// Grained map + fixed-order tree reduction keeps published
    /// parameters bitwise identical for any L. Off-policy native-backend
    /// only: rejected at build time under PPO or the XLA backend.
    pub fn learner_threads(self, n: usize) -> Self {
        self.set(move |c| c.learner_threads = n)
    }

    /// Replay sampling strategy: uniform (default) or prioritized
    /// (proportional TD-error, with normalized importance weights).
    /// Off-policy only; rejected at build time under PPO.
    pub fn replay_strategy(self, s: crate::config::ReplayStrategy) -> Self {
        self.set(move |c| c.replay_strategy = s)
    }

    /// Write a durable checkpoint after every `every`-th iteration into
    /// `dir` (learner state + per-worker RNG/env snapshots; see
    /// `runtime::checkpoint`). `every = 0` disables checkpointing.
    pub fn checkpoint(self, every: usize, dir: &str) -> Self {
        let d = dir.to_string();
        self.set(move |c| {
            c.checkpoint_every = every;
            c.checkpoint_dir = d;
        })
    }

    /// Resume training from the newest checkpoint in `dir`. The
    /// checkpoint's fingerprint (env, algorithm, fleet shape, seed) must
    /// match this session's config.
    pub fn resume(self, dir: &str) -> Self {
        let d = dir.to_string();
        self.set(move |c| c.resume = d)
    }

    /// Supervisor respawn budget per component after a panic (default 2;
    /// 0 = fail fast on the first panic).
    pub fn max_restarts(self, n: usize) -> Self {
        self.set(move |c| c.max_restarts = n)
    }

    /// Fleet topology: `Threads` (default) runs samplers as in-process
    /// threads; `Procs` runs each sampler as a `walle sample` child
    /// process served by an in-process policy daemon over a Unix socket
    /// (requires `--inference-mode shared`). Per-env chunk streams are
    /// bitwise identical either way.
    pub fn fleet_mode(self, m: crate::config::FleetMode) -> Self {
        self.set(move |c| c.fleet_mode = m)
    }

    /// Deterministic fault plan for chaos testing, e.g.
    /// `"worker:1@tick:500,shard:0@dispatch:40"` or
    /// `"random:seed=7,count=2,horizon=1000"`. Empty = no injection.
    pub fn fault_inject(self, spec: &str) -> Self {
        let s = spec.to_string();
        self.set(move |c| c.fault_inject = s)
    }

    /// Shared-pool scheduled epoch flips: flip the pool epoch gate every
    /// `k` fleet dispatches instead of at publish boundaries (0 = off;
    /// requires shared inference with the pool epoch gate).
    pub fn flip_schedule(self, k: u64) -> Self {
        self.set(move |c| c.flip_schedule = k)
    }

    /// Artifacts directory for the XLA backend.
    pub fn artifacts_dir(self, dir: &str) -> Self {
        let d = dir.to_string();
        self.set(move |c| c.artifacts_dir = d)
    }

    /// Write run outputs (`metrics.csv`, `config.json`, `params.bin`,
    /// `inference.json`) under this directory.
    pub fn out_dir(mut self, dir: &str) -> Self {
        self.out_dir = Some(dir.to_string());
        self
    }

    /// Suppress per-iteration stdout logging (tests, sweeps).
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Resolve and validate the session. Errors are actionable: they
    /// name the offending knob and what to change.
    pub fn build(self) -> anyhow::Result<Session> {
        let mut cfg = match self.base {
            Some(c) => c,
            None => TrainConfig::preset(self.preset_env.as_deref().unwrap_or("halfcheetah")),
        };
        if let Some(algo) = &self.algo {
            // Probe whether the instance carries non-default
            // hyper-parameters (apply_to only touches cfg.algo + its own
            // section, so comparing against a default config with only
            // the algo set detects exactly that). A default-configured
            // instance — `.algo(Ppo::default())` — selects the algorithm
            // WITHOUT clobbering the base's preset-tuned section; a
            // customized instance overrides it.
            let mut probe = TrainConfig::default();
            algo.apply_to(&mut probe);
            let default_probe = TrainConfig {
                algo: probe.algo,
                ..TrainConfig::default()
            };
            if probe == default_probe {
                cfg.algo = probe.algo;
            } else {
                algo.apply_to(&mut cfg);
            }
        }
        for op in self.ops {
            op(&mut cfg);
        }
        // cfg.algo == algo.id() holds by construction: apply_to wrote
        // the instance's identity into cfg and no builder op sets
        // cfg.algo (an `.algo(..)` call deliberately overrides whatever
        // algorithm a `.config(..)` base carried — documented above).
        let algo = match self.algo {
            Some(a) => a,
            None => algorithm_from_config(&cfg),
        };
        if algo.id() != crate::config::Algo::Ppo && !self.ppo_only_knobs.is_empty() {
            anyhow::bail!(
                "{} {} PPO-only (data-parallel gradient sharding / gradient-data \
                 staleness bounds have no meaning for a replay learner), but the \
                 session algorithm is {} — drop the knob or use .algo(Ppo::default())",
                self.ppo_only_knobs.join(", "),
                if self.ppo_only_knobs.len() == 1 { "is" } else { "are" },
                algo.name()
            );
        }
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        algo.validate(&cfg).map_err(|e| anyhow::anyhow!(e))?;
        let spec = SessionSpec::resolve(algo.as_ref(), &cfg);
        Ok(Session {
            cfg,
            algo,
            spec,
            out_dir: self.out_dir,
            quiet: self.quiet,
        })
    }
}

/// A fully resolved, validated run description — build one with
/// [`Session::builder`] or [`Session::from_config`].
pub struct Session {
    cfg: TrainConfig,
    algo: Box<dyn Algorithm>,
    spec: SessionSpec,
    out_dir: Option<String>,
    quiet: bool,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Build a session straight from a `TrainConfig` (the CLI adapter
    /// path; the algorithm is resolved through the registry).
    pub fn from_config(cfg: TrainConfig) -> anyhow::Result<Session> {
        Session::builder().config(cfg).build()
    }

    /// The resolved config (single source of truth for the run).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// The algorithm every pipeline stage dispatches through.
    pub fn algorithm(&self) -> &dyn Algorithm {
        self.algo.as_ref()
    }

    /// The resolved spec (what `walle info` renders).
    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    /// Run the full training session. With an `.out_dir(..)` configured,
    /// also writes `config.json`, `metrics.csv`, `params.bin`, and (in
    /// shared inference mode) `inference.json` there.
    pub fn run(&self) -> anyhow::Result<RunResult> {
        self.run_inner(None)
    }

    /// [`Session::run`] watching an external shutdown flag: flip it from
    /// a SIGINT/SIGTERM handler and the fleet drains through the normal
    /// stop/queue-close paths instead of dying mid-write.
    pub fn run_watched(
        &self,
        shutdown: &std::sync::atomic::AtomicBool,
    ) -> anyhow::Result<RunResult> {
        self.run_inner(Some(shutdown))
    }

    fn run_inner(
        &self,
        shutdown: Option<&std::sync::atomic::AtomicBool>,
    ) -> anyhow::Result<RunResult> {
        let factory = make_factory(&self.cfg)?;
        let mut log = if self.quiet {
            MetricsLog::quiet()
        } else {
            MetricsLog::new()
        };
        if let Some(dir) = &self.out_dir {
            std::fs::create_dir_all(dir)?;
            self.cfg.save(&format!("{dir}/config.json"))?;
            log = log.with_csv(&format!("{dir}/metrics.csv"))?;
        }
        let result = orchestrator::run_with_watched(
            self.algo.as_ref(),
            &self.cfg,
            factory.as_ref(),
            &mut log,
            shutdown,
        )?;
        if let Some(dir) = &self.out_dir {
            save_params(&format!("{dir}/params.bin"), &result.final_params)?;
            if let Some(rep) = &result.infer {
                std::fs::write(format!("{dir}/inference.json"), rep.to_json().to_string())?;
            }
        }
        Ok(result)
    }

    /// Deterministically evaluate `params` over `episodes` mean-action
    /// rollouts through the SAME trait-constructed actor the training
    /// path uses, with an explicit observation-normalizer snapshot —
    /// pass `RunResult::final_norm` to reproduce exactly what the
    /// trained policy saw.
    pub fn evaluate_with_norm(
        &self,
        params: &[f32],
        norm: &crate::algo::normalizer::NormSnapshot,
        episodes: usize,
    ) -> anyhow::Result<EvalResult> {
        let factory = make_factory(&self.cfg)?;
        let want = self.algo.policy_param_count(factory.as_ref(), &self.cfg);
        anyhow::ensure!(
            params.len() == want,
            "checkpoint has {} params, {} on {} expects {}",
            params.len(),
            self.algo.name(),
            self.cfg.env,
            want
        );
        eval::evaluate_algo(
            self.algo.as_ref(),
            factory.as_ref(),
            &self.cfg.env,
            params,
            norm,
            episodes,
            self.cfg.seed,
        )
    }

    /// [`Session::evaluate_with_norm`] with the identity normalizer —
    /// the only faithful choice for a bare checkpoint file, which
    /// carries parameters but NOT the training-time normalizer snapshot
    /// (`walle eval`'s long-standing limitation). For in-process results
    /// prefer `evaluate_with_norm(&r.final_params, &r.final_norm, ..)`.
    pub fn evaluate(&self, params: &[f32], episodes: usize) -> anyhow::Result<EvalResult> {
        let (obs_dim, _) = crate::env::registry::env_dims(&self.cfg.env)
            .ok_or_else(|| anyhow::anyhow!("unknown env {:?}", self.cfg.env))?;
        let norm = crate::algo::normalizer::NormSnapshot::identity(obs_dim);
        self.evaluate_with_norm(params, &norm, episodes)
    }
}

// ----------------------------------------------------------------- spec

/// Resolved inference topology of a session.
#[derive(Debug, Clone, PartialEq)]
pub struct InferTopology {
    /// `"local"` or `"shared"`.
    pub mode: String,
    /// Resolved shard count S (None in local mode; Auto is resolved
    /// against the sampler count and this machine's cores).
    pub shards: Option<usize>,
    /// Straggler-cut policy spelling (`"adaptive"` / `"fixed:<us>"`).
    pub wait: String,
    /// Version-adoption mode (`"pool"` / `"shard"`).
    pub epoch: String,
}

/// The resolved, render-ready description of a session: algorithm name +
/// hyper-parameters (via the [`Algorithm`] trait, no hard-coded `Algo::`
/// matches) + inference topology, anchored on the underlying config —
/// the ONLY source of truth; everything else here is resolved from it by
/// [`SessionSpec::resolve`]. Round-trips to JSON
/// ([`SessionSpec::to_json`] / [`SessionSpec::from_json`], which also
/// accepts configs spelled with the legacy `infer_max_wait_us` key).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSpec {
    /// Algorithm name, resolved through the trait.
    pub algo: String,
    /// The algorithm's hyper-parameters, rendered through the trait.
    pub hyperparams: Json,
    /// Resolved inference topology (Auto shard counts made concrete).
    pub infer: InferTopology,
    /// The full underlying config (the JSON round-trip anchor; fleet
    /// shape, env, backend etc. are read from here).
    pub config: TrainConfig,
}

impl SessionSpec {
    /// Resolve a spec from a config through the algorithm trait.
    pub fn resolve(algo: &dyn Algorithm, cfg: &TrainConfig) -> SessionSpec {
        let shards = match cfg.inference_mode {
            InferenceMode::Local => None,
            InferenceMode::Shared => Some(cfg.infer_shards.resolve(cfg.samplers)),
        };
        SessionSpec {
            algo: algo.name().to_string(),
            hyperparams: algo.hyperparams(cfg),
            infer: InferTopology {
                mode: cfg.inference_mode.name().to_string(),
                shards,
                wait: cfg.infer_wait.name(),
                epoch: cfg.infer_epoch.name().to_string(),
            },
            config: cfg.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut infer = vec![("mode", Json::Str(self.infer.mode.clone()))];
        if let Some(s) = self.infer.shards {
            infer.push(("shards", Json::Num(s as f64)));
        }
        infer.push(("wait", Json::Str(self.infer.wait.clone())));
        infer.push(("epoch", Json::Str(self.infer.epoch.clone())));
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            (
                "total_envs",
                Json::Num((self.config.samplers * self.config.envs_per_sampler) as f64),
            ),
            ("hyperparams", self.hyperparams.clone()),
            ("inference", Json::obj(infer)),
            ("config", self.config.to_json()),
        ])
    }

    /// Rebuild a spec from its JSON form: the embedded `config` object
    /// (or, as a fallback, a bare `TrainConfig` JSON — including ones
    /// spelled with the legacy `infer_max_wait_us` key) is parsed and
    /// re-resolved through the registry, so derived fields can never
    /// drift from the config.
    pub fn from_json(j: &Json) -> anyhow::Result<SessionSpec> {
        let cfg_json = j.opt("config").unwrap_or(j);
        let cfg = TrainConfig::from_json(cfg_json)?;
        let algo = algorithm_from_config(&cfg);
        Ok(SessionSpec::resolve(algo.as_ref(), &cfg))
    }

    /// Human-readable rendering (the `walle info` body).
    pub fn render(&self) -> String {
        let cfg = &self.config;
        let mut out = String::new();
        out.push_str(&format!(
            "session: {} on {} ({} backend, {} mode)\n",
            self.algo,
            cfg.env,
            cfg.backend.name(),
            if cfg.async_mode { "async" } else { "sync" }
        ));
        out.push_str(&format!(
            "fleet:   {} samplers x {} envs = {} lockstep envs\n",
            cfg.samplers,
            cfg.envs_per_sampler,
            cfg.samplers * cfg.envs_per_sampler
        ));
        match self.infer.shards {
            Some(s) => out.push_str(&format!(
                "infer:   shared pool, {} shard(s), wait {}, epoch {}\n",
                s, self.infer.wait, self.infer.epoch
            )),
            None => out.push_str("infer:   local (one private backend per worker)\n"),
        }
        out.push_str(&format!("{}:     {}\n", self.algo, self.hyperparams));
        out
    }
}

// ------------------------------------------------------- checkpoint I/O

/// Save a flat f32 parameter vector as little-endian bytes.
pub fn save_params(path: &str, params: &[f32]) -> anyhow::Result<()> {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

/// Load a checkpoint written by [`save_params`].
pub fn load_params(path: &str) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "corrupt checkpoint");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
