//! # WALL-E: An Efficient Reinforcement Learning Research Framework
//!
//! Reproduction of Xu, Zhang & Zhao (2018): parallel rollout samplers
//! feeding an asynchronous PPO learner through an experience queue, with
//! policy parameters broadcast back through a policy queue.
//!
//! Three-layer architecture (DESIGN.md):
//! * **L3 (this crate)** — the coordinator: sampler workers, queues,
//!   learner, metrics, CLI, plus every substrate (physics envs, native nn,
//!   JSON/CLI/RNG utilities).
//! * **L2 (JAX, build-time)** — policy/value networks + PPO/DDPG update
//!   rules, AOT-lowered to HLO text artifacts.
//! * **L1 (Pallas, build-time)** — fused dense, GAE-scan and Adam kernels
//!   inside those artifacts.
//!
//! At runtime Python is never on the path: `runtime::XlaBackend` loads the
//! HLO artifacts via PJRT; `runtime::NativeBackend` is the artifact-free
//! pure-Rust mirror used for tests and quick starts.

pub mod algo;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod nn;
pub mod replay;
pub mod runtime;
pub mod util;
