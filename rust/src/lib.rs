//! # WALL-E: An Efficient Reinforcement Learning Research Framework
//!
//! Reproduction of Xu, Zhang & Zhao (2018): parallel rollout samplers
//! feeding an asynchronous PPO learner through an experience queue, with
//! policy parameters broadcast back through a policy queue.
//!
//! Three-layer architecture (DESIGN.md):
//! * **L3 (this crate)** — the coordinator: sampler workers, queues,
//!   learner, metrics, CLI, plus every substrate (physics envs, native nn,
//!   JSON/CLI/RNG utilities).
//! * **L2 (JAX, build-time)** — policy/value networks + PPO/DDPG update
//!   rules, AOT-lowered to HLO text artifacts.
//! * **L1 (Pallas, build-time)** — fused dense, GAE-scan and Adam kernels
//!   inside those artifacts.
//!
//! At runtime Python is never on the path: `runtime::XlaBackend` loads the
//! HLO artifacts via PJRT; `runtime::NativeBackend` is the artifact-free
//! pure-Rust mirror used for tests and quick starts.
//!
//! The library entry point is [`session::Session`]:
//!
//! ```no_run
//! use walle::algo::ppo::Ppo;
//! use walle::session::Session;
//!
//! let result = Session::builder()
//!     .env("pendulum")
//!     .samplers(4)
//!     .algo(Ppo::default())
//!     .build()?
//!     .run()?;
//! # anyhow::Ok(())
//! ```
//!
//! Every pipeline stage dispatches through the [`algo::api::Algorithm`]
//! trait (PPO, DDPG, TD3, SAC ship in-tree); `docs/API.md` documents the
//! trait contract, the builder, and the add-your-own-algorithm
//! walkthrough. The off-policy learners draw from a sharded replay
//! buffer ([`replay::shard`]) and can spread the minibatch gradient over
//! `--learner-threads` workers with a fixed-order tree reduction, so
//! published parameters stay bitwise identical for any thread count.

pub mod algo;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod nn;
pub mod replay;
pub mod runtime;
pub mod session;
pub mod util;
