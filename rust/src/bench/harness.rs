//! Minimal benchmarking harness (criterion-style warmup + timed samples)
//! used by the `[[bench]]` targets (`harness = false`).

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one benchmark: per-iteration seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// criterion-like one-liner: name, mean ± std, min, p50.
    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<40} {:>12} ± {:>10}  (min {:>12}, p50 {:>12}, n={})",
            self.name,
            fmt_secs(s.mean),
            fmt_secs(s.std),
            fmt_secs(s.min),
            fmt_secs(s.p50),
            s.n
        )
    }
}

/// Human-friendly seconds formatting (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner: `warmup` untimed runs then `samples` timed runs of
/// `f(iters_per_sample)`; reports seconds per single iteration.
pub struct Bench {
    name: String,
    warmup: usize,
    samples: usize,
    iters_per_sample: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            warmup: 2,
            samples: 10,
            iters_per_sample: 1,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    pub fn iters_per_sample(mut self, n: usize) -> Self {
        self.iters_per_sample = n.max(1);
        self
    }

    /// Run and report to stdout; returns per-iteration timing samples.
    pub fn run(self, mut f: impl FnMut()) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        let result = BenchResult {
            name: self.name,
            samples,
        };
        println!("{}", result.report_line());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let r = Bench::new("sleep1ms")
            .warmup(0)
            .samples(3)
            .run(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        let s = r.summary();
        assert!(s.mean >= 0.001 && s.mean < 0.05, "mean={}", s.mean);
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn iters_per_sample_divides() {
        let r = Bench::new("noop").warmup(0).samples(2).iters_per_sample(100).run(|| {});
        assert!(r.summary().mean < 1e-3);
    }
}
