//! Benchmark substrate: a small timing harness (the offline crate set has
//! no criterion) and the figure-series generators that regenerate every
//! figure in the paper's evaluation (Figs 3–7).

pub mod figures;
pub mod harness;
