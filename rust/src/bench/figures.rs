//! Figure-series generators: regenerate every figure in the paper's
//! evaluation section from live runs of the coordinator.
//!
//! | Paper figure | Generator | Series |
//! |---|---|---|
//! | Fig 3 | [`fig3_return_curves`] | avg return vs iteration, N=1 vs N=10 |
//! | Fig 4 | [`scaling_sweep`] | rollout (collect) time vs N |
//! | Fig 5 | [`scaling_sweep`] + [`speedups`] | collection speedup vs N |
//! | Fig 6 | [`scaling_sweep`] | % learn vs % collect time vs N |
//! | Fig 7 | [`scaling_sweep`] | learn time per iteration vs N |
//!
//! Absolute numbers differ from the paper (their testbed: Python + MuJoCo
//! on a big CPU server; ours: Rust + the physics substrate), but the
//! *shapes* — monotone decrease, near-linear (not over-linear) speedup,
//! growing learn fraction, flat learn time — are the reproduction targets
//! recorded in EXPERIMENTS.md.

use crate::config::TrainConfig;
use crate::coordinator::metrics::{IterationMetrics, MetricsLog};
use crate::coordinator::orchestrator;
use crate::runtime::BackendFactory;
use crate::util::stats::linreg;
use std::io::Write;

/// One row of the Fig 4–7 sweep.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub n: usize,
    /// Mean rollout/collection seconds per iteration (steady state),
    /// virtual-core timing: max-over-workers busy time (== wall time on a
    /// testbed with >= N cores; see DESIGN.md §3 hardware substitution).
    pub collect_secs: f64,
    /// Measured wall-clock collect time on *this* testbed (drain time;
    /// reported alongside for transparency).
    pub wall_collect_secs: f64,
    /// Mean policy-learning seconds per iteration.
    pub learn_secs: f64,
    pub collect_frac: f64,
    pub learn_frac: f64,
    pub mean_return: f32,
    /// Shared inference only: mean fraction of the fleet mega-batch
    /// filled per forward (None in local mode).
    pub mean_batch_fill: Option<f64>,
}

/// Run the N-sweep behind Figs 4–7: same sample budget per iteration,
/// varying sampler count. `skip` leading iterations are dropped from the
/// steady-state means (compile + warmup noise).
pub fn scaling_sweep(
    base: &TrainConfig,
    factory_for: &dyn Fn(&TrainConfig) -> anyhow::Result<Box<dyn BackendFactory>>,
    ns: &[usize],
    skip: usize,
) -> anyhow::Result<Vec<SweepRow>> {
    let mut rows = Vec::new();
    for &n in ns {
        let mut cfg = base.clone();
        cfg.samplers = n;
        let factory = factory_for(&cfg)?;
        let mut log = MetricsLog::quiet();
        let result = orchestrator::run(&cfg, factory.as_ref(), &mut log)?;
        let tail: Vec<&IterationMetrics> = result.metrics.iter().skip(skip).collect();
        anyhow::ensure!(!tail.is_empty(), "sweep needs iterations > skip");
        let collect =
            tail.iter().map(|m| m.virtual_collect_secs).sum::<f64>() / tail.len() as f64;
        let wall_collect =
            tail.iter().map(|m| m.collect_secs).sum::<f64>() / tail.len() as f64;
        let learn = tail.iter().map(|m| m.learn_secs).sum::<f64>() / tail.len() as f64;
        let mean_return = crate::util::stats::mean_f32(
            &tail.iter().map(|m| m.mean_return).collect::<Vec<_>>(),
        );
        rows.push(SweepRow {
            n,
            collect_secs: collect,
            wall_collect_secs: wall_collect,
            learn_secs: learn,
            collect_frac: collect / (collect + learn),
            learn_frac: learn / (collect + learn),
            mean_return,
            mean_batch_fill: result.infer.as_ref().map(|r| r.mean_fill()),
        });
        crate::log_info!(
            "sweep N={n}: collect {collect:.3}s learn {learn:.3}s return {mean_return:.1}"
        );
    }
    Ok(rows)
}

/// Fig 5 series: speedup(N) = T_collect(1) / T_collect(N), plus the linear
/// fit slope and R² (the paper's "near-linear, not over-linear" claim).
pub fn speedups(rows: &[SweepRow]) -> (Vec<(usize, f64)>, f64, f64) {
    let t1 = rows
        .iter()
        .find(|r| r.n == 1)
        .map(|r| r.collect_secs)
        .unwrap_or_else(|| rows[0].collect_secs * rows[0].n as f64);
    let series: Vec<(usize, f64)> = rows
        .iter()
        .map(|r| (r.n, t1 / r.collect_secs))
        .collect();
    let xs: Vec<f64> = series.iter().map(|&(n, _)| n as f64).collect();
    let ys: Vec<f64> = series.iter().map(|&(_, s)| s).collect();
    let (_, slope, r2) = linreg(&xs, &ys);
    (series, slope, r2)
}

/// Fig 3: full return-vs-iteration curves for each N.
pub fn fig3_return_curves(
    base: &TrainConfig,
    factory_for: &dyn Fn(&TrainConfig) -> anyhow::Result<Box<dyn BackendFactory>>,
    ns: &[usize],
) -> anyhow::Result<Vec<(usize, Vec<IterationMetrics>)>> {
    let mut out = Vec::new();
    for &n in ns {
        let mut cfg = base.clone();
        cfg.samplers = n;
        let factory = factory_for(&cfg)?;
        let mut log = MetricsLog::quiet();
        let result = orchestrator::run(&cfg, factory.as_ref(), &mut log)?;
        out.push((n, result.metrics));
    }
    Ok(out)
}

// ------------------------------------------------------------- CSV output

fn create(path: &str) -> anyhow::Result<std::io::BufWriter<std::fs::File>> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    Ok(std::io::BufWriter::new(std::fs::File::create(path)?))
}

/// Write the sweep as `fig4_rollout_time.csv`, `fig5_speedup.csv`,
/// `fig6_time_breakdown.csv`, `fig7_learn_time.csv` under `out_dir`.
pub fn write_sweep_csvs(rows: &[SweepRow], out_dir: &str) -> anyhow::Result<()> {
    let mut f4 = create(&format!("{out_dir}/fig4_rollout_time.csv"))?;
    writeln!(f4, "n,collect_secs,wall_collect_secs")?;
    for r in rows {
        writeln!(f4, "{},{:.6},{:.6}", r.n, r.collect_secs, r.wall_collect_secs)?;
    }
    let (series, slope, r2) = speedups(rows);
    let mut f5 = create(&format!("{out_dir}/fig5_speedup.csv"))?;
    writeln!(f5, "n,speedup,ideal")?;
    for (n, s) in &series {
        writeln!(f5, "{n},{s:.4},{n}")?;
    }
    writeln!(f5, "# linear fit slope={slope:.4} r2={r2:.4}")?;
    let mut f6 = create(&format!("{out_dir}/fig6_time_breakdown.csv"))?;
    writeln!(f6, "n,collect_frac,learn_frac")?;
    for r in rows {
        writeln!(f6, "{},{:.4},{:.4}", r.n, r.collect_frac, r.learn_frac)?;
    }
    let mut f7 = create(&format!("{out_dir}/fig7_learn_time.csv"))?;
    writeln!(f7, "n,learn_secs")?;
    for r in rows {
        writeln!(f7, "{},{:.6}", r.n, r.learn_secs)?;
    }
    Ok(())
}

/// Write Fig 3 curves as `fig3_return.csv` (long format).
pub fn write_fig3_csv(
    curves: &[(usize, Vec<IterationMetrics>)],
    out_dir: &str,
) -> anyhow::Result<()> {
    let mut f = create(&format!("{out_dir}/fig3_return.csv"))?;
    writeln!(f, "n,iter,wall_secs,virtual_wall_secs,total_steps,mean_return")?;
    for (n, ms) in curves {
        let mut vwall = 0.0f64;
        for m in ms {
            vwall += m.virtual_collect_secs + m.learn_secs;
            writeln!(
                f,
                "{},{},{:.3},{:.3},{},{:.4}",
                n, m.iter, m.wall_secs, vwall, m.total_steps, m.mean_return
            )?;
        }
    }
    Ok(())
}

/// Pretty-print a sweep table (the bench binaries' stdout report).
pub fn print_sweep_table(rows: &[SweepRow], title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:>4} {:>14} {:>14} {:>10} {:>10} {:>12}",
        "N", "collect (s)", "learn (s)", "%collect", "%learn", "return"
    );
    for r in rows {
        println!(
            "{:>4} {:>14.4} {:>14.4} {:>9.1}% {:>9.1}% {:>12.2}",
            r.n,
            r.collect_secs,
            r.learn_secs,
            100.0 * r.collect_frac,
            100.0 * r.learn_frac,
            r.mean_return
        );
    }
    let (series, slope, r2) = speedups(rows);
    print!("speedup: ");
    for (n, s) in &series {
        print!("N={n}:{s:.2}x ");
    }
    println!("(fit slope {slope:.2}, r² {r2:.3})");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, TrainConfig};
    use crate::runtime::native_backend::NativeFactory;

    fn tiny_base() -> TrainConfig {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.backend = Backend::Native;
        cfg.samples_per_iter = 400;
        cfg.iterations = 2;
        cfg.chunk_steps = 100;
        cfg.hidden = vec![8, 8];
        cfg.ppo.epochs = 1;
        cfg.ppo.minibatch = 128;
        cfg
    }

    fn factory_for(cfg: &TrainConfig) -> anyhow::Result<Box<dyn BackendFactory>> {
        Ok(Box::new(NativeFactory::new(
            3,
            1,
            &cfg.hidden,
            cfg.ppo.clone(),
            cfg.ddpg.clone(),
        )))
    }

    #[test]
    fn sweep_produces_row_per_n() {
        let rows = scaling_sweep(&tiny_base(), &factory_for, &[1, 2], 0).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].n, 1);
        assert!(rows.iter().all(|r| r.collect_secs > 0.0));
        assert!(rows
            .iter()
            .all(|r| (r.collect_frac + r.learn_frac - 1.0).abs() < 1e-9));
    }

    #[test]
    fn shared_inference_sweep_records_batch_fill() {
        let mut base = tiny_base();
        base.inference_mode = crate::config::InferenceMode::Shared;
        base.infer_wait = crate::config::InferWait::Fixed(500);
        let rows = scaling_sweep(&base, &factory_for, &[2], 0).unwrap();
        let fill = rows[0].mean_batch_fill.expect("shared sweep must record fill");
        assert!(fill > 0.0 && fill <= 1.0 + 1e-9, "fill {fill}");
        // local sweeps leave it unset
        let rows = scaling_sweep(&tiny_base(), &factory_for, &[1], 0).unwrap();
        assert!(rows[0].mean_batch_fill.is_none());
    }

    #[test]
    fn speedups_normalize_to_n1() {
        let rows = vec![
            SweepRow {
                n: 1,
                collect_secs: 8.0,
                wall_collect_secs: 8.0,
                learn_secs: 1.0,
                collect_frac: 8.0 / 9.0,
                learn_frac: 1.0 / 9.0,
                mean_return: 0.0,
                mean_batch_fill: None,
            },
            SweepRow {
                n: 4,
                collect_secs: 2.0,
                wall_collect_secs: 2.0,
                learn_secs: 1.0,
                collect_frac: 2.0 / 3.0,
                learn_frac: 1.0 / 3.0,
                mean_return: 0.0,
                mean_batch_fill: None,
            },
        ];
        let (series, slope, r2) = speedups(&rows);
        assert_eq!(series[0], (1, 1.0));
        assert_eq!(series[1], (4, 4.0));
        assert!((slope - 1.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn csv_writers_emit_all_figures() {
        let rows = scaling_sweep(&tiny_base(), &factory_for, &[1, 2], 0).unwrap();
        let dir = std::env::temp_dir().join("walle_fig_test");
        let dir_s = dir.to_str().unwrap();
        write_sweep_csvs(&rows, dir_s).unwrap();
        for f in [
            "fig4_rollout_time.csv",
            "fig5_speedup.csv",
            "fig6_time_breakdown.csv",
            "fig7_learn_time.csv",
        ] {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(text.lines().count() >= 3, "{f}:\n{text}");
        }
        let curves = fig3_return_curves(&tiny_base(), &factory_for, &[1]).unwrap();
        write_fig3_csv(&curves, dir_s).unwrap();
        let text = std::fs::read_to_string(dir.join("fig3_return.csv")).unwrap();
        assert!(text.starts_with("n,iter"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
