//! Uniform ring replay buffer for off-policy learning (DDPG — the paper's
//! further-work §6.1: "Off-policy learning (DDPG) with replay buffer ...
//! it might be an advantage to adopt the parallel experience collection
//! architecture").
//!
//! Flat SoA storage (obs/act/rew/next_obs/done) with O(1) insert and O(B)
//! uniform sampling into caller-owned buffers — no allocation on the
//! learner hot path.
//!
//! [`ReplayBuffer`] is the single-ring reference implementation, kept as
//! the unit-test oracle; every training learner (native and fused XLA)
//! uses [`shard::ShardedReplay`], whose striped storage, shard-count-
//! invariant sampling, and checkpoint serialization are documented in
//! that module.

pub mod shard;

use crate::util::rng::Pcg64;

/// Fixed-capacity uniform replay buffer.
pub struct ReplayBuffer {
    obs_dim: usize,
    act_dim: usize,
    capacity: usize,
    len: usize,
    head: usize,
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
    done: Vec<f32>,
}

/// One sampled minibatch (owned, shaped for `runtime::DdpgBatch`).
#[derive(Debug, Clone, Default)]
pub struct ReplaySample {
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub rew: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub done: Vec<f32>,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        assert!(capacity > 0);
        Self {
            obs_dim,
            act_dim,
            capacity,
            len: 0,
            head: 0,
            obs: vec![0.0; capacity * obs_dim],
            act: vec![0.0; capacity * act_dim],
            rew: vec![0.0; capacity],
            next_obs: vec![0.0; capacity * obs_dim],
            done: vec![0.0; capacity],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Insert one transition, overwriting the oldest when full.
    pub fn push(&mut self, obs: &[f32], act: &[f32], rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        debug_assert_eq!(act.len(), self.act_dim);
        debug_assert_eq!(next_obs.len(), self.obs_dim);
        let i = self.head;
        self.obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(obs);
        self.act[i * self.act_dim..(i + 1) * self.act_dim].copy_from_slice(act);
        self.rew[i] = rew;
        self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(next_obs);
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Uniformly sample `batch` transitions into `out` (resized as needed).
    pub fn sample_into(&self, batch: usize, rng: &mut Pcg64, out: &mut ReplaySample) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        let (o, a) = (self.obs_dim, self.act_dim);
        out.obs.clear();
        out.obs.resize(batch * o, 0.0);
        out.act.clear();
        out.act.resize(batch * a, 0.0);
        out.rew.clear();
        out.rew.resize(batch, 0.0);
        out.next_obs.clear();
        out.next_obs.resize(batch * o, 0.0);
        out.done.clear();
        out.done.resize(batch, 0.0);
        for row in 0..batch {
            let i = rng.below(self.len);
            out.obs[row * o..(row + 1) * o].copy_from_slice(&self.obs[i * o..(i + 1) * o]);
            out.act[row * a..(row + 1) * a].copy_from_slice(&self.act[i * a..(i + 1) * a]);
            out.rew[row] = self.rew[i];
            out.next_obs[row * o..(row + 1) * o]
                .copy_from_slice(&self.next_obs[i * o..(i + 1) * o]);
            out.done[row] = self.done[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen, UsizeIn};

    fn tr(i: usize) -> (Vec<f32>, Vec<f32>, f32, Vec<f32>, bool) {
        (
            vec![i as f32, i as f32 + 0.5],
            vec![-(i as f32)],
            i as f32 * 10.0,
            vec![i as f32 + 1.0, i as f32 + 1.5],
            i % 3 == 0,
        )
    }

    #[test]
    fn fills_then_wraps() {
        let mut buf = ReplayBuffer::new(4, 2, 1);
        for i in 0..6 {
            let (o, a, r, n, d) = tr(i);
            buf.push(&o, &a, r, &n, d);
        }
        assert_eq!(buf.len(), 4);
        // oldest two (0,1) were overwritten by 4,5
        let mut rng = Pcg64::new(0);
        let mut s = ReplaySample::default();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            buf.sample_into(1, &mut rng, &mut s);
            seen.insert(s.rew[0] as i64);
        }
        assert_eq!(
            seen.into_iter().collect::<Vec<_>>(),
            vec![20, 30, 40, 50],
            "only the newest 4 transitions should remain"
        );
    }

    #[test]
    fn sample_preserves_transition_integrity() {
        let mut buf = ReplayBuffer::new(100, 2, 1);
        for i in 0..50 {
            let (o, a, r, n, d) = tr(i);
            buf.push(&o, &a, r, &n, d);
        }
        let mut rng = Pcg64::new(1);
        let mut s = ReplaySample::default();
        buf.sample_into(32, &mut rng, &mut s);
        for row in 0..32 {
            let i = s.rew[row] / 10.0;
            // fields must all come from the same transition i
            assert_eq!(s.obs[row * 2], i);
            assert_eq!(s.act[row], -i);
            assert_eq!(s.next_obs[row * 2], i + 1.0);
            assert_eq!(s.done[row], if (i as usize) % 3 == 0 { 1.0 } else { 0.0 });
        }
    }

    #[test]
    fn property_len_never_exceeds_capacity() {
        struct G;
        impl Gen for G {
            type Value = (usize, usize);
            fn generate(&self, rng: &mut Pcg64) -> (usize, usize) {
                (
                    UsizeIn(1, 64).generate(rng),
                    UsizeIn(0, 300).generate(rng),
                )
            }
        }
        check(3, 60, &G, |&(cap, pushes)| {
            let mut buf = ReplayBuffer::new(cap, 1, 1);
            for i in 0..pushes {
                buf.push(&[i as f32], &[0.0], 0.0, &[0.0], false);
            }
            buf.len() == pushes.min(cap)
        });
    }

    #[test]
    #[should_panic(expected = "empty replay buffer")]
    fn sampling_empty_panics() {
        let buf = ReplayBuffer::new(4, 1, 1);
        let mut rng = Pcg64::new(0);
        let mut s = ReplaySample::default();
        buf.sample_into(1, &mut rng, &mut s);
    }
}
