//! Sharded replay buffer with shard-count-invariant sampling (PR 8).
//!
//! [`ShardedReplay`] stripes transitions over `S` independent shards, each
//! behind its own mutex, so concurrent inserters never contend on one
//! global lock. The crucial property is that *sampling is defined on the
//! global insert sequence, not on the physical shards*: every insert is
//! tagged with a monotonically increasing global index `g` (assigned in
//! canonical absorb order — see `docs/OPERATIONS.md`), stored at shard
//! `g % S`, slot `(g / S) % ceil(C / S)`, and the sampling window is
//! always the most recent `min(total, C)` global indices regardless of
//! `S`. A minibatch drawn by [`ShardedReplay::sample_into`] is therefore
//! a pure function of `(seed, draw counter, window contents)` — the
//! transition SET is bitwise identical for any shard count, which
//! `rust/tests/coordinator_props.rs` enforces as a property.
//!
//! Slot-validity argument: with `cap_s = ceil(C / S)` slots per shard the
//! physical store holds `S * cap_s >= C` transitions. A window occupant
//! `g >= total - C` is only overwritten by global index `g + S * cap_s >=
//! total - C + C = total`, which has not been inserted yet — so every
//! index in the logical window is always physically present.
//!
//! Two sampling strategies are pluggable via [`ReplayStrategy`]:
//! * `Uniform` — every window entry equally likely (all IS weights 1).
//! * `Prioritized` — proportional prioritization (Schaul et al.):
//!   `p_i = (|td_i| + EPS)^ALPHA` over a Fenwick tree for O(log C)
//!   inverse-CDF draws, importance weights `w_i = (N * P(i))^-BETA`
//!   normalized so the batch max is 1. The `EPS` floor keeps every stored
//!   transition reachable at any priority spread (no starvation).
//!
//! [`ReplayRng`] is the seed-addressable draw source: call `k` derives a
//! fresh `Pcg64` stream from `(seed, k)`, so a restored `(seed, draws)`
//! pair resumes the exact draw sequence — checkpoints persist two u64s,
//! never a raw generator cursor.

use crate::config::ReplayStrategy;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Priority exponent alpha in `p = (|td| + eps)^alpha`.
pub const PRIO_ALPHA: f32 = 0.6;
/// Importance-sampling exponent beta in `w = (N * P)^-beta`.
pub const PRIO_BETA: f32 = 0.4;
/// Priority floor: keeps zero-TD transitions reachable (no starvation).
pub const PRIO_EPS: f32 = 1e-3;

/// Stream base for [`ReplayRng`] draw streams (distinct from the env,
/// policy-noise, and learner stream families — see docs/API.md).
const REPLAY_STREAM_BASE: u64 = 1 << 36;

/// Serialized shard-section version (embedded in learner checkpoint blobs).
const SHARD_STATE_VERSION: u32 = 1;

/// Seed-addressable minibatch draw source: draw `k` runs on its own
/// deterministic stream, so the sequence of drawn index sets is a pure
/// function of `(seed, k)` and survives checkpoint/resume as two u64s.
#[derive(Debug, Clone)]
pub struct ReplayRng {
    seed: u64,
    draws: u64,
}

impl ReplayRng {
    pub fn new(seed: u64) -> Self {
        Self { seed, draws: 0 }
    }

    /// Number of minibatch draws performed so far.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// Fresh generator for the next draw; advances the draw counter.
    fn next_draw(&mut self) -> Pcg64 {
        let rng = Pcg64::with_stream(self.seed, REPLAY_STREAM_BASE + self.draws);
        self.draws += 1;
        rng
    }

    pub fn save_state(&self, w: &mut ByteWriter) {
        w.put_u64(self.seed);
        w.put_u64(self.draws);
    }

    pub fn load_state(r: &mut ByteReader) -> Result<Self> {
        Ok(Self {
            seed: r.read_u64()?,
            draws: r.read_u64()?,
        })
    }
}

/// One sampled minibatch: `runtime::DdpgBatch`-shaped lanes plus the
/// importance weights and global indices prioritized replay needs.
#[derive(Debug, Clone, Default)]
pub struct ShardSample {
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub rew: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub done: Vec<f32>,
    /// Importance-sampling weight per row (all 1.0 under `Uniform`).
    pub weights: Vec<f32>,
    /// Global insert index per row — pass back to
    /// [`ShardedReplay::update_priorities`] after computing TD errors.
    pub indices: Vec<u64>,
}

/// Flat SoA storage for one shard (slot-indexed, `slots` rows).
struct Shard {
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
    done: Vec<f32>,
}

/// Priority state (central, keyed by `g % capacity` — distinct within the
/// window because the window never exceeds `capacity` entries).
struct PrioState {
    tree: Fenwick,
    /// alpha-powered priority per ring slot (0 = never written).
    prios: Vec<f64>,
    /// running max alpha-powered priority; new inserts adopt it so fresh
    /// experience is sampled at least once before its TD error is known.
    max_prio: f64,
}

/// Sharded replay buffer; see the module docs for the invariants.
pub struct ShardedReplay {
    obs_dim: usize,
    act_dim: usize,
    /// Logical sampling-window capacity C (independent of shard count).
    capacity: usize,
    slots_per_shard: usize,
    shards: Vec<Mutex<Shard>>,
    /// Global insert counter; index tags are assigned from it.
    total: AtomicU64,
    strategy: ReplayStrategy,
    prio: Option<Mutex<PrioState>>,
}

impl ShardedReplay {
    pub fn new(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        shards: usize,
        strategy: ReplayStrategy,
    ) -> Self {
        assert!(capacity > 0, "replay capacity must be positive");
        assert!(shards > 0, "shard count must be positive");
        let slots = (capacity + shards - 1) / shards;
        let shard_store = (0..shards)
            .map(|_| {
                Mutex::new(Shard {
                    obs: vec![0.0; slots * obs_dim],
                    act: vec![0.0; slots * act_dim],
                    rew: vec![0.0; slots],
                    next_obs: vec![0.0; slots * obs_dim],
                    done: vec![0.0; slots],
                })
            })
            .collect();
        let prio = match strategy {
            ReplayStrategy::Uniform => None,
            ReplayStrategy::Prioritized => Some(Mutex::new(PrioState {
                tree: Fenwick::new(capacity),
                prios: vec![0.0; capacity],
                max_prio: 1.0,
            })),
        };
        Self {
            obs_dim,
            act_dim,
            capacity,
            slots_per_shard: slots,
            shards: shard_store,
            total: AtomicU64::new(0),
            strategy,
            prio,
        }
    }

    /// Transitions currently in the sampling window.
    pub fn len(&self) -> usize {
        let total = self.total.load(Ordering::Acquire);
        total.min(self.capacity as u64) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn strategy(&self) -> ReplayStrategy {
        self.strategy
    }

    /// Total transitions ever inserted (the next global index tag).
    pub fn total_inserted(&self) -> u64 {
        self.total.load(Ordering::Acquire)
    }

    #[inline]
    fn locate(&self, g: u64) -> (usize, usize) {
        let s = self.shards.len() as u64;
        let shard = (g % s) as usize;
        let slot = ((g / s) % self.slots_per_shard as u64) as usize;
        (shard, slot)
    }

    /// Insert one transition, tagged with the next global index.
    /// Thread-safe (striped locks); determinism of a *run* additionally
    /// requires the canonical single-order insertion the learner performs.
    pub fn push(&self, obs: &[f32], act: &[f32], rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        debug_assert_eq!(act.len(), self.act_dim);
        debug_assert_eq!(next_obs.len(), self.obs_dim);
        let g = self.total.fetch_add(1, Ordering::AcqRel);
        let (shard, slot) = self.locate(g);
        {
            let mut sh = self.shards[shard].lock().expect("replay shard poisoned");
            let (o, a) = (self.obs_dim, self.act_dim);
            sh.obs[slot * o..(slot + 1) * o].copy_from_slice(obs);
            sh.act[slot * a..(slot + 1) * a].copy_from_slice(act);
            sh.rew[slot] = rew;
            sh.next_obs[slot * o..(slot + 1) * o].copy_from_slice(next_obs);
            sh.done[slot] = if done { 1.0 } else { 0.0 };
        }
        if let Some(prio) = &self.prio {
            let mut p = prio.lock().expect("priority state poisoned");
            let ring = (g % self.capacity as u64) as usize;
            let mp = p.max_prio;
            p.set(ring, mp);
        }
    }

    /// Draw `batch` transitions into `out` (resized as needed). The drawn
    /// index set depends only on `(rng seed, rng draw counter, window
    /// contents)` — never on the shard count.
    pub fn sample_into(&self, batch: usize, rng: &mut ReplayRng, out: &mut ShardSample) {
        let total = self.total.load(Ordering::Acquire);
        let len = total.min(self.capacity as u64);
        assert!(len > 0, "sampling from empty replay buffer");
        let start = total - len;
        let (o, a) = (self.obs_dim, self.act_dim);
        out.obs.clear();
        out.obs.resize(batch * o, 0.0);
        out.act.clear();
        out.act.resize(batch * a, 0.0);
        out.rew.clear();
        out.rew.resize(batch, 0.0);
        out.next_obs.clear();
        out.next_obs.resize(batch * o, 0.0);
        out.done.clear();
        out.done.resize(batch, 0.0);
        out.weights.clear();
        out.weights.resize(batch, 1.0);
        out.indices.clear();
        out.indices.resize(batch, 0);

        let mut draw = rng.next_draw();
        match (&self.prio, self.strategy) {
            (Some(prio), ReplayStrategy::Prioritized) => {
                let p = prio.lock().expect("priority state poisoned");
                let mass = p.tree.total();
                debug_assert!(mass > 0.0, "prioritized replay with zero total mass");
                for row in 0..batch {
                    let u = draw.next_f64() * mass;
                    let ring = p.tree.find(u);
                    let g = Self::ring_to_global(ring as u64, start, len, self.capacity as u64);
                    out.indices[row] = g;
                    // w_i = (N * P(i))^-beta, normalized below
                    let pr = (p.prios[ring] / mass) * len as f64;
                    out.weights[row] = (pr.max(f64::MIN_POSITIVE) as f32).powf(-PRIO_BETA);
                }
                let wmax = out
                    .weights
                    .iter()
                    .fold(0.0f32, |m, &w| if w > m { w } else { m });
                if wmax > 0.0 {
                    for w in &mut out.weights {
                        *w /= wmax;
                    }
                }
            }
            _ => {
                for row in 0..batch {
                    out.indices[row] = start + draw.below(len as usize) as u64;
                }
            }
        }
        for row in 0..batch {
            let (shard, slot) = self.locate(out.indices[row]);
            let sh = self.shards[shard].lock().expect("replay shard poisoned");
            out.obs[row * o..(row + 1) * o].copy_from_slice(&sh.obs[slot * o..(slot + 1) * o]);
            out.act[row * a..(row + 1) * a].copy_from_slice(&sh.act[slot * a..(slot + 1) * a]);
            out.rew[row] = sh.rew[slot];
            out.next_obs[row * o..(row + 1) * o]
                .copy_from_slice(&sh.next_obs[slot * o..(slot + 1) * o]);
            out.done[row] = sh.done[slot];
        }
    }

    /// Map a ring slot back to the unique global index of the window
    /// occupying it (window length `len <= capacity` makes it unique).
    fn ring_to_global(ring: u64, start: u64, len: u64, capacity: u64) -> u64 {
        let g = start + (ring + capacity - start % capacity) % capacity;
        debug_assert!(g < start + len, "ring slot outside sampling window");
        g
    }

    /// Refresh priorities after a learner step (`Prioritized` only; no-op
    /// under `Uniform`). Stale indices that have left the window are
    /// skipped — their slot now belongs to a newer transition.
    pub fn update_priorities(&self, indices: &[u64], td_errors: &[f32]) {
        let Some(prio) = &self.prio else { return };
        debug_assert_eq!(indices.len(), td_errors.len());
        let total = self.total.load(Ordering::Acquire);
        let len = total.min(self.capacity as u64);
        let start = total - len;
        let mut p = prio.lock().expect("priority state poisoned");
        for (&g, &td) in indices.iter().zip(td_errors) {
            if g < start || g >= total {
                continue;
            }
            let ring = (g % self.capacity as u64) as usize;
            let v = ((td.abs() + PRIO_EPS) as f64).powf(PRIO_ALPHA as f64);
            p.set(ring, v);
            if v > p.max_prio {
                p.max_prio = v;
            }
        }
    }

    /// Current sampling probability of global index `g` (`None` when `g`
    /// is outside the window). Uniform strategy: `1 / len`.
    pub fn sampling_prob(&self, g: u64) -> Option<f64> {
        let total = self.total.load(Ordering::Acquire);
        let len = total.min(self.capacity as u64);
        if g < total - len || g >= total {
            return None;
        }
        match &self.prio {
            None => Some(1.0 / len as f64),
            Some(prio) => {
                let p = prio.lock().expect("priority state poisoned");
                let ring = (g % self.capacity as u64) as usize;
                Some(p.prios[ring] / p.tree.total())
            }
        }
    }

    /// Serialize the logical window (global order) plus priorities as a
    /// versioned section. The encoding is shard-count-portable: a
    /// checkpoint written with S shards restores into any S'.
    pub fn save_state(&self, w: &mut ByteWriter) {
        let total = self.total.load(Ordering::Acquire);
        let len = total.min(self.capacity as u64);
        let start = total - len;
        w.put_u32(SHARD_STATE_VERSION);
        w.put_u64(total);
        w.put_usize(len as usize);
        let (o, a) = (self.obs_dim, self.act_dim);
        let mut row_obs = vec![0.0f32; o];
        let mut row_act = vec![0.0f32; a];
        let mut row_next = vec![0.0f32; o];
        for g in start..total {
            let (shard, slot) = self.locate(g);
            let sh = self.shards[shard].lock().expect("replay shard poisoned");
            row_obs.copy_from_slice(&sh.obs[slot * o..(slot + 1) * o]);
            row_act.copy_from_slice(&sh.act[slot * a..(slot + 1) * a]);
            row_next.copy_from_slice(&sh.next_obs[slot * o..(slot + 1) * o]);
            let (rew, done) = (sh.rew[slot], sh.done[slot]);
            drop(sh);
            for &v in &row_obs {
                w.put_f32(v);
            }
            for &v in &row_act {
                w.put_f32(v);
            }
            w.put_f32(rew);
            for &v in &row_next {
                w.put_f32(v);
            }
            w.put_f32(done);
        }
        if let Some(prio) = &self.prio {
            let p = prio.lock().expect("priority state poisoned");
            for g in start..total {
                let ring = (g % self.capacity as u64) as usize;
                w.put_f64(p.prios[ring]);
            }
            w.put_f64(p.max_prio);
        }
    }

    /// Restore a [`ShardedReplay::save_state`] section: contents, global
    /// counter, and (when prioritized) every priority — so resumed runs
    /// replay bitwise-identical minibatches.
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<()> {
        let ver = r.read_u32()?;
        if ver != SHARD_STATE_VERSION {
            bail!("unknown replay shard-section version {ver} (expected {SHARD_STATE_VERSION})");
        }
        let total = r.read_u64()?;
        let len = r.read_usize()? as u64;
        if len > self.capacity as u64 || len > total {
            bail!("corrupt replay section: len {len} vs capacity {} / total {total}", self.capacity);
        }
        let (o, a) = (self.obs_dim, self.act_dim);
        let mut row_obs = vec![0.0f32; o];
        let mut row_act = vec![0.0f32; a];
        let mut row_next = vec![0.0f32; o];
        // Re-insert in global order: push() re-derives each entry's shard
        // and slot under the CURRENT shard count, so the section is
        // portable across --replay-shards settings.
        self.total.store(total - len, Ordering::Release);
        for _ in 0..len {
            for v in row_obs.iter_mut() {
                *v = r.read_f32()?;
            }
            for v in row_act.iter_mut() {
                *v = r.read_f32()?;
            }
            let rew = r.read_f32()?;
            for v in row_next.iter_mut() {
                *v = r.read_f32()?;
            }
            let done = r.read_f32()?;
            self.push(&row_obs, &row_act, rew, &row_next, done != 0.0);
        }
        debug_assert_eq!(self.total.load(Ordering::Acquire), total);
        if let Some(prio) = &self.prio {
            let start = total - len;
            let mut p = prio.lock().expect("priority state poisoned");
            for g in start..total {
                let ring = (g % self.capacity as u64) as usize;
                let v = r.read_f64()?;
                p.set(ring, v);
            }
            p.max_prio = r.read_f64()?;
        }
        Ok(())
    }
}

impl PrioState {
    fn set(&mut self, ring: usize, v: f64) {
        let old = self.prios[ring];
        self.prios[ring] = v;
        self.tree.add(ring, v - old);
    }
}

/// Fenwick (binary indexed) tree over f64 priorities: O(log n) point
/// update and inverse-CDF search, the classic PER sum-tree.
struct Fenwick {
    n: usize,
    tree: Vec<f64>, // 1-indexed
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Self {
            n,
            tree: vec![0.0; n + 1],
        }
    }

    fn add(&mut self, mut i: usize, delta: f64) {
        i += 1;
        while i <= self.n {
            self.tree[i] += delta;
            i += i & i.wrapping_neg();
        }
    }

    fn total(&self) -> f64 {
        self.prefix(self.n)
    }

    fn prefix(&self, mut i: usize) -> f64 {
        let mut s = 0.0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Smallest index whose prefix sum exceeds `u` (clamped into range).
    fn find(&self, mut u: f64) -> usize {
        let mut pos = 0usize;
        let mut mask = self.n.next_power_of_two();
        while mask > 0 {
            let next = pos + mask;
            if next <= self.n && self.tree[next] < u {
                u -= self.tree[next];
                pos = next;
            }
            mask >>= 1;
        }
        pos.min(self.n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn push_n(buf: &ShardedReplay, n: usize) {
        for i in 0..n {
            let f = i as f32;
            buf.push(&[f, f + 0.5], &[-f], f * 10.0, &[f + 1.0, f + 1.5], i % 3 == 0);
        }
    }

    /// Multiset of transition ids (encoded in obs[0]) drawn by one batch.
    fn drawn_ids(buf: &ShardedReplay, rng: &mut ReplayRng, batch: usize) -> Vec<i64> {
        let mut s = ShardSample::default();
        buf.sample_into(batch, rng, &mut s);
        let mut ids: Vec<i64> = (0..batch).map(|r| s.obs[r * 2] as i64).collect();
        ids.sort_unstable();
        ids
    }

    #[test]
    fn window_drops_oldest_like_a_ring() {
        for shards in [1, 3, 4] {
            let buf = ShardedReplay::new(4, 2, 1, shards, ReplayStrategy::Uniform);
            push_n(&buf, 6);
            assert_eq!(buf.len(), 4);
            let mut rng = ReplayRng::new(0);
            let mut seen = BTreeSet::new();
            for _ in 0..64 {
                for id in drawn_ids(&buf, &mut rng, 4) {
                    seen.insert(id);
                }
            }
            assert_eq!(
                seen.into_iter().collect::<Vec<_>>(),
                vec![2, 3, 4, 5],
                "shards={shards}: only the newest 4 transitions should remain"
            );
        }
    }

    #[test]
    fn sample_set_is_invariant_to_shard_count() {
        let mut reference: Option<Vec<Vec<i64>>> = None;
        for shards in [1, 2, 4] {
            let buf = ShardedReplay::new(64, 2, 1, shards, ReplayStrategy::Uniform);
            push_n(&buf, 150); // wraps the window twice
            let mut rng = ReplayRng::new(42);
            let draws: Vec<Vec<i64>> =
                (0..8).map(|_| drawn_ids(&buf, &mut rng, 16)).collect();
            match &reference {
                None => reference = Some(draws),
                Some(want) => assert_eq!(want, &draws, "shards={shards}"),
            }
        }
    }

    #[test]
    fn rows_keep_transition_integrity() {
        let buf = ShardedReplay::new(100, 2, 1, 3, ReplayStrategy::Uniform);
        push_n(&buf, 50);
        let mut rng = ReplayRng::new(1);
        let mut s = ShardSample::default();
        buf.sample_into(32, &mut rng, &mut s);
        for row in 0..32 {
            let i = s.rew[row] / 10.0;
            assert_eq!(s.obs[row * 2], i);
            assert_eq!(s.act[row], -i);
            assert_eq!(s.next_obs[row * 2], i + 1.0);
            assert_eq!(s.done[row], if (i as usize) % 3 == 0 { 1.0 } else { 0.0 });
            assert_eq!(s.weights[row], 1.0);
            assert_eq!(s.indices[row], i as u64);
        }
    }

    #[test]
    fn replay_rng_resumes_exact_draw_sequence() {
        let buf = ShardedReplay::new(32, 2, 1, 2, ReplayStrategy::Uniform);
        push_n(&buf, 32);
        let mut a = ReplayRng::new(7);
        let _burn: Vec<_> = (0..3).map(|_| drawn_ids(&buf, &mut a, 8)).collect();
        let mut w = ByteWriter::new();
        a.save_state(&mut w);
        let buf2 = w.into_vec();
        let mut b = ReplayRng::load_state(&mut ByteReader::new(&buf2)).unwrap();
        assert_eq!(a.draws(), b.draws());
        for _ in 0..4 {
            assert_eq!(drawn_ids(&buf, &mut a, 8), drawn_ids(&buf, &mut b, 8));
        }
    }

    #[test]
    fn prioritized_draws_follow_priorities_but_never_starve() {
        let buf = ShardedReplay::new(16, 2, 1, 2, ReplayStrategy::Prioritized);
        push_n(&buf, 16);
        // extreme spread: index 3 dominant, everything else at the floor
        let idx: Vec<u64> = (0..16).collect();
        let mut td = vec![0.0f32; 16];
        td[3] = 1e6;
        buf.update_priorities(&idx, &td);
        // every transition keeps nonzero mass (reachable) …
        for g in 0..16u64 {
            assert!(buf.sampling_prob(g).unwrap() > 0.0, "g={g} starved");
        }
        // … and the dominant one dominates the draws
        let mut rng = ReplayRng::new(5);
        let mut s = ShardSample::default();
        let mut hits = 0usize;
        for _ in 0..32 {
            buf.sample_into(16, &mut rng, &mut s);
            hits += s.indices.iter().filter(|&&g| g == 3).count();
        }
        assert!(hits > 32 * 16 / 2, "dominant priority drew {hits}/512");
        // probabilities are a normalized distribution
        let mass: f64 = (0..16u64).map(|g| buf.sampling_prob(g).unwrap()).sum();
        assert!((mass - 1.0).abs() < 1e-9);
        // IS weights: finite, positive, batch max == 1
        assert!(s.weights.iter().all(|w| w.is_finite() && *w > 0.0 && *w <= 1.0));
        assert!(s.weights.iter().any(|w| (*w - 1.0).abs() < 1e-6));
    }

    #[test]
    fn save_load_round_trips_across_shard_counts() {
        for strategy in [ReplayStrategy::Uniform, ReplayStrategy::Prioritized] {
            let buf = ShardedReplay::new(24, 2, 1, 3, strategy);
            push_n(&buf, 40); // wrapped
            if strategy == ReplayStrategy::Prioritized {
                let idx: Vec<u64> = (16..40).collect();
                let td: Vec<f32> = (0..24).map(|i| i as f32 * 0.1).collect();
                buf.update_priorities(&idx, &td);
            }
            let mut w = ByteWriter::new();
            buf.save_state(&mut w);
            let bytes = w.into_vec();
            // restore under a DIFFERENT shard count
            let mut buf2 = ShardedReplay::new(24, 2, 1, 2, strategy);
            buf2.load_state(&mut ByteReader::new(&bytes)).unwrap();
            assert_eq!(buf2.len(), buf.len());
            assert_eq!(buf2.total_inserted(), buf.total_inserted());
            for g in 16..40u64 {
                let (pa, pb) = (buf.sampling_prob(g).unwrap(), buf2.sampling_prob(g).unwrap());
                assert!((pa - pb).abs() < 1e-12, "g={g}: {pa} vs {pb}");
            }
            // identical draw sequences after restore
            let mut ra = ReplayRng::new(9);
            let mut rb = ReplayRng::new(9);
            for _ in 0..6 {
                assert_eq!(drawn_ids(&buf, &mut ra, 8), drawn_ids(&buf2, &mut rb, 8));
            }
        }
    }

    #[test]
    fn fenwick_inverse_cdf_hits_every_bucket() {
        let mut f = Fenwick::new(5);
        let ps = [0.5, 0.0, 1.5, 0.25, 0.75];
        for (i, &p) in ps.iter().enumerate() {
            f.add(i, p);
        }
        assert!((f.total() - 3.0).abs() < 1e-12);
        // cumulative boundaries: [0.5, 0.5, 2.0, 2.25, 3.0]
        assert_eq!(f.find(0.0), 0);
        assert_eq!(f.find(0.1), 0);
        assert_eq!(f.find(0.5), 0); // boundary lands left (prefix(1) >= u) …
        assert_eq!(f.find(0.500001), 2); // … and the zero-mass bucket 1 is unreachable
        assert_eq!(f.find(1.9), 2);
        assert_eq!(f.find(2.1), 3);
        assert_eq!(f.find(2.9), 4);
        assert_eq!(f.find(99.0), 4); // clamped
    }

    #[test]
    #[should_panic(expected = "empty replay buffer")]
    fn sampling_empty_panics() {
        let buf = ShardedReplay::new(4, 1, 1, 2, ReplayStrategy::Uniform);
        let mut rng = ReplayRng::new(0);
        let mut s = ShardSample::default();
        buf.sample_into(1, &mut rng, &mut s);
    }
}
