//! Typed run configuration: defaults per env preset, JSON round-trip, and
//! validation. The launcher builds a `TrainConfig` from CLI flags and/or a
//! `--config file.json`, and every component reads from it — one source of
//! truth per run (the config is also echoed into the metrics CSV header so
//! runs are self-describing).

use crate::util::json::{Json, JsonError};
use std::collections::BTreeMap;

/// Which algorithm drives the learner. Each variant is backed by an
/// `algo::api::Algorithm` implementation (see
/// `algo::api::algorithm_from_config`, the registry this enum keys).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Ppo,
    Ddpg,
    /// Twin-delayed DDPG (Fujimoto et al., 2018): twin critics, delayed
    /// policy updates, target-policy smoothing. Native backend only for
    /// now (no TD3 AOT artifacts).
    Td3,
    /// Soft actor-critic (Haarnoja et al., 2018): twin soft critics,
    /// reparameterized tanh-Gaussian actor, learned temperature. Native
    /// backend only for now (no SAC AOT artifacts).
    Sac,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "ppo" => Some(Algo::Ppo),
            "ddpg" => Some(Algo::Ddpg),
            "td3" => Some(Algo::Td3),
            "sac" => Some(Algo::Sac),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Ppo => "ppo",
            Algo::Ddpg => "ddpg",
            Algo::Td3 => "td3",
            Algo::Sac => "sac",
        }
    }
}

/// Replay sampling strategy of the off-policy learners
/// (`--replay-strategy`). See `replay::shard` for the exact math.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayStrategy {
    /// Every window transition equally likely (default).
    Uniform,
    /// Proportional prioritization (Schaul et al., 2016): draws weighted
    /// by `(|td| + eps)^alpha`, importance weights returned per row.
    /// DDPG/TD3 native path only.
    Prioritized,
}

impl ReplayStrategy {
    pub fn parse(s: &str) -> Option<ReplayStrategy> {
        match s {
            "uniform" => Some(ReplayStrategy::Uniform),
            "prioritized" => Some(ReplayStrategy::Prioritized),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplayStrategy::Uniform => "uniform",
            ReplayStrategy::Prioritized => "prioritized",
        }
    }
}

/// Which compute backend executes the policy/learner math.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT HLO artifacts through PJRT (the production path).
    Xla,
    /// Pure-Rust mirror (artifact-free; tests/quickstart).
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "xla" => Some(Backend::Xla),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Xla => "xla",
            Backend::Native => "native",
        }
    }
}

/// How sampler workers evaluate the policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferenceMode {
    /// Every worker owns a private backend sized to its M envs (PR 1
    /// vectorized path): N small forwards per sim tick fleet-wide.
    Local,
    /// One shared inference server owns a fleet-sized backend and
    /// coalesces all workers' rows into one mega-batch forward per sim
    /// tick (SEED/Spreeze-style centralized inference).
    Shared,
}

impl InferenceMode {
    pub fn parse(s: &str) -> Option<InferenceMode> {
        match s {
            "local" => Some(InferenceMode::Local),
            "shared" => Some(InferenceMode::Shared),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            InferenceMode::Local => "local",
            InferenceMode::Shared => "shared",
        }
    }
}

/// Shard count of the shared inference pool (`--infer-shards`).
///
/// Shared mode runs `S` server threads; worker `w` is statically assigned
/// to shard `w % S`, so each shard coalesces its own workers' rows into
/// one batched forward per sim tick. Per-env trajectories are independent
/// of `S` (the forward is row-independent; see
/// `runtime::inference_server`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferShards {
    /// `clamp(N / 8, 1, cores / 2)` — one shard per ~8 workers, never
    /// more than half the machine's cores (the serve threads must leave
    /// room for the samplers they feed).
    Auto,
    /// Exactly this many shards. `TrainConfig::validate` rejects shared
    /// runs where this exceeds the worker count (every shard must own at
    /// least one worker); direct [`InferShards::resolve_with`] callers
    /// get the value clamped to `[1, N]` instead.
    Fixed(usize),
}

impl InferShards {
    /// Parse `"auto"` or a positive integer.
    pub fn parse(s: &str) -> Option<InferShards> {
        if s == "auto" {
            return Some(InferShards::Auto);
        }
        s.parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .map(InferShards::Fixed)
    }

    /// CLI/JSON spelling: `"auto"` or the shard count.
    pub fn name(&self) -> String {
        match self {
            InferShards::Auto => "auto".into(),
            InferShards::Fixed(n) => n.to_string(),
        }
    }

    /// Resolve to a concrete shard count for `workers` samplers on this
    /// machine.
    pub fn resolve(&self, workers: usize) -> usize {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(8);
        self.resolve_with(workers, cores)
    }

    /// [`InferShards::resolve`] with an explicit core count (testable).
    pub fn resolve_with(&self, workers: usize, cores: usize) -> usize {
        let w = workers.max(1);
        match *self {
            InferShards::Fixed(s) => s.clamp(1, w),
            InferShards::Auto => (w / 8).clamp(1, (cores / 2).max(1)).min(w),
        }
    }
}

/// Straggler-cut policy of the shared inference pool (`--infer-wait`).
///
/// A shard dispatches a partial batch rather than wait indefinitely for a
/// straggler worker (env reset, sync-mode parking, queue backpressure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferWait {
    /// Track an EWMA/MAD of client inter-arrival gaps per shard and cut
    /// once the queue has been quiet for `2*EWMA + 4*MAD` microseconds —
    /// the expected marginal batch fill no longer pays for the wait. See
    /// `runtime::inference_server::AdaptiveWait`.
    Adaptive,
    /// Cut a fixed number of microseconds after the first pending slab
    /// (the PR 2 `--infer-max-wait-us` behavior).
    Fixed(u64),
}

/// Ceiling on a fixed straggler-cut budget (60 s): a cut beyond this
/// parks the whole fleet behind one straggler for longer than any env
/// tick could justify, so `validate` treats it as a typo'd/overflowed
/// microsecond value rather than a tuning choice.
pub const MAX_INFER_WAIT_US: u64 = 60_000_000;

impl InferWait {
    /// Parse `"adaptive"`, `"fixed:<us>"`, or a bare microsecond count.
    /// Range checks (no zero, no 60s+ budgets) live in
    /// `TrainConfig::validate`, where they can reject with an actionable
    /// message instead of silently clamping at runtime.
    pub fn parse(s: &str) -> Option<InferWait> {
        if s == "adaptive" {
            return Some(InferWait::Adaptive);
        }
        let us = s.strip_prefix("fixed:").unwrap_or(s);
        us.parse::<u64>().ok().map(InferWait::Fixed)
    }

    /// CLI/JSON spelling: `"adaptive"` or `"fixed:<us>"`.
    pub fn name(&self) -> String {
        match self {
            InferWait::Adaptive => "adaptive".into(),
            InferWait::Fixed(us) => format!("fixed:{us}"),
        }
    }
}

static LEGACY_INFER_WAIT_ONCE: std::sync::Once = std::sync::Once::new();

/// Warn — exactly once per process, enforced by the `Once` — that
/// `infer_max_wait_us` is the deprecated PR 2 spelling of
/// `infer_wait = "fixed:<us>"`. Shared by the JSON loader and the CLI
/// legacy-flag paths so repeated configs don't spam the log.
pub fn warn_legacy_infer_max_wait_us() {
    LEGACY_INFER_WAIT_ONCE.call_once(|| {
        crate::log_warn!(
            "`infer_max_wait_us` is deprecated; spell it `infer_wait`: \"fixed:<us>\" \
             (or use the adaptive default)"
        );
    });
}

/// Whether the deprecation warning has fired (it can fire at most once
/// per process by construction — the regression test asserts it does).
pub fn legacy_infer_wait_warned() -> bool {
    LEGACY_INFER_WAIT_ONCE.is_completed()
}

/// How the shared-inference pool adopts newly published policy versions
/// (`--infer-epoch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferEpoch {
    /// Pool-wide epochs (default): a learner publish becomes a *proposed*
    /// epoch and ALL shards flip to the new snapshot on the same dispatch
    /// boundary (`runtime::epoch::EpochGate`), so `--infer-shards` stays
    /// a pure performance knob even across mid-run version changes.
    Pool,
    /// Each shard observes the policy store independently, once per
    /// dispatch (the pre-epoch behavior): two shards may adopt a publish
    /// a dispatch apart. Escape hatch for isolating gate behavior;
    /// per-worker chunk streams stay single-version-per-chunk either way.
    Shard,
}

impl InferEpoch {
    /// Parse `"pool"` or `"shard"`.
    pub fn parse(s: &str) -> Option<InferEpoch> {
        match s {
            "pool" => Some(InferEpoch::Pool),
            "shard" => Some(InferEpoch::Shard),
            _ => None,
        }
    }

    /// CLI/JSON spelling.
    pub fn name(&self) -> &'static str {
        match self {
            InferEpoch::Pool => "pool",
            InferEpoch::Shard => "shard",
        }
    }
}

/// Where the sampler fleet lives (`--fleet-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Sampler workers are threads of the training process (default —
    /// the topology of every prior layer).
    Threads,
    /// Sampler workers are child OS processes connected to an in-process
    /// policy daemon over a Unix socket (`runtime::daemon`): the WALL-E
    /// multi-process serving tier. Per-env chunk streams are bitwise
    /// identical to threads mode — the transport is a pure topology knob.
    Procs,
}

impl FleetMode {
    /// Parse `"threads"` or `"procs"`.
    pub fn parse(s: &str) -> Option<FleetMode> {
        match s {
            "threads" => Some(FleetMode::Threads),
            "procs" => Some(FleetMode::Procs),
            _ => None,
        }
    }

    /// CLI/JSON spelling.
    pub fn name(&self) -> &'static str {
        match self {
            FleetMode::Threads => "threads",
            FleetMode::Procs => "procs",
        }
    }
}

/// Numeric precision of the shared-inference actor forward
/// (`--infer-precision`). The learner is always f32; int8 quantizes the
/// actor once per policy publish (see `nn::quant`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferPrecision {
    /// f32 forwards from the published flat vector (default).
    F32,
    /// int8 symmetric weights (per-column scales) + dynamic per-row
    /// activation quantization, i32 accumulation. Native backend, shared
    /// inference mode only.
    Int8,
}

impl InferPrecision {
    pub fn parse(s: &str) -> Option<InferPrecision> {
        match s {
            "f32" => Some(InferPrecision::F32),
            "int8" => Some(InferPrecision::Int8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            InferPrecision::F32 => "f32",
            InferPrecision::Int8 => "int8",
        }
    }
}

/// Rounding contract of the native CPU kernels (`--kernels`). See
/// `nn::kernels` for the full contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelsCfg {
    /// SIMD kernels are bitwise identical to the scalar reference
    /// (default — keeps cross-shard/cross-flip bitwise determinism).
    Exact,
    /// FMA + register tiling + vectorized reductions; results drift from
    /// scalar only by float reassociation (~1e-6 relative).
    Fast,
}

impl KernelsCfg {
    pub fn parse(s: &str) -> Option<KernelsCfg> {
        match s {
            "exact" => Some(KernelsCfg::Exact),
            "fast" => Some(KernelsCfg::Fast),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelsCfg::Exact => "exact",
            KernelsCfg::Fast => "fast",
        }
    }

    /// The `nn::kernels` mode this config selects.
    pub fn mode(&self) -> crate::nn::kernels::KernelMode {
        match self {
            KernelsCfg::Exact => crate::nn::kernels::KernelMode::Exact,
            KernelsCfg::Fast => crate::nn::kernels::KernelMode::Fast,
        }
    }
}

/// Env stepping engine (`--env-engine`). See `env::batch` for the
/// contract; both engines are bitwise interchangeable in exact kernel
/// mode, so this is a performance knob, not a semantics knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvEngineCfg {
    /// Pick the best available engine (currently: batched for every
    /// registry env). The default.
    Auto,
    /// Force the structure-of-arrays `BatchedEnv` engine.
    Batched,
    /// Force the legacy one-`Env`-per-instance loop (reference path;
    /// also what wrapper stacks and third-party scalar envs use).
    Scalar,
}

impl EnvEngineCfg {
    pub fn parse(s: &str) -> Option<EnvEngineCfg> {
        match s {
            "auto" => Some(EnvEngineCfg::Auto),
            "batched" => Some(EnvEngineCfg::Batched),
            "scalar" => Some(EnvEngineCfg::Scalar),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EnvEngineCfg::Auto => "auto",
            EnvEngineCfg::Batched => "batched",
            EnvEngineCfg::Scalar => "scalar",
        }
    }

    /// The `env::batch` engine this config resolves to.
    pub fn engine(&self) -> crate::env::batch::EnvEngine {
        match self {
            EnvEngineCfg::Auto | EnvEngineCfg::Batched => crate::env::batch::EnvEngine::Batched,
            EnvEngineCfg::Scalar => crate::env::batch::EnvEngine::Scalar,
        }
    }
}

/// PPO hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct PpoCfg {
    /// Optimization epochs over each iteration's batch.
    pub epochs: usize,
    /// Minibatch size per Adam step.
    pub minibatch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Linearly anneal `lr` to zero over the run.
    pub lr_anneal: bool,
    /// Discount factor.
    pub gamma: f32,
    /// GAE lambda.
    pub lam: f32,
    /// PPO clip range epsilon.
    pub clip: f32,
    /// Entropy bonus coefficient.
    pub ent_coef: f32,
    /// Value-loss coefficient.
    pub vf_coef: f32,
    /// Normalize advantages per iteration.
    pub norm_adv: bool,
}

impl Default for PpoCfg {
    fn default() -> Self {
        Self {
            epochs: 10,
            minibatch: 512,
            lr: 3e-4,
            lr_anneal: false,
            gamma: 0.99,
            lam: 0.95,
            clip: 0.2,
            ent_coef: 0.0,
            vf_coef: 0.5,
            norm_adv: true,
        }
    }
}

/// DDPG hyper-parameters (further-work §6.1).
#[derive(Debug, Clone, PartialEq)]
pub struct DdpgCfg {
    /// Replay minibatch size per update.
    pub batch: usize,
    /// Discount factor.
    pub gamma: f32,
    /// Polyak averaging rate for the target networks.
    pub tau: f32,
    /// Actor Adam learning rate.
    pub lr_actor: f32,
    /// Critic Adam learning rate.
    pub lr_critic: f32,
    /// Replay ring-buffer capacity in transitions.
    pub replay_capacity: usize,
    /// Transitions collected before the first update.
    pub warmup_steps: usize,
    /// Gaussian exploration-noise stddev added to actions.
    pub explore_noise: f32,
    /// Gradient updates per training iteration.
    pub updates_per_iter: usize,
}

impl Default for DdpgCfg {
    fn default() -> Self {
        Self {
            batch: 256,
            gamma: 0.99,
            tau: 0.005,
            lr_actor: 1e-3,
            lr_critic: 1e-3,
            replay_capacity: 200_000,
            warmup_steps: 2_000,
            explore_noise: 0.1,
            updates_per_iter: 200,
        }
    }
}

/// TD3 hyper-parameters (Fujimoto et al., 2018). The leading fields
/// mirror [`DdpgCfg`] (TD3 is a DDPG refinement); the last three are
/// TD3's own tricks.
#[derive(Debug, Clone, PartialEq)]
pub struct Td3Cfg {
    /// Replay minibatch size per update.
    pub batch: usize,
    /// Discount factor.
    pub gamma: f32,
    /// Polyak averaging rate for the three target networks.
    pub tau: f32,
    /// Actor Adam learning rate.
    pub lr_actor: f32,
    /// Critic Adam learning rate (both critics).
    pub lr_critic: f32,
    /// Replay ring-buffer capacity in transitions.
    pub replay_capacity: usize,
    /// Transitions collected before the first update.
    pub warmup_steps: usize,
    /// Gaussian exploration-noise stddev added to actions (sampler side).
    pub explore_noise: f32,
    /// Gradient updates per training iteration.
    pub updates_per_iter: usize,
    /// Delayed policy updates: the actor (and all targets) step once per
    /// this many critic updates.
    pub policy_delay: usize,
    /// Target-policy smoothing: stddev of the noise added to the target
    /// action when forming the TD target.
    pub target_noise: f32,
    /// Clamp for the target-policy smoothing noise.
    pub noise_clip: f32,
}

impl Default for Td3Cfg {
    fn default() -> Self {
        Self {
            batch: 256,
            gamma: 0.99,
            tau: 0.005,
            lr_actor: 1e-3,
            lr_critic: 1e-3,
            replay_capacity: 200_000,
            warmup_steps: 2_000,
            explore_noise: 0.1,
            updates_per_iter: 200,
            policy_delay: 2,
            target_noise: 0.2,
            noise_clip: 0.5,
        }
    }
}

/// SAC hyper-parameters (Haarnoja et al., 2018). The leading fields
/// mirror [`DdpgCfg`]; the last two drive the entropy temperature.
/// Exploration comes from the stochastic policy itself, so there is no
/// `explore_noise` knob.
#[derive(Debug, Clone, PartialEq)]
pub struct SacCfg {
    /// Replay minibatch size per update.
    pub batch: usize,
    /// Discount factor.
    pub gamma: f32,
    /// Polyak averaging rate for the two target critics.
    pub tau: f32,
    /// Actor Adam learning rate.
    pub lr_actor: f32,
    /// Critic Adam learning rate (both critics).
    pub lr_critic: f32,
    /// Plain-SGD learning rate on `log(alpha)` (the learned temperature).
    pub lr_alpha: f32,
    /// Initial entropy temperature alpha.
    pub init_alpha: f32,
    /// Replay ring-buffer capacity in transitions.
    pub replay_capacity: usize,
    /// Transitions collected before the first update.
    pub warmup_steps: usize,
    /// Gradient updates per training iteration.
    pub updates_per_iter: usize,
}

impl Default for SacCfg {
    fn default() -> Self {
        Self {
            batch: 256,
            gamma: 0.99,
            tau: 0.005,
            lr_actor: 3e-4,
            lr_critic: 3e-4,
            lr_alpha: 3e-4,
            init_alpha: 0.2,
            replay_capacity: 200_000,
            warmup_steps: 2_000,
            updates_per_iter: 200,
        }
    }
}

impl PpoCfg {
    /// JSON object of these hyper-parameters (the `"ppo"` section of a
    /// `TrainConfig`, also rendered by `walle info` via the trait).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epochs", Json::Num(self.epochs as f64)),
            ("minibatch", Json::Num(self.minibatch as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("lr_anneal", Json::Bool(self.lr_anneal)),
            ("gamma", Json::Num(self.gamma as f64)),
            ("lam", Json::Num(self.lam as f64)),
            ("clip", Json::Num(self.clip as f64)),
            ("ent_coef", Json::Num(self.ent_coef as f64)),
            ("vf_coef", Json::Num(self.vf_coef as f64)),
            ("norm_adv", Json::Bool(self.norm_adv)),
        ])
    }
}

impl DdpgCfg {
    /// JSON object of these hyper-parameters (the `"ddpg"` section).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::Num(self.batch as f64)),
            ("gamma", Json::Num(self.gamma as f64)),
            ("tau", Json::Num(self.tau as f64)),
            ("lr_actor", Json::Num(self.lr_actor as f64)),
            ("lr_critic", Json::Num(self.lr_critic as f64)),
            ("replay_capacity", Json::Num(self.replay_capacity as f64)),
            ("warmup_steps", Json::Num(self.warmup_steps as f64)),
            ("explore_noise", Json::Num(self.explore_noise as f64)),
            ("updates_per_iter", Json::Num(self.updates_per_iter as f64)),
        ])
    }
}

impl Td3Cfg {
    /// JSON object of these hyper-parameters (the `"td3"` section).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::Num(self.batch as f64)),
            ("gamma", Json::Num(self.gamma as f64)),
            ("tau", Json::Num(self.tau as f64)),
            ("lr_actor", Json::Num(self.lr_actor as f64)),
            ("lr_critic", Json::Num(self.lr_critic as f64)),
            ("replay_capacity", Json::Num(self.replay_capacity as f64)),
            ("warmup_steps", Json::Num(self.warmup_steps as f64)),
            ("explore_noise", Json::Num(self.explore_noise as f64)),
            ("updates_per_iter", Json::Num(self.updates_per_iter as f64)),
            ("policy_delay", Json::Num(self.policy_delay as f64)),
            ("target_noise", Json::Num(self.target_noise as f64)),
            ("noise_clip", Json::Num(self.noise_clip as f64)),
        ])
    }
}

impl SacCfg {
    /// JSON object of these hyper-parameters (the `"sac"` section).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::Num(self.batch as f64)),
            ("gamma", Json::Num(self.gamma as f64)),
            ("tau", Json::Num(self.tau as f64)),
            ("lr_actor", Json::Num(self.lr_actor as f64)),
            ("lr_critic", Json::Num(self.lr_critic as f64)),
            ("lr_alpha", Json::Num(self.lr_alpha as f64)),
            ("init_alpha", Json::Num(self.init_alpha as f64)),
            ("replay_capacity", Json::Num(self.replay_capacity as f64)),
            ("warmup_steps", Json::Num(self.warmup_steps as f64)),
            ("updates_per_iter", Json::Num(self.updates_per_iter as f64)),
        ])
    }
}

/// Full run configuration: one source of truth per training run, built
/// from CLI flags and/or a `--config file.json` and echoed into every
/// run's `config.json` so results are self-describing.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Environment name (`pendulum`, `cartpole`, `reacher`,
    /// `halfcheetah` — see `env::registry::ENV_NAMES`).
    pub env: String,
    /// Learner algorithm driving the run (PPO, DDPG, TD3, or SAC).
    pub algo: Algo,
    /// Compute backend executing policy/learner math (AOT XLA artifacts
    /// or the pure-Rust native mirror).
    pub backend: Backend,
    /// Root RNG seed; every env/noise stream derives from it
    /// deterministically.
    pub seed: u64,
    /// Number of parallel sampler workers (the paper's N).
    pub samplers: usize,
    /// Vectorized envs per sampler worker (M): each worker steps M
    /// homogeneous envs in lockstep behind ONE batched policy forward per
    /// sim tick, multiplying rollout throughput per thread. 1 = the
    /// paper's original one-env-per-worker loop.
    pub envs_per_sampler: usize,
    /// Where policy inference runs: `local` = one private backend per
    /// worker (N forwards per tick); `shared` = the sharded inference
    /// pool batches workers' rows into fleet-wide forwards.
    pub inference_mode: InferenceMode,
    /// Shared mode: how many inference-server shards serve the fleet
    /// (`auto` = one per ~8 workers, capped at half the cores).
    pub infer_shards: InferShards,
    /// Shared mode: the straggler-cut policy — when a shard dispatches a
    /// partial batch instead of waiting for late workers.
    pub infer_wait: InferWait,
    /// Shared mode: how the pool adopts newly published policy versions
    /// (`pool` = all shards flip on one dispatch boundary behind the
    /// epoch gate, the default; `shard` = independent per-shard store
    /// observation, the pre-epoch behavior).
    pub infer_epoch: InferEpoch,
    /// Numeric precision of the shared-inference actor forward (`f32`
    /// default; `int8` = publish-time quantized actor snapshots — native
    /// backend + shared inference only; the learner stays f32).
    pub infer_precision: InferPrecision,
    /// Rounding contract of the native CPU kernels (`exact` = SIMD
    /// bitwise-equal to scalar, the default; `fast` = FMA + tiling).
    pub kernels: KernelsCfg,
    /// Env stepping engine (`auto` default = SoA batched `step_all`
    /// sweep; `scalar` = legacy per-env loop). Bitwise interchangeable
    /// in exact kernel mode.
    pub env_engine: EnvEngineCfg,
    /// Samples collected per iteration (paper: 20,000).
    pub samples_per_iter: usize,
    /// Training iterations to run.
    pub iterations: usize,
    /// Sampler→learner queue capacity, in chunks (backpressure bound).
    pub queue_capacity: usize,
    /// Steps per experience chunk a sampler pushes at once.
    pub chunk_steps: usize,
    /// Fully-asynchronous mode: samplers never pause between iterations
    /// (the paper's architecture); `false` gives a synchronous barrier per
    /// iteration (ablation baseline).
    pub async_mode: bool,
    /// Normalize observations with a running mean/std shared via the
    /// policy queue.
    pub norm_obs: bool,
    /// Reward scale applied to the learning signal (episode returns are
    /// reported unscaled). Keeps value-loss magnitudes sane for envs with
    /// large return scales.
    pub reward_scale: f32,
    /// Directory holding the AOT artifacts (`--backend xla` only).
    pub artifacts_dir: String,
    /// Hidden-layer widths of the policy/value MLPs.
    pub hidden: Vec<usize>,
    /// PPO hyper-parameters (used when `algo == Algo::Ppo`).
    pub ppo: PpoCfg,
    /// DDPG hyper-parameters (used when `algo == Algo::Ddpg`).
    pub ddpg: DdpgCfg,
    /// TD3 hyper-parameters (used when `algo == Algo::Td3`).
    pub td3: Td3Cfg,
    /// SAC hyper-parameters (used when `algo == Algo::Sac`).
    pub sac: SacCfg,
    /// Parallel-learning shards (further-work §6.2); 1 = single learner.
    pub learner_shards: usize,
    /// Replay-buffer shards (`--replay-shards`): striped-lock insert lanes
    /// of the off-policy replay store. Sampled minibatch SETS are a pure
    /// function of (seed, draw index, contents) — independent of this
    /// knob (see `replay::shard`).
    pub replay_shards: usize,
    /// Off-policy gradient worker threads (`--learner-threads`): minibatch
    /// grains fan over L workers and recombine through a fixed-order tree
    /// reduction, so published parameters are bitwise identical for any L
    /// (see `coordinator::learn_pool`). Native DDPG/TD3 only.
    pub learner_threads: usize,
    /// Replay sampling strategy (`--replay-strategy`): `uniform` (default)
    /// or `prioritized` (proportional PER with importance weights).
    pub replay_strategy: ReplayStrategy,
    /// Async mode: discard chunks whose policy version lags the current
    /// one by more than this (0 = keep everything). Bounds the
    /// off-policy-ness the PPO ratios see.
    pub max_staleness: u64,
    /// Write a durable checkpoint every this many iterations (0 = off).
    /// See `runtime::checkpoint` for what a snapshot captures.
    pub checkpoint_every: usize,
    /// Directory checkpoints are written into (`--checkpoint-dir`).
    pub checkpoint_dir: String,
    /// Resume from the newest checkpoint in this directory ("" = fresh
    /// run). The checkpoint's run fingerprint must match this config.
    pub resume: String,
    /// Deterministic fault plan (`--fault-inject`), e.g.
    /// `"worker:1@tick:500,shard:0@dispatch:40"` or
    /// `"random:seed=7,count=2,horizon=1000"`; "" = no injection (the
    /// zero-cost path). See `util::fault`.
    pub fault_inject: String,
    /// Shared + pool-epoch mode: force a deterministic epoch flip every
    /// this many dispatches per shard even without a learner publish
    /// (0 = flip only on publish). See `runtime::epoch::EpochGate`.
    pub flip_schedule: u64,
    /// Supervisor restart budget: how many times a panicked sampler
    /// worker or inference shard is respawned before the fleet aborts.
    /// Under `--fleet-mode procs` the same budget covers dead sampler
    /// child processes.
    pub max_restarts: usize,
    /// Sampler placement (`--fleet-mode`): `threads` (default) or
    /// `procs` (sampler child processes served by the policy daemon).
    pub fleet_mode: FleetMode,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            env: "halfcheetah".into(),
            algo: Algo::Ppo,
            backend: Backend::Native,
            seed: 0,
            samplers: 10,
            envs_per_sampler: 1,
            inference_mode: InferenceMode::Local,
            infer_shards: InferShards::Auto,
            infer_wait: InferWait::Adaptive,
            infer_epoch: InferEpoch::Pool,
            infer_precision: InferPrecision::F32,
            kernels: KernelsCfg::Exact,
            env_engine: EnvEngineCfg::Auto,
            samples_per_iter: 20_000,
            iterations: 100,
            queue_capacity: 16,
            chunk_steps: 200,
            async_mode: true,
            norm_obs: true,
            reward_scale: 1.0,
            artifacts_dir: "artifacts".into(),
            hidden: vec![64, 64],
            ppo: PpoCfg::default(),
            ddpg: DdpgCfg::default(),
            td3: Td3Cfg::default(),
            sac: SacCfg::default(),
            learner_shards: 1,
            replay_shards: 1,
            learner_threads: 1,
            replay_strategy: ReplayStrategy::Uniform,
            max_staleness: 2,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            resume: String::new(),
            fault_inject: String::new(),
            flip_schedule: 0,
            max_restarts: 2,
            fleet_mode: FleetMode::Threads,
        }
    }
}

impl TrainConfig {
    /// Per-env preset defaults (matching python/compile/aot.py PRESETS).
    pub fn preset(env: &str) -> TrainConfig {
        let mut cfg = TrainConfig {
            env: env.to_string(),
            ..Default::default()
        };
        match env {
            "pendulum" => {
                cfg.samples_per_iter = 4_000;
                cfg.ppo.minibatch = 256;
                cfg.samplers = 4;
                cfg.chunk_steps = 200;
                cfg.reward_scale = 0.1; // returns ~-1300 raw
                cfg.ppo.lr = 1e-3;
            }
            "cartpole" => {
                cfg.samples_per_iter = 4_000;
                cfg.ppo.minibatch = 256;
                cfg.samplers = 4;
            }
            "reacher" => {
                cfg.samples_per_iter = 4_000;
                cfg.ppo.minibatch = 256;
                cfg.samplers = 4;
                cfg.chunk_steps = 50;
            }
            _ => {} // halfcheetah defaults above
        }
        cfg
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.samplers == 0 {
            return Err("samplers must be >= 1".into());
        }
        if self.envs_per_sampler == 0 {
            return Err("envs_per_sampler must be >= 1".into());
        }
        if self.samplers * self.envs_per_sampler > self.samples_per_iter {
            return Err(format!(
                "samplers * envs_per_sampler = {} exceeds samples_per_iter {} — \
                 every env must contribute at least one step per iteration",
                self.samplers * self.envs_per_sampler,
                self.samples_per_iter
            ));
        }
        if self.samples_per_iter == 0 {
            return Err("samples_per_iter must be > 0".into());
        }
        if self.chunk_steps == 0 || self.chunk_steps > self.samples_per_iter {
            return Err(format!(
                "chunk_steps {} must be in [1, samples_per_iter {}]",
                self.chunk_steps, self.samples_per_iter
            ));
        }
        if self.queue_capacity == 0 {
            return Err("queue_capacity must be > 0".into());
        }
        if self.ppo.minibatch == 0 {
            return Err("ppo.minibatch must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.ppo.gamma) || !(0.0..=1.0).contains(&self.ppo.lam) {
            return Err("gamma/lam must be in [0,1]".into());
        }
        if self.learner_shards == 0 {
            return Err("learner_shards must be >= 1".into());
        }
        if let InferShards::Fixed(s) = self.infer_shards {
            if s == 0 {
                return Err("infer_shards must be >= 1 (or \"auto\")".into());
            }
            if self.inference_mode == InferenceMode::Shared && s > self.samplers {
                return Err(format!(
                    "infer_shards {} exceeds samplers {} — every shard must own \
                     at least one worker",
                    s, self.samplers
                ));
            }
        }
        if let InferWait::Fixed(us) = self.infer_wait {
            if us == 0 {
                return Err(
                    "infer_wait fixed:0 would busy-spin the dispatch cut (a \
                     zero-microsecond straggler budget dispatches every slab \
                     alone, defeating coalescing while pegging a core); use \
                     fixed:<us> >= 1 or the adaptive default"
                        .into(),
                );
            }
            if us > MAX_INFER_WAIT_US {
                return Err(format!(
                    "infer_wait fixed:{us} exceeds the {MAX_INFER_WAIT_US} us \
                     (60 s) ceiling — a cut that long parks the whole fleet \
                     behind one straggler (this usually means a millisecond or \
                     second value was pasted as microseconds); pick a smaller \
                     budget or the adaptive default"
                ));
            }
        }
        if self.fleet_mode == FleetMode::Procs {
            if self.inference_mode != InferenceMode::Shared {
                return Err(
                    "fleet_mode procs serves every sampler process from the \
                     policy daemon's shared inference pool — add \
                     --inference-mode shared (per-process local actors would \
                     duplicate the policy weights and bypass the serving tier)"
                        .into(),
                );
            }
            if !self.fault_inject.is_empty() {
                return Err(
                    "fault_inject scripts in-process fault cells, which sampler \
                     child processes cannot trip — run the chaos plan under \
                     --fleet-mode threads, or kill the sampler processes \
                     directly (the supervisor respawns them either way)"
                        .into(),
                );
            }
            if !self.resume.is_empty() || self.checkpoint_every > 0 {
                return Err(
                    "checkpoint/resume captures per-worker sampler snapshots, \
                     which live inside the child processes under --fleet-mode \
                     procs and are not collected over the wire yet — drop \
                     --checkpoint-every/--resume or use --fleet-mode threads"
                        .into(),
                );
            }
        }
        if self.infer_precision == InferPrecision::Int8 {
            if self.backend == Backend::Xla {
                return Err(
                    "infer_precision int8 quantizes the native kernel path — the \
                     XLA artifacts are compiled f32; use --backend native (or drop \
                     --infer-precision)"
                        .into(),
                );
            }
            if self.inference_mode != InferenceMode::Shared {
                return Err(
                    "infer_precision int8 applies to the shared inference pool's \
                     publish-time snapshots; local mode actors read the f32 flat \
                     vector directly — use --inference-mode shared"
                        .into(),
                );
            }
        }
        if self.learner_shards > 1 && self.algo != Algo::Ppo {
            return Err(format!(
                "learner_shards = {} is a PPO-only knob (data-parallel PPO \
                 gradient sharding, §6.2); algo {:?} runs a single replay \
                 learner — drop --learner-shards or switch to --algo ppo",
                self.learner_shards, self.algo.name()
            ));
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            return Err("checkpoint_every needs a non-empty checkpoint_dir".into());
        }
        if !self.fault_inject.is_empty() {
            crate::util::fault::FaultPlan::parse(&self.fault_inject)
                .map_err(|e| format!("bad fault_inject spec: {e}"))?;
        }
        if self.flip_schedule > 0
            && (self.inference_mode != InferenceMode::Shared
                || self.infer_epoch != InferEpoch::Pool)
        {
            return Err(
                "flip_schedule drives the pool epoch gate — it needs \
                 --inference-mode shared with --infer-epoch pool"
                    .into(),
            );
        }
        if self.algo == Algo::Td3 {
            // td3 + xla is allowed: the sampler-side actor is the DDPG
            // deterministic actor, so it reuses the act_ddpg_b{B} AOT
            // artifacts; the twin-critic learner always runs native math
            // (learner_threads > 1 + xla is still rejected below).
            if self.td3.batch == 0 {
                return Err("td3.batch must be > 0".into());
            }
            if self.td3.policy_delay == 0 {
                return Err("td3.policy_delay must be >= 1 (1 = update the \
                     actor every critic step, DDPG-style)"
                    .into());
            }
            if !(0.0..=1.0).contains(&self.td3.gamma) {
                return Err("td3.gamma must be in [0,1]".into());
            }
        }
        if self.algo == Algo::Sac {
            if self.backend == Backend::Xla {
                return Err(
                    "algo sac has no AOT/XLA artifacts yet — its soft \
                     actor-critic learner runs native math only; use \
                     --backend native"
                        .into(),
                );
            }
            if self.sac.batch == 0 {
                return Err("sac.batch must be > 0".into());
            }
            if !(0.0..=1.0).contains(&self.sac.gamma) {
                return Err("sac.gamma must be in [0,1]".into());
            }
            if self.sac.init_alpha <= 0.0 {
                return Err("sac.init_alpha must be > 0 (the temperature is \
                     parameterized as log(alpha))"
                    .into());
            }
            if self.infer_precision == InferPrecision::Int8 {
                return Err(
                    "infer_precision int8 snapshots the deterministic actor \
                     head; the SAC tanh-Gaussian actor has no int8 path yet \
                     — drop --infer-precision"
                        .into(),
                );
            }
        }
        if self.replay_shards == 0 {
            return Err("replay_shards must be >= 1".into());
        }
        if self.learner_threads == 0 {
            return Err("learner_threads must be >= 1".into());
        }
        if self.algo == Algo::Ppo {
            if self.replay_shards > 1 {
                return Err(format!(
                    "replay_shards = {} is an off-policy-only knob (the \
                     DDPG/TD3/SAC replay store); PPO learns on-policy \
                     without a replay buffer — drop --replay-shards or \
                     switch algo",
                    self.replay_shards
                ));
            }
            if self.learner_threads > 1 {
                return Err(format!(
                    "learner_threads = {} is an off-policy-only knob (the \
                     DDPG/TD3 grained gradient pool); PPO parallelism is \
                     --learner-shards — drop --learner-threads or switch \
                     algo",
                    self.learner_threads
                ));
            }
            if self.replay_strategy != ReplayStrategy::Uniform {
                return Err(
                    "replay_strategy is an off-policy-only knob (the \
                     DDPG/TD3 replay store); PPO learns on-policy without \
                     a replay buffer — drop --replay-strategy or switch \
                     algo"
                        .into(),
                );
            }
        }
        if self.learner_threads > 1 {
            if self.backend == Backend::Xla {
                return Err(
                    "learner_threads > 1 grains the native gradient math \
                     behind a fixed-order tree reduction; the fused XLA \
                     learner path cannot grain — use --backend native"
                        .into(),
                );
            }
            if self.algo == Algo::Sac {
                return Err(
                    "learner_threads > 1 is not wired for SAC yet (its \
                     learner runs single-threaded); drop --learner-threads \
                     or use --algo ddpg/td3"
                        .into(),
                );
            }
        }
        if self.replay_strategy == ReplayStrategy::Prioritized {
            if self.backend == Backend::Xla {
                return Err(
                    "replay_strategy prioritized applies per-row importance \
                     weights in the native critic grains; the fused XLA \
                     learner is unweighted — use --backend native"
                        .into(),
                );
            }
            if self.algo == Algo::Sac {
                return Err(
                    "replay_strategy prioritized is not wired for SAC yet \
                     (its learner samples uniformly); drop \
                     --replay-strategy or use --algo ddpg/td3"
                        .into(),
                );
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("env".into(), Json::Str(self.env.clone()));
        m.insert("algo".into(), Json::Str(self.algo.name().into()));
        m.insert("backend".into(), Json::Str(self.backend.name().into()));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("samplers".into(), Json::Num(self.samplers as f64));
        m.insert(
            "envs_per_sampler".into(),
            Json::Num(self.envs_per_sampler as f64),
        );
        m.insert(
            "inference_mode".into(),
            Json::Str(self.inference_mode.name().into()),
        );
        m.insert(
            "infer_shards".into(),
            Json::Str(self.infer_shards.name()),
        );
        m.insert("infer_wait".into(), Json::Str(self.infer_wait.name()));
        m.insert(
            "infer_epoch".into(),
            Json::Str(self.infer_epoch.name().into()),
        );
        m.insert(
            "infer_precision".into(),
            Json::Str(self.infer_precision.name().into()),
        );
        m.insert("kernels".into(), Json::Str(self.kernels.name().into()));
        m.insert("env_engine".into(), Json::Str(self.env_engine.name().into()));
        m.insert(
            "samples_per_iter".into(),
            Json::Num(self.samples_per_iter as f64),
        );
        m.insert("iterations".into(), Json::Num(self.iterations as f64));
        m.insert(
            "queue_capacity".into(),
            Json::Num(self.queue_capacity as f64),
        );
        m.insert("chunk_steps".into(), Json::Num(self.chunk_steps as f64));
        m.insert("async_mode".into(), Json::Bool(self.async_mode));
        m.insert("norm_obs".into(), Json::Bool(self.norm_obs));
        m.insert("reward_scale".into(), Json::Num(self.reward_scale as f64));
        m.insert("artifacts_dir".into(), Json::Str(self.artifacts_dir.clone()));
        m.insert(
            "hidden".into(),
            Json::Arr(self.hidden.iter().map(|&h| Json::Num(h as f64)).collect()),
        );
        m.insert(
            "learner_shards".into(),
            Json::Num(self.learner_shards as f64),
        );
        m.insert(
            "replay_shards".into(),
            Json::Num(self.replay_shards as f64),
        );
        m.insert(
            "learner_threads".into(),
            Json::Num(self.learner_threads as f64),
        );
        m.insert(
            "replay_strategy".into(),
            Json::Str(self.replay_strategy.name().into()),
        );
        m.insert("max_staleness".into(), Json::Num(self.max_staleness as f64));
        m.insert(
            "checkpoint_every".into(),
            Json::Num(self.checkpoint_every as f64),
        );
        m.insert(
            "checkpoint_dir".into(),
            Json::Str(self.checkpoint_dir.clone()),
        );
        m.insert("resume".into(), Json::Str(self.resume.clone()));
        m.insert("fault_inject".into(), Json::Str(self.fault_inject.clone()));
        m.insert(
            "flip_schedule".into(),
            Json::Num(self.flip_schedule as f64),
        );
        m.insert("max_restarts".into(), Json::Num(self.max_restarts as f64));
        m.insert("fleet_mode".into(), Json::Str(self.fleet_mode.name().into()));
        m.insert("ppo".into(), self.ppo.to_json());
        m.insert("ddpg".into(), self.ddpg.to_json());
        m.insert("td3".into(), self.td3.to_json());
        m.insert("sac".into(), self.sac.to_json());
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<TrainConfig, JsonError> {
        let mut cfg = TrainConfig::default();
        if let Some(v) = j.opt("env") {
            cfg.env = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("algo") {
            cfg.algo = Algo::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad algo {v:?}")))?;
        }
        if let Some(v) = j.opt("backend") {
            cfg.backend = Backend::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad backend {v:?}")))?;
        }
        if let Some(v) = j.opt("seed") {
            cfg.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("samplers") {
            cfg.samplers = v.as_usize()?;
        }
        if let Some(v) = j.opt("envs_per_sampler") {
            cfg.envs_per_sampler = v.as_usize()?;
        }
        if let Some(v) = j.opt("inference_mode") {
            cfg.inference_mode = InferenceMode::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad inference_mode {v:?}")))?;
        }
        if let Some(v) = j.opt("infer_shards") {
            // accept "auto"/"4" strings or a bare number
            cfg.infer_shards = match v {
                Json::Num(n) if *n >= 1.0 => InferShards::Fixed(*n as usize),
                _ => InferShards::parse(v.as_str()?)
                    .ok_or_else(|| JsonError::Access(format!("bad infer_shards {v:?}")))?,
            };
        }
        if let Some(v) = j.opt("infer_wait") {
            cfg.infer_wait = match v {
                Json::Num(n) if *n < 0.0 => {
                    return Err(JsonError::Access(format!(
                        "infer_wait {n} is negative — the straggler cut is a \
                         microsecond budget >= 1 (or \"adaptive\")"
                    )))
                }
                Json::Num(n) => InferWait::Fixed(*n as u64),
                _ => InferWait::parse(v.as_str()?)
                    .ok_or_else(|| JsonError::Access(format!("bad infer_wait {v:?}")))?,
            };
        } else if let Some(v) = j.opt("infer_max_wait_us") {
            // legacy (pre-shard) configs: a fixed straggler cut in us
            warn_legacy_infer_max_wait_us();
            cfg.infer_wait = InferWait::Fixed(v.as_f64()? as u64);
        }
        if let Some(v) = j.opt("infer_epoch") {
            cfg.infer_epoch = InferEpoch::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad infer_epoch {v:?}")))?;
        }
        if let Some(v) = j.opt("infer_precision") {
            cfg.infer_precision = InferPrecision::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad infer_precision {v:?}")))?;
        }
        if let Some(v) = j.opt("kernels") {
            cfg.kernels = KernelsCfg::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad kernels {v:?}")))?;
        }
        if let Some(v) = j.opt("env_engine") {
            cfg.env_engine = EnvEngineCfg::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad env_engine {v:?}")))?;
        }
        if let Some(v) = j.opt("samples_per_iter") {
            cfg.samples_per_iter = v.as_usize()?;
        }
        if let Some(v) = j.opt("iterations") {
            cfg.iterations = v.as_usize()?;
        }
        if let Some(v) = j.opt("queue_capacity") {
            cfg.queue_capacity = v.as_usize()?;
        }
        if let Some(v) = j.opt("chunk_steps") {
            cfg.chunk_steps = v.as_usize()?;
        }
        if let Some(v) = j.opt("async_mode") {
            cfg.async_mode = v.as_bool()?;
        }
        if let Some(v) = j.opt("norm_obs") {
            cfg.norm_obs = v.as_bool()?;
        }
        if let Some(v) = j.opt("reward_scale") {
            cfg.reward_scale = v.as_f32()?;
        }
        if let Some(v) = j.opt("artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("hidden") {
            cfg.hidden = v
                .as_arr()?
                .iter()
                .map(|h| h.as_usize())
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = j.opt("learner_shards") {
            cfg.learner_shards = v.as_usize()?;
        }
        if let Some(v) = j.opt("replay_shards") {
            cfg.replay_shards = v.as_usize()?;
        }
        if let Some(v) = j.opt("learner_threads") {
            cfg.learner_threads = v.as_usize()?;
        }
        if let Some(v) = j.opt("replay_strategy") {
            cfg.replay_strategy = ReplayStrategy::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad replay_strategy {v:?}")))?;
        }
        if let Some(v) = j.opt("max_staleness") {
            cfg.max_staleness = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("checkpoint_every") {
            cfg.checkpoint_every = v.as_usize()?;
        }
        if let Some(v) = j.opt("checkpoint_dir") {
            cfg.checkpoint_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("resume") {
            cfg.resume = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("fault_inject") {
            cfg.fault_inject = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("flip_schedule") {
            cfg.flip_schedule = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("max_restarts") {
            cfg.max_restarts = v.as_usize()?;
        }
        if let Some(v) = j.opt("fleet_mode") {
            cfg.fleet_mode = FleetMode::parse(v.as_str()?)
                .ok_or_else(|| JsonError::Access(format!("bad fleet_mode {v:?}")))?;
        }
        if let Some(p) = j.opt("ppo") {
            if let Some(v) = p.opt("epochs") {
                cfg.ppo.epochs = v.as_usize()?;
            }
            if let Some(v) = p.opt("minibatch") {
                cfg.ppo.minibatch = v.as_usize()?;
            }
            if let Some(v) = p.opt("lr") {
                cfg.ppo.lr = v.as_f32()?;
            }
            if let Some(v) = p.opt("lr_anneal") {
                cfg.ppo.lr_anneal = v.as_bool()?;
            }
            if let Some(v) = p.opt("gamma") {
                cfg.ppo.gamma = v.as_f32()?;
            }
            if let Some(v) = p.opt("lam") {
                cfg.ppo.lam = v.as_f32()?;
            }
            if let Some(v) = p.opt("clip") {
                cfg.ppo.clip = v.as_f32()?;
            }
            if let Some(v) = p.opt("ent_coef") {
                cfg.ppo.ent_coef = v.as_f32()?;
            }
            if let Some(v) = p.opt("vf_coef") {
                cfg.ppo.vf_coef = v.as_f32()?;
            }
            if let Some(v) = p.opt("norm_adv") {
                cfg.ppo.norm_adv = v.as_bool()?;
            }
        }
        if let Some(d) = j.opt("ddpg") {
            if let Some(v) = d.opt("batch") {
                cfg.ddpg.batch = v.as_usize()?;
            }
            if let Some(v) = d.opt("gamma") {
                cfg.ddpg.gamma = v.as_f32()?;
            }
            if let Some(v) = d.opt("tau") {
                cfg.ddpg.tau = v.as_f32()?;
            }
            if let Some(v) = d.opt("lr_actor") {
                cfg.ddpg.lr_actor = v.as_f32()?;
            }
            if let Some(v) = d.opt("lr_critic") {
                cfg.ddpg.lr_critic = v.as_f32()?;
            }
            if let Some(v) = d.opt("replay_capacity") {
                cfg.ddpg.replay_capacity = v.as_usize()?;
            }
            if let Some(v) = d.opt("warmup_steps") {
                cfg.ddpg.warmup_steps = v.as_usize()?;
            }
            if let Some(v) = d.opt("explore_noise") {
                cfg.ddpg.explore_noise = v.as_f32()?;
            }
            if let Some(v) = d.opt("updates_per_iter") {
                cfg.ddpg.updates_per_iter = v.as_usize()?;
            }
        }
        if let Some(t) = j.opt("td3") {
            if let Some(v) = t.opt("batch") {
                cfg.td3.batch = v.as_usize()?;
            }
            if let Some(v) = t.opt("gamma") {
                cfg.td3.gamma = v.as_f32()?;
            }
            if let Some(v) = t.opt("tau") {
                cfg.td3.tau = v.as_f32()?;
            }
            if let Some(v) = t.opt("lr_actor") {
                cfg.td3.lr_actor = v.as_f32()?;
            }
            if let Some(v) = t.opt("lr_critic") {
                cfg.td3.lr_critic = v.as_f32()?;
            }
            if let Some(v) = t.opt("replay_capacity") {
                cfg.td3.replay_capacity = v.as_usize()?;
            }
            if let Some(v) = t.opt("warmup_steps") {
                cfg.td3.warmup_steps = v.as_usize()?;
            }
            if let Some(v) = t.opt("explore_noise") {
                cfg.td3.explore_noise = v.as_f32()?;
            }
            if let Some(v) = t.opt("updates_per_iter") {
                cfg.td3.updates_per_iter = v.as_usize()?;
            }
            if let Some(v) = t.opt("policy_delay") {
                cfg.td3.policy_delay = v.as_usize()?;
            }
            if let Some(v) = t.opt("target_noise") {
                cfg.td3.target_noise = v.as_f32()?;
            }
            if let Some(v) = t.opt("noise_clip") {
                cfg.td3.noise_clip = v.as_f32()?;
            }
        }
        if let Some(s) = j.opt("sac") {
            if let Some(v) = s.opt("batch") {
                cfg.sac.batch = v.as_usize()?;
            }
            if let Some(v) = s.opt("gamma") {
                cfg.sac.gamma = v.as_f32()?;
            }
            if let Some(v) = s.opt("tau") {
                cfg.sac.tau = v.as_f32()?;
            }
            if let Some(v) = s.opt("lr_actor") {
                cfg.sac.lr_actor = v.as_f32()?;
            }
            if let Some(v) = s.opt("lr_critic") {
                cfg.sac.lr_critic = v.as_f32()?;
            }
            if let Some(v) = s.opt("lr_alpha") {
                cfg.sac.lr_alpha = v.as_f32()?;
            }
            if let Some(v) = s.opt("init_alpha") {
                cfg.sac.init_alpha = v.as_f32()?;
            }
            if let Some(v) = s.opt("replay_capacity") {
                cfg.sac.replay_capacity = v.as_usize()?;
            }
            if let Some(v) = s.opt("warmup_steps") {
                cfg.sac.warmup_steps = v.as_usize()?;
            }
            if let Some(v) = s.opt("updates_per_iter") {
                cfg.sac.updates_per_iter = v.as_usize()?;
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<TrainConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        let cfg = TrainConfig::from_json(&j)?;
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(cfg)
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        TrainConfig::default().validate().unwrap();
        for env in ["pendulum", "cartpole", "reacher", "halfcheetah"] {
            TrainConfig::preset(env).validate().unwrap();
        }
    }

    #[test]
    fn json_round_trip_is_identity() {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.algo = Algo::Ddpg;
        cfg.backend = Backend::Xla;
        cfg.seed = 1234;
        cfg.ppo.lr = 1e-3;
        cfg.ddpg.tau = 0.01;
        cfg.learner_shards = 4;
        cfg.envs_per_sampler = 8;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_shards = InferShards::Fixed(2);
        cfg.infer_wait = InferWait::Fixed(750);
        cfg.infer_epoch = InferEpoch::Shard;
        cfg.checkpoint_every = 5;
        cfg.checkpoint_dir = "ckpts".into();
        cfg.resume = "old-ckpts".into();
        cfg.fault_inject = "worker:1@tick:500".into();
        cfg.flip_schedule = 32;
        cfg.max_restarts = 3;
        cfg.fleet_mode = FleetMode::Procs;
        let j = cfg.to_json();
        let back = TrainConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"env": "pendulum", "samplers": 3}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.env, "pendulum");
        assert_eq!(cfg.samplers, 3);
        assert_eq!(cfg.ppo.epochs, PpoCfg::default().epochs);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = TrainConfig::default();
        cfg.samplers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.chunk_steps = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.ppo.gamma = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.learner_shards = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.envs_per_sampler = 0;
        assert!(cfg.validate().is_err());
        // every env must get at least one step per iteration
        let mut cfg = TrainConfig::default();
        cfg.samplers = 4;
        cfg.envs_per_sampler = 64;
        cfg.samples_per_iter = 100;
        cfg.chunk_steps = 50;
        assert!(cfg.validate().is_err());
        cfg.samples_per_iter = 4_000;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn bad_enum_strings_error() {
        let j = Json::parse(r#"{"algo": "a2c"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"backend": "gpu"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"inference_mode": "remote"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }

    #[test]
    fn inference_mode_parses_and_defaults_local() {
        assert_eq!(TrainConfig::default().inference_mode, InferenceMode::Local);
        assert_eq!(InferenceMode::parse("shared"), Some(InferenceMode::Shared));
        assert_eq!(InferenceMode::parse("local"), Some(InferenceMode::Local));
        assert_eq!(InferenceMode::parse("gpu"), None);
        let j = Json::parse(
            r#"{"inference_mode": "shared", "infer_wait": "fixed:50", "infer_shards": "2"}"#,
        )
        .unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.inference_mode, InferenceMode::Shared);
        assert_eq!(cfg.infer_wait, InferWait::Fixed(50));
        assert_eq!(cfg.infer_shards, InferShards::Fixed(2));
    }

    #[test]
    fn infer_knobs_parse_and_default() {
        let d = TrainConfig::default();
        assert_eq!(d.infer_shards, InferShards::Auto);
        assert_eq!(d.infer_wait, InferWait::Adaptive);
        assert_eq!(InferShards::parse("auto"), Some(InferShards::Auto));
        assert_eq!(InferShards::parse("4"), Some(InferShards::Fixed(4)));
        assert_eq!(InferShards::parse("0"), None);
        assert_eq!(InferShards::parse("many"), None);
        assert_eq!(InferWait::parse("adaptive"), Some(InferWait::Adaptive));
        assert_eq!(InferWait::parse("fixed:200"), Some(InferWait::Fixed(200)));
        assert_eq!(InferWait::parse("350"), Some(InferWait::Fixed(350)));
        assert_eq!(InferWait::parse("fixed:"), None);
        assert_eq!(InferWait::parse("never"), None);
        // round-trippable spellings
        assert_eq!(InferShards::Auto.name(), "auto");
        assert_eq!(InferShards::Fixed(4).name(), "4");
        assert_eq!(InferWait::Adaptive.name(), "adaptive");
        assert_eq!(InferWait::Fixed(200).name(), "fixed:200");
    }

    #[test]
    fn infer_shards_resolution() {
        // auto = clamp(N/8, 1, cores/2), never exceeding N
        assert_eq!(InferShards::Auto.resolve_with(1, 16), 1);
        assert_eq!(InferShards::Auto.resolve_with(8, 16), 1);
        assert_eq!(InferShards::Auto.resolve_with(16, 16), 2);
        assert_eq!(InferShards::Auto.resolve_with(64, 16), 8);
        assert_eq!(InferShards::Auto.resolve_with(256, 16), 8); // cores/2 cap
        assert_eq!(InferShards::Auto.resolve_with(256, 2), 1); // tiny machine
        assert_eq!(InferShards::Auto.resolve_with(2, 64), 1); // S <= N
        assert_eq!(InferShards::Fixed(4).resolve_with(16, 16), 4);
        assert_eq!(InferShards::Fixed(9).resolve_with(4, 16), 4); // clamp to N
    }

    #[test]
    fn infer_epoch_parses_and_defaults_pool() {
        assert_eq!(TrainConfig::default().infer_epoch, InferEpoch::Pool);
        assert_eq!(InferEpoch::parse("pool"), Some(InferEpoch::Pool));
        assert_eq!(InferEpoch::parse("shard"), Some(InferEpoch::Shard));
        assert_eq!(InferEpoch::parse("tick"), None);
        assert_eq!(InferEpoch::Pool.name(), "pool");
        assert_eq!(InferEpoch::Shard.name(), "shard");
        let j = Json::parse(r#"{"infer_epoch": "shard"}"#).unwrap();
        assert_eq!(
            TrainConfig::from_json(&j).unwrap().infer_epoch,
            InferEpoch::Shard
        );
        assert!(TrainConfig::from_json(&Json::parse(r#"{"infer_epoch": "x"}"#).unwrap())
            .is_err());
    }

    /// Satellite regression: the pre-shard `infer_max_wait_us` key still
    /// round-trips as `InferWait::Fixed` and fires its deprecation
    /// warning exactly once per process no matter how often it parses.
    #[test]
    fn legacy_infer_max_wait_us_round_trips_and_warns_once() {
        let j = Json::parse(r#"{"infer_max_wait_us": 750}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.infer_wait, InferWait::Fixed(750));
        // the modern spelling comes back out of to_json and parses to the
        // same policy
        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.infer_wait, InferWait::Fixed(750));
        assert_eq!(back, cfg);
        // parse the legacy key again: the warning fired, and the Once
        // guarantees it can never fire a second time
        let _ = TrainConfig::from_json(
            &Json::parse(r#"{"infer_max_wait_us": 10}"#).unwrap(),
        )
        .unwrap();
        assert!(legacy_infer_wait_warned());
    }

    #[test]
    fn legacy_infer_max_wait_us_maps_to_fixed_wait() {
        let j = Json::parse(r#"{"infer_max_wait_us": 500}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.infer_wait, InferWait::Fixed(500));
        // the new key wins when both are present
        let j =
            Json::parse(r#"{"infer_max_wait_us": 500, "infer_wait": "adaptive"}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.infer_wait, InferWait::Adaptive);
        // numeric forms also accepted for the new keys
        let j = Json::parse(r#"{"infer_wait": 120, "infer_shards": 3}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.infer_wait, InferWait::Fixed(120));
        assert_eq!(cfg.infer_shards, InferShards::Fixed(3));
    }

    /// Satellite bugfix: degenerate fixed straggler budgets are rejected
    /// at validation time with an explanation, instead of being silently
    /// clamped (or busy-spun) deep in the dispatch loop at runtime.
    #[test]
    fn infer_wait_fixed_zero_and_overflow_rejected_at_validation() {
        let mut cfg = TrainConfig::default();
        cfg.infer_wait = InferWait::Fixed(0);
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("fixed:0"), "unhelpful message: {err}");
        cfg.infer_wait = InferWait::Fixed(MAX_INFER_WAIT_US + 1);
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("ceiling"), "unhelpful message: {err}");
        // the boundary itself is allowed, as is any sane budget
        cfg.infer_wait = InferWait::Fixed(MAX_INFER_WAIT_US);
        assert!(cfg.validate().is_ok());
        cfg.infer_wait = InferWait::Fixed(1);
        assert!(cfg.validate().is_ok());
        // negative JSON values error with an actionable message rather
        // than silently wrapping through the float cast
        let j = Json::parse(r#"{"infer_wait": -5}"#).unwrap();
        let err = TrainConfig::from_json(&j).unwrap_err();
        assert!(format!("{err:?}").contains("negative"));
    }

    #[test]
    fn fleet_mode_parses_and_procs_constraints_validate() {
        assert_eq!(TrainConfig::default().fleet_mode, FleetMode::Threads);
        assert_eq!(FleetMode::parse("threads"), Some(FleetMode::Threads));
        assert_eq!(FleetMode::parse("procs"), Some(FleetMode::Procs));
        assert_eq!(FleetMode::parse("fork"), None);
        assert_eq!(FleetMode::Threads.name(), "threads");
        assert_eq!(FleetMode::Procs.name(), "procs");
        let j = Json::parse(r#"{"fleet_mode": "procs"}"#).unwrap();
        assert_eq!(
            TrainConfig::from_json(&j).unwrap().fleet_mode,
            FleetMode::Procs
        );
        assert!(
            TrainConfig::from_json(&Json::parse(r#"{"fleet_mode": "x"}"#).unwrap()).is_err()
        );

        // procs requires the shared pool (the daemon IS the pool)
        let mut cfg = TrainConfig::default();
        cfg.fleet_mode = FleetMode::Procs;
        cfg.inference_mode = InferenceMode::Local;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("inference-mode shared"), "message: {err}");
        cfg.inference_mode = InferenceMode::Shared;
        assert!(cfg.validate().is_ok());
        // in-process fault cells cannot reach child processes
        cfg.fault_inject = "worker:0@tick:10".into();
        assert!(cfg.validate().unwrap_err().contains("fault_inject"));
        cfg.fault_inject = String::new();
        // checkpoint/resume snapshots live in the children
        cfg.checkpoint_every = 3;
        assert!(cfg.validate().unwrap_err().contains("checkpoint"));
        cfg.checkpoint_every = 0;
        cfg.resume = "ckpts".into();
        assert!(cfg.validate().unwrap_err().contains("resume"));
        cfg.resume = String::new();
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn shard_validation_requires_a_worker_per_shard() {
        let mut cfg = TrainConfig::default();
        cfg.inference_mode = InferenceMode::Shared;
        cfg.samplers = 4;
        cfg.infer_shards = InferShards::Fixed(8);
        assert!(cfg.validate().is_err());
        cfg.infer_shards = InferShards::Fixed(4);
        assert!(cfg.validate().is_ok());
        // local mode ignores the knob; auto always validates
        cfg.inference_mode = InferenceMode::Local;
        cfg.infer_shards = InferShards::Fixed(8);
        assert!(cfg.validate().is_ok());
        cfg.infer_shards = InferShards::Auto;
        cfg.inference_mode = InferenceMode::Shared;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn infer_precision_and_kernels_parse_round_trip_and_validate() {
        let d = TrainConfig::default();
        assert_eq!(d.infer_precision, InferPrecision::F32);
        assert_eq!(d.kernels, KernelsCfg::Exact);
        assert_eq!(InferPrecision::parse("int8"), Some(InferPrecision::Int8));
        assert_eq!(InferPrecision::parse("f16"), None);
        assert_eq!(KernelsCfg::parse("fast"), Some(KernelsCfg::Fast));
        assert_eq!(KernelsCfg::parse("simd"), None);
        assert_eq!(InferPrecision::Int8.name(), "int8");
        assert_eq!(KernelsCfg::Fast.name(), "fast");
        assert_eq!(d.env_engine, EnvEngineCfg::Auto);
        assert_eq!(EnvEngineCfg::parse("scalar"), Some(EnvEngineCfg::Scalar));
        assert_eq!(EnvEngineCfg::parse("soa"), None);
        assert_eq!(EnvEngineCfg::Batched.name(), "batched");
        // auto resolves to the batched engine
        use crate::env::batch::EnvEngine;
        assert_eq!(EnvEngineCfg::Auto.engine(), EnvEngine::Batched);
        assert_eq!(EnvEngineCfg::Scalar.engine(), EnvEngine::Scalar);

        let mut cfg = TrainConfig::preset("pendulum");
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_precision = InferPrecision::Int8;
        cfg.kernels = KernelsCfg::Fast;
        cfg.env_engine = EnvEngineCfg::Scalar;
        cfg.validate().unwrap();
        let back = TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);

        // int8 is a shared-inference native-backend knob
        cfg.backend = Backend::Xla;
        assert!(cfg.validate().unwrap_err().contains("int8"));
        cfg.backend = Backend::Native;
        cfg.inference_mode = InferenceMode::Local;
        assert!(cfg.validate().unwrap_err().contains("shared"));

        assert!(TrainConfig::from_json(
            &Json::parse(r#"{"infer_precision": "int4"}"#).unwrap()
        )
        .is_err());
        assert!(TrainConfig::from_json(&Json::parse(r#"{"kernels": "turbo"}"#).unwrap())
            .is_err());
        assert!(
            TrainConfig::from_json(&Json::parse(r#"{"env_engine": "vector"}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn td3_round_trips_and_validates() {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.algo = Algo::Td3;
        cfg.td3.policy_delay = 3;
        cfg.td3.target_noise = 0.1;
        cfg.td3.noise_clip = 0.3;
        cfg.validate().unwrap();
        let back = TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
        assert_eq!(Algo::parse("td3"), Some(Algo::Td3));
        assert_eq!(Algo::Td3.name(), "td3");
        // td3 + xla validates: the sampler-side actor is DDPG-shaped and
        // reuses the act_ddpg_b{B} AOT artifacts (learner stays native).
        cfg.backend = Backend::Xla;
        cfg.validate().unwrap();
        // ...but the multi-threaded learner still rejects xla learner-side.
        cfg.learner_threads = 2;
        assert!(cfg.validate().unwrap_err().contains("learner_threads"));
        cfg.learner_threads = 1;
        cfg.backend = Backend::Native;
        cfg.td3.policy_delay = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn robustness_knobs_validate() {
        // a malformed fault plan is rejected at config time, not mid-run
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.fault_inject = "worker:zero@tick:9".into();
        assert!(cfg.validate().unwrap_err().contains("fault_inject"));
        cfg.fault_inject = "worker:1@tick:500,shard:0@dispatch:40".into();
        assert!(cfg.validate().is_ok());

        // flip_schedule needs the pool epoch gate to exist
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.flip_schedule = 16;
        assert!(cfg.validate().unwrap_err().contains("flip_schedule"));
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_epoch = InferEpoch::Shard;
        assert!(cfg.validate().is_err());
        cfg.infer_epoch = InferEpoch::Pool;
        assert!(cfg.validate().is_ok());

        // checkpointing needs somewhere to write
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.checkpoint_every = 2;
        cfg.checkpoint_dir = String::new();
        assert!(cfg.validate().is_err());
        cfg.checkpoint_dir = "checkpoints".into();
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn learner_shards_is_a_ppo_only_knob() {
        let mut cfg = TrainConfig::default();
        cfg.learner_shards = 4;
        assert!(cfg.validate().is_ok(), "sharded PPO learning is fine");
        cfg.algo = Algo::Ddpg;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("PPO-only"), "unhelpful error: {err}");
        cfg.algo = Algo::Td3;
        assert!(cfg.validate().is_err());
        cfg.learner_shards = 1;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn sac_round_trips_and_validates() {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.algo = Algo::Sac;
        cfg.sac.init_alpha = 0.1;
        cfg.sac.lr_alpha = 1e-4;
        cfg.validate().unwrap();
        let back = TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
        assert_eq!(Algo::parse("sac"), Some(Algo::Sac));
        assert_eq!(Algo::Sac.name(), "sac");
        // SAC has no AOT artifacts: the XLA backend is rejected loudly
        cfg.backend = Backend::Xla;
        assert!(cfg.validate().unwrap_err().contains("sac"));
        cfg.backend = Backend::Native;
        cfg.sac.init_alpha = 0.0;
        assert!(cfg.validate().is_err());
        cfg.sac.init_alpha = 0.2;
        cfg.sac.batch = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn replay_knobs_parse_round_trip_and_validate() {
        let d = TrainConfig::default();
        assert_eq!(d.replay_shards, 1);
        assert_eq!(d.learner_threads, 1);
        assert_eq!(d.replay_strategy, ReplayStrategy::Uniform);
        assert_eq!(
            ReplayStrategy::parse("prioritized"),
            Some(ReplayStrategy::Prioritized)
        );
        assert_eq!(ReplayStrategy::parse("uniform"), Some(ReplayStrategy::Uniform));
        assert_eq!(ReplayStrategy::parse("rank"), None);
        assert_eq!(ReplayStrategy::Prioritized.name(), "prioritized");

        let mut cfg = TrainConfig::preset("pendulum");
        cfg.algo = Algo::Ddpg;
        cfg.replay_shards = 4;
        cfg.learner_threads = 2;
        cfg.replay_strategy = ReplayStrategy::Prioritized;
        cfg.validate().unwrap();
        let back = TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);

        // zero is never a shard/thread count
        cfg.replay_shards = 0;
        assert!(cfg.validate().is_err());
        cfg.replay_shards = 4;
        cfg.learner_threads = 0;
        assert!(cfg.validate().is_err());
        cfg.learner_threads = 2;

        // the grained reduction and PER weights live in the native path
        cfg.backend = Backend::Xla;
        assert!(cfg.validate().is_err());
        cfg.backend = Backend::Native;

        // SAC takes sharded replay but not threads/prioritized yet
        cfg.algo = Algo::Sac;
        assert!(cfg.validate().unwrap_err().contains("learner_threads"));
        cfg.learner_threads = 1;
        assert!(cfg.validate().unwrap_err().contains("prioritized"));
        cfg.replay_strategy = ReplayStrategy::Uniform;
        assert!(cfg.validate().is_ok());

        assert!(TrainConfig::from_json(
            &Json::parse(r#"{"replay_strategy": "rank"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn replay_knobs_are_off_policy_only() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.algo, Algo::Ppo);
        cfg.replay_shards = 2;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("off-policy-only"), "unhelpful error: {err}");
        cfg.replay_shards = 1;
        cfg.learner_threads = 4;
        assert!(cfg.validate().unwrap_err().contains("off-policy-only"));
        cfg.learner_threads = 1;
        cfg.replay_strategy = ReplayStrategy::Prioritized;
        assert!(cfg.validate().unwrap_err().contains("off-policy-only"));
        cfg.replay_strategy = ReplayStrategy::Uniform;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn file_round_trip() {
        let cfg = TrainConfig::preset("reacher");
        let path = std::env::temp_dir().join("walle_cfg_test.json");
        let path = path.to_str().unwrap();
        cfg.save(path).unwrap();
        let back = TrainConfig::load(path).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_file(path);
    }
}
