//! Vectorized environments: M homogeneous `Env` instances stepped in
//! lockstep behind one contiguous observation buffer.
//!
//! `VecEnv` is the substrate of vectorized sampling (WarpDrive / Spreeze
//! style): one batched policy forward drives all M envs of a sampler
//! worker per sim tick, so inference cost is amortized M-fold without
//! adding threads. Since PR 9 it is a thin adapter over one of two
//! engines: the SoA [`BatchedEnv`](super::batch::BatchedEnv) lockstep
//! engine (default for registry envs — one `step_all` sweep advances all
//! M lanes column-at-a-time), or the legacy per-env scalar fallback
//! (wrapper stacks and third-party `Env` impls). The two are bitwise
//! interchangeable in exact kernel mode, and snapshots are portable
//! across engines. Invariants:
//!
//!   * each env owns an **independent RNG stream**, so env `i`'s
//!     trajectory is bitwise-identical whether it runs inside a `VecEnv`
//!     of size 1 or size M (see the conformance tests below);
//!   * per-env episode state (step count, raw return, time-limit
//!     truncation) lives here, not in the sampler or the engine, so
//!     every consumer agrees on boundary semantics: `terminal` =
//!     env-reported done (GAE must NOT bootstrap through), `truncated` =
//!     time-limit cut (GAE bootstraps with V(s'));
//!   * `step_all` never auto-resets: callers read the post-step
//!     observation (the bootstrap state s') first, then call
//!     [`VecEnv::reset_env`] for each finished env — exactly the ordering
//!     the single-env sampler loop used.

use super::batch::{self, BatchedEnv, EnvEngine};
use super::Env;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::rng::Pcg64;

/// Outcome of one lockstep tick for one env slot.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct VecStepInfo {
    /// Raw (unscaled) reward of this transition.
    pub reward: f32,
    /// True terminal state (env returned done).
    pub terminal: bool,
    /// Time-limit truncation (episode cap reached without terminal).
    pub truncated: bool,
}

impl VecStepInfo {
    /// Episode boundary of any kind (caller must `reset_env` afterwards).
    pub fn ended(&self) -> bool {
        self.terminal || self.truncated
    }
}

/// Complete restorable state of a [`VecEnv`]: per-env dynamics state
/// ([`Env::save_state`]), per-env RNG registers, the contiguous
/// observation buffer, and the episode counters. Restoring it onto a
/// freshly constructed same-shape `VecEnv` continues every trajectory
/// bitwise — the substrate of worker respawn snapshots and durable
/// checkpoints.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VecEnvState {
    /// Per-env `Env::save_state` payloads.
    pub env_state: Vec<Vec<f32>>,
    /// Per-env PCG64 `(state, inc)` registers.
    pub rng: Vec<(u128, u128)>,
    /// Row-major [M * obs_dim] raw observation buffer.
    pub obs: Vec<f32>,
    /// Per-env current-episode step counts.
    pub ep_len: Vec<u64>,
    /// Per-env current-episode raw returns.
    pub ep_return: Vec<f32>,
}

impl VecEnvState {
    /// Serialize into a checkpoint blob (see `util::bytes`).
    pub fn write(&self, w: &mut ByteWriter) {
        w.put_usize(self.env_state.len());
        for s in &self.env_state {
            w.put_f32s(s);
        }
        for &(state, inc) in &self.rng {
            w.put_u128(state);
            w.put_u128(inc);
        }
        w.put_f32s(&self.obs);
        for &l in &self.ep_len {
            w.put_u64(l);
        }
        w.put_f32s(&self.ep_return);
    }

    /// Deserialize a blob produced by [`VecEnvState::write`].
    pub fn read(r: &mut ByteReader) -> anyhow::Result<VecEnvState> {
        let m = r.read_usize()?;
        let mut env_state = Vec::with_capacity(m);
        for _ in 0..m {
            env_state.push(r.read_f32s()?);
        }
        let mut rng = Vec::with_capacity(m);
        for _ in 0..m {
            let state = r.read_u128()?;
            let inc = r.read_u128()?;
            rng.push((state, inc));
        }
        let obs = r.read_f32s()?;
        let mut ep_len = Vec::with_capacity(m);
        for _ in 0..m {
            ep_len.push(r.read_u64()?);
        }
        let ep_return = r.read_f32s()?;
        Ok(VecEnvState {
            env_state,
            rng,
            obs,
            ep_len,
            ep_return,
        })
    }
}

/// The stepping engine behind a [`VecEnv`]: SoA lockstep or legacy
/// per-env scalar (see the module docs).
enum Engine {
    Scalar(Vec<Box<dyn Env>>),
    Batched(Box<dyn BatchedEnv>),
}

/// M homogeneous environments stepped in lockstep with per-env RNG
/// streams and per-env episode accounting.
pub struct VecEnv {
    engine: Engine,
    rngs: Vec<Pcg64>,
    /// Row-major [M * obs_dim] raw observations (current state per env).
    obs: Vec<f32>,
    ep_len: Vec<usize>,
    ep_return: Vec<f32>,
    obs_dim: usize,
    act_dim: usize,
    max_ep: usize,
    m: usize,
}

impl VecEnv {
    /// Bundle `envs` (all the same task) with one RNG stream per env —
    /// the scalar engine (any `Env` impl, including wrapper stacks).
    pub fn new(envs: Vec<Box<dyn Env>>, rngs: Vec<Pcg64>) -> anyhow::Result<VecEnv> {
        anyhow::ensure!(!envs.is_empty(), "VecEnv needs at least one env");
        anyhow::ensure!(
            envs.len() == rngs.len(),
            "VecEnv: {} envs but {} rng streams",
            envs.len(),
            rngs.len()
        );
        let obs_dim = envs[0].obs_dim();
        let act_dim = envs[0].act_dim();
        let max_ep = envs[0].max_episode_steps();
        for e in &envs {
            anyhow::ensure!(
                e.obs_dim() == obs_dim
                    && e.act_dim() == act_dim
                    && e.max_episode_steps() == max_ep,
                "VecEnv requires homogeneous envs"
            );
        }
        let m = envs.len();
        Ok(VecEnv {
            engine: Engine::Scalar(envs),
            rngs,
            obs: vec![0.0; m * obs_dim],
            ep_len: vec![0; m],
            ep_return: vec![0.0; m],
            obs_dim,
            act_dim,
            max_ep,
            m,
        })
    }

    /// Wrap a batched engine with one RNG stream per lane.
    pub fn from_batched(env: Box<dyn BatchedEnv>, rngs: Vec<Pcg64>) -> anyhow::Result<VecEnv> {
        let m = env.num_envs();
        anyhow::ensure!(m > 0, "VecEnv needs at least one env");
        anyhow::ensure!(
            m == rngs.len(),
            "VecEnv: {} lanes but {} rng streams",
            m,
            rngs.len()
        );
        let obs_dim = env.obs_dim();
        let act_dim = env.act_dim();
        let max_ep = env.max_episode_steps();
        Ok(VecEnv {
            engine: Engine::Batched(env),
            rngs,
            obs: vec![0.0; m * obs_dim],
            ep_len: vec![0; m],
            ep_return: vec![0.0; m],
            obs_dim,
            act_dim,
            max_ep,
            m,
        })
    }

    /// Build M instances of a registered env with the process-wide
    /// active engine (see [`batch::active_engine`]). Env `i` gets RNG
    /// stream `first_stream + i`, so the same `(seed, stream)` pair
    /// always reproduces the same trajectory regardless of M, worker
    /// layout, or engine.
    pub fn from_registry(
        name: &str,
        m: usize,
        seed: u64,
        first_stream: u64,
    ) -> anyhow::Result<VecEnv> {
        VecEnv::from_registry_with(name, m, seed, first_stream, batch::active_engine())
    }

    /// Build M instances of a registered env with an explicit engine
    /// (tests/benches that must not depend on the process-global
    /// selection).
    pub fn from_registry_with(
        name: &str,
        m: usize,
        seed: u64,
        first_stream: u64,
        engine: EnvEngine,
    ) -> anyhow::Result<VecEnv> {
        let rngs: Vec<Pcg64> = (0..m)
            .map(|i| Pcg64::with_stream(seed, first_stream + i as u64))
            .collect();
        match engine {
            EnvEngine::Batched => {
                let env = super::registry::make_batched_env(name, m)
                    .ok_or_else(|| anyhow::anyhow!("unknown env {name:?}"))?;
                VecEnv::from_batched(env, rngs)
            }
            EnvEngine::Scalar => {
                let envs = (0..m)
                    .map(|_| {
                        super::registry::make_env(name)
                            .ok_or_else(|| anyhow::anyhow!("unknown env {name:?}"))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?;
                VecEnv::new(envs, rngs)
            }
        }
    }

    /// Which engine this VecEnv runs on.
    pub fn engine(&self) -> EnvEngine {
        match &self.engine {
            Engine::Scalar(_) => EnvEngine::Scalar,
            Engine::Batched(_) => EnvEngine::Batched,
        }
    }

    pub fn num_envs(&self) -> usize {
        self.m
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    pub fn max_episode_steps(&self) -> usize {
        self.max_ep
    }

    pub fn name(&self) -> &'static str {
        match &self.engine {
            Engine::Scalar(envs) => envs[0].name(),
            Engine::Batched(env) => env.name(),
        }
    }

    /// Contiguous raw observations, row-major [M * obs_dim].
    pub fn obs(&self) -> &[f32] {
        &self.obs
    }

    /// Raw observation row of env `i`.
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    /// Steps taken in env `i`'s current episode.
    pub fn ep_len(&self, i: usize) -> usize {
        self.ep_len[i]
    }

    /// Raw (unscaled) return accumulated in env `i`'s current episode.
    pub fn ep_return(&self, i: usize) -> f32 {
        self.ep_return[i]
    }

    /// Reset every env from its own stream (fresh episodes everywhere).
    pub fn reset_all(&mut self) {
        for i in 0..self.m {
            self.reset_env(i);
        }
    }

    /// Reset env `i` only: fresh initial state from env `i`'s RNG stream,
    /// episode counters cleared, observation row rewritten.
    pub fn reset_env(&mut self, i: usize) {
        let row = &mut self.obs[i * self.obs_dim..(i + 1) * self.obs_dim];
        match &mut self.engine {
            Engine::Scalar(envs) => envs[i].reset(&mut self.rngs[i], row),
            Engine::Batched(env) => env.reset_lane(i, &mut self.rngs[i], row),
        }
        self.ep_len[i] = 0;
        self.ep_return[i] = 0.0;
    }

    /// Capture the complete dynamic state of all M envs (dynamics, RNG
    /// registers, observation buffer, episode counters). The payload is
    /// engine-portable: `save_lane` uses the scalar `save_state` layout,
    /// so a snapshot taken on one engine restores on the other.
    pub fn save_state(&self) -> VecEnvState {
        let env_state = match &self.engine {
            Engine::Scalar(envs) => envs.iter().map(|e| e.save_state()).collect(),
            Engine::Batched(env) => (0..self.m).map(|i| env.save_lane(i)).collect(),
        };
        VecEnvState {
            env_state,
            rng: self.rngs.iter().map(|r| r.raw_state()).collect(),
            obs: self.obs.clone(),
            ep_len: self.ep_len.iter().map(|&l| l as u64).collect(),
            ep_return: self.ep_return.clone(),
        }
    }

    /// Restore state captured by [`VecEnv::save_state`] onto a same-shape
    /// `VecEnv` (same env type and M). Future trajectories continue
    /// bitwise from the captured point; callers must NOT `reset_all`
    /// afterwards (that would re-draw initial states and advance RNGs).
    pub fn load_state(&mut self, s: &VecEnvState) -> anyhow::Result<()> {
        let m = self.m;
        anyhow::ensure!(
            s.env_state.len() == m && s.rng.len() == m && s.obs.len() == m * self.obs_dim,
            "VecEnv state shape mismatch: snapshot has {} envs / {} obs, this VecEnv has {} / {}",
            s.env_state.len(),
            s.obs.len(),
            m,
            m * self.obs_dim
        );
        match &mut self.engine {
            Engine::Scalar(envs) => {
                for (e, st) in envs.iter_mut().zip(&s.env_state) {
                    e.load_state(st);
                }
            }
            Engine::Batched(env) => {
                for (i, st) in s.env_state.iter().enumerate() {
                    env.load_lane(i, st);
                }
            }
        }
        for (r, &(state, inc)) in self.rngs.iter_mut().zip(&s.rng) {
            *r = Pcg64::from_raw(state, inc);
        }
        self.obs.copy_from_slice(&s.obs);
        for (l, &v) in self.ep_len.iter_mut().zip(&s.ep_len) {
            *l = v as usize;
        }
        self.ep_return.copy_from_slice(&s.ep_return);
        Ok(())
    }

    /// Step all M envs in index order with `actions` ([M * act_dim],
    /// already clipped by the caller), writing per-env outcomes into
    /// `out` ([M]) and the next observations into the contiguous buffer.
    ///
    /// Finished envs (terminal or truncated) are NOT auto-reset; their
    /// rows hold s' until the caller invokes [`VecEnv::reset_env`].
    pub fn step_all(&mut self, actions: &[f32], out: &mut [VecStepInfo]) {
        debug_assert_eq!(actions.len(), self.m * self.act_dim);
        debug_assert_eq!(out.len(), self.m);
        match &mut self.engine {
            Engine::Scalar(envs) => {
                for (i, env) in envs.iter_mut().enumerate() {
                    let act = &actions[i * self.act_dim..(i + 1) * self.act_dim];
                    let row = &mut self.obs[i * self.obs_dim..(i + 1) * self.obs_dim];
                    let step = env.step(act, row);
                    self.ep_len[i] += 1;
                    self.ep_return[i] += step.reward;
                    out[i] = VecStepInfo {
                        reward: step.reward,
                        terminal: step.done,
                        truncated: !step.done && self.ep_len[i] >= self.max_ep,
                    };
                }
            }
            Engine::Batched(env) => {
                // one SoA sweep writes all M next observations straight
                // into the contiguous buffer; episode accounting below is
                // identical to the scalar arm per lane
                let steps = env.step_all(actions, &mut self.obs);
                for (i, step) in steps.iter().enumerate() {
                    self.ep_len[i] += 1;
                    self.ep_return[i] += step.reward;
                    out[i] = VecStepInfo {
                        reward: step.reward,
                        terminal: step.done,
                        truncated: !step.done && self.ep_len[i] >= self.max_ep,
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::registry::{make_env, ENV_NAMES};

    /// Reference driver: one independent env with its own RNG stream,
    /// mirroring the VecEnv episode bookkeeping exactly.
    struct SingleRef {
        env: Box<dyn Env>,
        rng: Pcg64,
        obs: Vec<f32>,
        ep_len: usize,
        ep_return: f32,
    }

    impl SingleRef {
        fn new(name: &str, seed: u64, stream: u64) -> SingleRef {
            let env = make_env(name).unwrap();
            let obs = vec![0.0; env.obs_dim()];
            SingleRef {
                env,
                rng: Pcg64::with_stream(seed, stream),
                obs,
                ep_len: 0,
                ep_return: 0.0,
            }
        }

        fn reset(&mut self) {
            self.env.reset(&mut self.rng, &mut self.obs);
            self.ep_len = 0;
            self.ep_return = 0.0;
        }

        fn step(&mut self, act: &[f32]) -> VecStepInfo {
            let s = self.env.step(act, &mut self.obs);
            self.ep_len += 1;
            self.ep_return += s.reward;
            VecStepInfo {
                reward: s.reward,
                terminal: s.done,
                truncated: !s.done && self.ep_len >= self.env.max_episode_steps(),
            }
        }
    }

    /// Satellite conformance test: M vectorized envs must produce
    /// bitwise-identical trajectories to M independent single envs driven
    /// with the same per-env RNG streams, including reset-on-done and
    /// time-limit truncation ordering.
    #[test]
    fn lockstep_matches_independent_envs_bitwise() {
        let m = 4;
        let seed = 7u64;
        for name in ENV_NAMES {
            let mut venv = VecEnv::from_registry(name, m, seed, 1).unwrap();
            venv.reset_all();
            let mut refs: Vec<SingleRef> = (0..m)
                .map(|i| SingleRef::new(name, seed, 1 + i as u64))
                .collect();
            for r in refs.iter_mut() {
                r.reset();
            }
            let act_dim = venv.act_dim();
            // action streams are shared between both sides and disjoint
            // from the env dynamics streams
            let mut act_rngs: Vec<Pcg64> = (0..m)
                .map(|i| Pcg64::with_stream(seed, 1000 + i as u64))
                .collect();

            let mut actions = vec![0.0f32; m * act_dim];
            let mut infos = vec![VecStepInfo::default(); m];
            let ticks = venv.max_episode_steps() * 2 + 17; // cross ≥2 truncations
            for tick in 0..ticks {
                for (i, rng) in act_rngs.iter_mut().enumerate() {
                    rng.fill_uniform(
                        &mut actions[i * act_dim..(i + 1) * act_dim],
                        -1.0,
                        1.0,
                    );
                }
                venv.step_all(&actions, &mut infos);
                for (i, r) in refs.iter_mut().enumerate() {
                    let want = r.step(&actions[i * act_dim..(i + 1) * act_dim]);
                    assert_eq!(
                        infos[i], want,
                        "{name} env {i} tick {tick}: step info diverged"
                    );
                    assert_eq!(
                        venv.obs_row(i),
                        &r.obs[..],
                        "{name} env {i} tick {tick}: obs diverged"
                    );
                    assert_eq!(venv.ep_len(i), r.ep_len, "{name} env {i} ep_len");
                    assert_eq!(
                        venv.ep_return(i).to_bits(),
                        r.ep_return.to_bits(),
                        "{name} env {i} ep_return not bitwise equal"
                    );
                    if infos[i].ended() {
                        venv.reset_env(i);
                        r.reset();
                        assert_eq!(
                            venv.obs_row(i),
                            &r.obs[..],
                            "{name} env {i} tick {tick}: reset obs diverged"
                        );
                    }
                }
            }
        }
    }

    /// Env 0's trajectory must not depend on how many siblings share the
    /// VecEnv (per-env streams ⇒ batching is observationally transparent).
    #[test]
    fn trajectory_independent_of_vector_width() {
        for &(name, stream0) in &[("pendulum", 1u64), ("cartpole", 5)] {
            let run = |m: usize| {
                let mut venv = VecEnv::from_registry(name, m, 99, stream0).unwrap();
                venv.reset_all();
                let act_dim = venv.act_dim();
                let mut act_rng = Pcg64::with_stream(99, 777);
                let mut actions = vec![0.0f32; m * act_dim];
                let mut infos = vec![VecStepInfo::default(); m];
                let mut trace = Vec::new();
                for _ in 0..300 {
                    // env 0's action comes from the shared stream; siblings
                    // act independently (their own streams don't matter here)
                    act_rng.fill_uniform(&mut actions[..act_dim], -1.0, 1.0);
                    for i in 1..m {
                        for a in actions[i * act_dim..(i + 1) * act_dim].iter_mut() {
                            *a = 0.0;
                        }
                    }
                    venv.step_all(&actions, &mut infos);
                    trace.push((infos[0].reward.to_bits(), venv.obs_row(0).to_vec()));
                    for i in 0..m {
                        if infos[i].ended() {
                            venv.reset_env(i);
                        }
                    }
                }
                trace
            };
            assert_eq!(run(1), run(8), "{name}: env 0 trajectory depends on M");
        }
    }

    /// Every registry env must restore bitwise through the VecEnv
    /// snapshot path (incl. serialization), mid-episode and across
    /// resets — the contract worker respawn and checkpoints rely on.
    #[test]
    fn snapshot_round_trip_continues_bitwise_for_all_envs() {
        for name in ENV_NAMES {
            let m = 2;
            let mut live = VecEnv::from_registry(name, m, 21, 1).unwrap();
            live.reset_all();
            let act_dim = live.act_dim();
            let mut act_rng = Pcg64::with_stream(21, 500);
            let mut actions = vec![0.0f32; m * act_dim];
            let mut infos = vec![VecStepInfo::default(); m];
            for _ in 0..13 {
                act_rng.fill_uniform(&mut actions, -1.0, 1.0);
                live.step_all(&actions, &mut infos);
                for i in 0..m {
                    if infos[i].ended() {
                        live.reset_env(i);
                    }
                }
            }
            // serialize → deserialize → restore into a FRESH VecEnv
            let snap = live.save_state();
            let mut w = crate::util::bytes::ByteWriter::new();
            snap.write(&mut w);
            let buf = w.into_vec();
            let mut r = crate::util::bytes::ByteReader::new(&buf);
            let back = VecEnvState::read(&mut r).unwrap();
            assert!(r.is_empty());
            assert_eq!(snap, back);
            let mut restored = VecEnv::from_registry(name, m, 999, 77).unwrap();
            restored.load_state(&back).unwrap();
            assert_eq!(live.obs(), restored.obs(), "{name}: obs after restore");
            // both sides must now agree bitwise forever; pendulum/reacher
            // cross a reset inside the window, halfcheetah (cap 1000) is
            // clamped to keep the physics cost sane
            let mut infos2 = vec![VecStepInfo::default(); m];
            let ticks = (live.max_episode_steps() + 9).min(230);
            for tick in 0..ticks {
                act_rng.fill_uniform(&mut actions, -1.0, 1.0);
                live.step_all(&actions, &mut infos);
                restored.step_all(&actions, &mut infos2);
                assert_eq!(infos, infos2, "{name} tick {tick}: infos diverged");
                assert_eq!(live.obs(), restored.obs(), "{name} tick {tick}: obs diverged");
                for i in 0..m {
                    if infos[i].ended() {
                        live.reset_env(i);
                        restored.reset_env(i);
                    }
                }
            }
        }
    }

    #[test]
    fn wrong_shape_snapshot_rejected() {
        let mut venv = VecEnv::from_registry("pendulum", 2, 3, 1).unwrap();
        venv.reset_all();
        let mut snap = venv.save_state();
        snap.env_state.pop();
        snap.rng.pop();
        assert!(venv.load_state(&snap).is_err());
    }

    #[test]
    fn heterogeneous_envs_rejected() {
        let envs = vec![make_env("pendulum").unwrap(), make_env("cartpole").unwrap()];
        let rngs = vec![Pcg64::new(0), Pcg64::new(1)];
        assert!(VecEnv::new(envs, rngs).is_err());
        assert!(VecEnv::new(vec![], vec![]).is_err());
        let envs = vec![make_env("pendulum").unwrap()];
        assert!(VecEnv::new(envs, vec![]).is_err());
    }

    #[test]
    fn episode_accounting_resets_per_env() {
        let mut venv = VecEnv::from_registry("pendulum", 2, 3, 1).unwrap();
        venv.reset_all();
        let mut infos = vec![VecStepInfo::default(); 2];
        let actions = vec![0.5f32; 2];
        venv.step_all(&actions, &mut infos);
        venv.step_all(&actions, &mut infos);
        assert_eq!(venv.ep_len(0), 2);
        assert_eq!(venv.ep_len(1), 2);
        assert!(venv.ep_return(0) <= 0.0); // pendulum rewards are costs
        venv.reset_env(0);
        assert_eq!(venv.ep_len(0), 0);
        assert_eq!(venv.ep_return(0), 0.0);
        assert_eq!(venv.ep_len(1), 2, "reset_env(0) must not touch env 1");
    }

    #[test]
    fn truncation_flag_fires_exactly_at_cap() {
        let mut venv = VecEnv::from_registry("pendulum", 1, 11, 1).unwrap();
        venv.reset_all();
        let cap = venv.max_episode_steps();
        let mut infos = vec![VecStepInfo::default(); 1];
        for t in 1..=cap {
            venv.step_all(&[0.0], &mut infos);
            assert_eq!(
                infos[0].truncated,
                t == cap,
                "truncation at step {t} (cap {cap})"
            );
            assert!(!infos[0].terminal, "pendulum never terminates");
        }
    }
}
