//! Planar rigid-body dynamics with sequential-impulse constraint solving.
//!
//! This is WALL-E's MuJoCo substitute (DESIGN.md §3): articulated chains of
//! rod-shaped bodies connected by motorized revolute joints with angle
//! limits, a static ground half-plane with Coulomb friction, semi-implicit
//! Euler integration and Baumgarte stabilization — the standard Box2D-style
//! formulation, specialized to what locomotion tasks need.
//!
//! The engine is deliberately *deterministic* (fixed iteration counts, no
//! reordering): identical seeds give identical rollouts, which the
//! coordinator's reproducibility tests rely on.

use super::vec2::{v2, Vec2};

/// A rigid rod (capsule) in the plane. The rod spans `[-half_len, half_len]`
/// along its local x-axis; contacts test both endpoints against the ground.
#[derive(Debug, Clone)]
pub struct Body {
    pub pos: Vec2,
    pub angle: f32,
    pub vel: Vec2,
    pub omega: f32,
    pub force: Vec2,
    pub torque: f32,
    pub half_len: f32,
    pub radius: f32,
    pub inv_mass: f32,
    pub inv_inertia: f32,
}

impl Body {
    /// A rod of given mass/half-length; inertia of a thin rod.
    pub fn rod(pos: Vec2, angle: f32, mass: f32, half_len: f32, radius: f32) -> Body {
        let inertia = mass * (2.0 * half_len) * (2.0 * half_len) / 12.0 + mass * radius * radius / 4.0;
        Body {
            pos,
            angle,
            vel: Vec2::ZERO,
            omega: 0.0,
            force: Vec2::ZERO,
            torque: 0.0,
            half_len,
            radius,
            inv_mass: 1.0 / mass,
            inv_inertia: 1.0 / inertia,
        }
    }

    /// World position of a point given in body-local coordinates.
    pub fn world_point(&self, local: Vec2) -> Vec2 {
        self.pos + local.rotate(self.angle)
    }

    /// Velocity of a world-space point attached to the body.
    pub fn velocity_at(&self, world_point: Vec2) -> Vec2 {
        self.vel + Vec2::cross_scalar(self.omega, world_point - self.pos)
    }

    pub fn endpoints(&self) -> [Vec2; 2] {
        [
            self.world_point(v2(-self.half_len, 0.0)),
            self.world_point(v2(self.half_len, 0.0)),
        ]
    }
}

/// Motorized revolute joint with optional angle limits, expressed between
/// body-local anchor points.
#[derive(Debug, Clone)]
pub struct RevoluteJoint {
    pub body_a: usize,
    pub body_b: usize,
    pub anchor_a: Vec2,
    pub anchor_b: Vec2,
    /// Joint angle limits relative to the reference angle (lo <= hi).
    pub limit: Option<(f32, f32)>,
    /// Reference relative angle (angle_b - angle_a at assembly).
    pub ref_angle: f32,
    /// Motor torque applied this step (+ on B, - on A).
    pub motor_torque: f32,
    // solver state (warm starting)
    impulse: Vec2,
    limit_impulse: f32,
}

impl RevoluteJoint {
    pub fn new(
        body_a: usize,
        body_b: usize,
        anchor_a: Vec2,
        anchor_b: Vec2,
        ref_angle: f32,
        limit: Option<(f32, f32)>,
    ) -> Self {
        Self {
            body_a,
            body_b,
            anchor_a,
            anchor_b,
            limit,
            ref_angle,
            motor_torque: 0.0,
            impulse: Vec2::ZERO,
            limit_impulse: 0.0,
        }
    }

    /// Current joint angle (relative angle minus reference).
    pub fn angle(&self, bodies: &[Body]) -> f32 {
        bodies[self.body_b].angle - bodies[self.body_a].angle - self.ref_angle
    }

    /// Current joint angular velocity.
    pub fn speed(&self, bodies: &[Body]) -> f32 {
        bodies[self.body_b].omega - bodies[self.body_a].omega
    }
}

/// Contact solver state for one body endpoint against the ground.
#[derive(Debug, Clone, Copy, Default)]
struct ContactState {
    normal_impulse: f32,
    tangent_impulse: f32,
}

/// World parameters.
#[derive(Debug, Clone)]
pub struct WorldCfg {
    pub gravity: f32,
    pub ground_y: f32,
    pub friction: f32,
    pub velocity_iters: usize,
    pub baumgarte: f32,
    pub contact_slop: f32,
    /// Linear/angular velocity damping per second (keeps chains tame).
    pub damping: f32,
    /// Hard velocity clamps — guard rails against solver blow-ups.
    pub max_vel: f32,
    pub max_omega: f32,
}

impl Default for WorldCfg {
    fn default() -> Self {
        Self {
            gravity: -9.81,
            ground_y: 0.0,
            friction: 0.9,
            velocity_iters: 12,
            baumgarte: 0.2,
            contact_slop: 0.005,
            damping: 0.02,
            max_vel: 50.0,
            max_omega: 50.0,
        }
    }
}

/// The planar world: bodies + joints + ground.
#[derive(Debug, Clone)]
pub struct World {
    pub cfg: WorldCfg,
    pub bodies: Vec<Body>,
    pub joints: Vec<RevoluteJoint>,
    contacts: Vec<ContactState>,
}

impl World {
    pub fn new(cfg: WorldCfg) -> World {
        World {
            cfg,
            bodies: Vec::new(),
            joints: Vec::new(),
            contacts: Vec::new(),
        }
    }

    pub fn add_body(&mut self, b: Body) -> usize {
        self.bodies.push(b);
        self.contacts.push(ContactState::default());
        self.contacts.push(ContactState::default());
        self.bodies.len() - 1
    }

    pub fn add_joint(&mut self, j: RevoluteJoint) -> usize {
        self.joints.push(j);
        self.joints.len() - 1
    }

    /// Apply a motor torque to joint `j` for the next step.
    pub fn set_motor(&mut self, j: usize, torque: f32) {
        self.joints[j].motor_torque = torque;
    }

    /// Advance one fixed timestep.
    pub fn step(&mut self, dt: f32) {
        let cfg = self.cfg.clone();

        // --- integrate velocities (gravity, applied forces, motors, damping)
        for b in &mut self.bodies {
            if b.inv_mass > 0.0 {
                b.vel += (v2(0.0, cfg.gravity) + b.force * b.inv_mass) * dt;
                b.omega += b.torque * b.inv_inertia * dt;
                let d = 1.0 / (1.0 + cfg.damping * dt);
                b.vel = b.vel * d;
                b.omega *= d;
            }
            b.force = Vec2::ZERO;
            b.torque = 0.0;
        }
        for j in 0..self.joints.len() {
            let (a, bb, tau) = {
                let jt = &self.joints[j];
                (jt.body_a, jt.body_b, jt.motor_torque)
            };
            self.bodies[a].omega -= tau * self.bodies[a].inv_inertia * dt;
            self.bodies[bb].omega += tau * self.bodies[bb].inv_inertia * dt;
        }

        // --- solve velocity constraints (joints + contacts), warm-started
        for _ in 0..cfg.velocity_iters {
            self.solve_joints(dt);
            self.solve_contacts(dt);
        }

        // --- integrate positions + clamp runaway velocities
        for b in &mut self.bodies {
            let sp = b.vel.len();
            if sp > cfg.max_vel {
                b.vel = b.vel * (cfg.max_vel / sp);
            }
            b.omega = b.omega.clamp(-cfg.max_omega, cfg.max_omega);
            b.pos += b.vel * dt;
            b.angle += b.omega * dt;
        }
    }

    fn solve_joints(&mut self, dt: f32) {
        let baumgarte = self.cfg.baumgarte;
        for j in 0..self.joints.len() {
            let (ia, ib, anchor_a, anchor_b, limit, ref_angle) = {
                let jt = &self.joints[j];
                (
                    jt.body_a,
                    jt.body_b,
                    jt.anchor_a,
                    jt.anchor_b,
                    jt.limit,
                    jt.ref_angle,
                )
            };
            let (pa, aa, va, wa, ima, iia) = {
                let b = &self.bodies[ia];
                (b.pos, b.angle, b.vel, b.omega, b.inv_mass, b.inv_inertia)
            };
            let (pb, ab, vb, wb, imb, iib) = {
                let b = &self.bodies[ib];
                (b.pos, b.angle, b.vel, b.omega, b.inv_mass, b.inv_inertia)
            };
            let ra = anchor_a.rotate(aa);
            let rb = anchor_b.rotate(ab);

            // Point-velocity constraint: vB + wB×rB - vA - wA×rA = -bias
            let cdot = vb + Vec2::cross_scalar(wb, rb) - va - Vec2::cross_scalar(wa, ra);
            let c = (pb + rb) - (pa + ra); // positional drift
            let bias = c * (baumgarte / dt);

            // K = (1/mA + 1/mB) I + iiA [ra]x[ra]x' + iiB [rb]x[rb]x'
            let k11 = ima + imb + iia * ra.y * ra.y + iib * rb.y * rb.y;
            let k12 = -iia * ra.x * ra.y - iib * rb.x * rb.y;
            let k22 = ima + imb + iia * ra.x * ra.x + iib * rb.x * rb.x;
            let det = k11 * k22 - k12 * k12;
            if det.abs() < 1e-12 {
                continue;
            }
            let rhs = -(cdot + bias);
            let imp = v2(
                (k22 * rhs.x - k12 * rhs.y) / det,
                (k11 * rhs.y - k12 * rhs.x) / det,
            );

            let ba = &mut self.bodies[ia];
            ba.vel = ba.vel - imp * ba.inv_mass;
            ba.omega -= ba.inv_inertia * ra.cross(imp);
            let bb = &mut self.bodies[ib];
            bb.vel = bb.vel + imp * bb.inv_mass;
            bb.omega += bb.inv_inertia * rb.cross(imp);
            self.joints[j].impulse += imp;

            // --- angle limits (inequality on relative angle)
            if let Some((lo, hi)) = limit {
                let angle = ab - aa - ref_angle;
                let wrel = self.bodies[ib].omega - self.bodies[ia].omega;
                let ii = iia + iib;
                if ii > 0.0 {
                    let mut imp_l = 0.0f32;
                    if angle < lo {
                        let cdot = wrel + (angle - lo) * (baumgarte / dt);
                        imp_l = (-cdot / ii).max(0.0);
                    } else if angle > hi {
                        let cdot = wrel + (angle - hi) * (baumgarte / dt);
                        imp_l = (-cdot / ii).min(0.0);
                    }
                    if imp_l != 0.0 {
                        self.bodies[ia].omega -= iia * imp_l;
                        self.bodies[ib].omega += iib * imp_l;
                        self.joints[j].limit_impulse += imp_l;
                    }
                }
            }
        }
    }

    fn solve_contacts(&mut self, dt: f32) {
        let cfg = &self.cfg;
        for bi in 0..self.bodies.len() {
            for (ei, ep) in self.bodies[bi].endpoints().iter().enumerate() {
                let pen = (cfg.ground_y + self.bodies[bi].radius) - ep.y;
                let ci = bi * 2 + ei;
                if pen < 0.0 {
                    self.contacts[ci] = ContactState::default();
                    continue;
                }
                let b = &self.bodies[bi];
                let r = *ep - b.pos;
                let vn = b.velocity_at(*ep).y;
                let kn = b.inv_mass + b.inv_inertia * r.x * r.x;
                if kn <= 0.0 {
                    continue;
                }
                let bias = -cfg.baumgarte / dt * (pen - cfg.contact_slop).max(0.0);
                let mut dpn = -(vn + bias) / kn;
                // clamp accumulated normal impulse to be repulsive
                let old = self.contacts[ci].normal_impulse;
                let new = (old + dpn).max(0.0);
                dpn = new - old;
                self.contacts[ci].normal_impulse = new;
                {
                    let b = &mut self.bodies[bi];
                    b.vel.y += dpn * b.inv_mass;
                    b.omega += b.inv_inertia * r.x * dpn;
                }

                // friction along x, clamped by μ * Pn
                let b = &self.bodies[bi];
                let vt = b.velocity_at(*ep).x;
                let kt = b.inv_mass + b.inv_inertia * r.y * r.y;
                if kt <= 0.0 {
                    continue;
                }
                let mut dpt = -vt / kt;
                let max_f = cfg.friction * self.contacts[ci].normal_impulse;
                let old_t = self.contacts[ci].tangent_impulse;
                let new_t = (old_t + dpt).clamp(-max_f, max_f);
                dpt = new_t - old_t;
                self.contacts[ci].tangent_impulse = new_t;
                let b = &mut self.bodies[bi];
                b.vel.x += dpt * b.inv_mass;
                b.omega -= b.inv_inertia * r.y * dpt;
            }
        }
    }

    /// Reset all solver warm-start state (call on env reset).
    pub fn reset_solver_state(&mut self) {
        for c in &mut self.contacts {
            *c = ContactState::default();
        }
        for j in &mut self.joints {
            j.impulse = Vec2::ZERO;
            j.limit_impulse = 0.0;
        }
    }

    /// Serialize the world's complete dynamic state (body kinematics and
    /// pending forces, joint motor torques and warm-start impulses,
    /// contact warm-start impulses) as flat f32s. Geometry, masses and
    /// `WorldCfg` are construction-time data and are NOT included: a
    /// same-topology world restored via [`World::load_state`] continues
    /// the trajectory bitwise (the checkpoint/respawn contract).
    pub fn save_state(&self) -> Vec<f32> {
        let mut out =
            Vec::with_capacity(self.bodies.len() * 9 + self.joints.len() * 4 + self.contacts.len() * 2);
        for b in &self.bodies {
            out.extend_from_slice(&[
                b.pos.x, b.pos.y, b.angle, b.vel.x, b.vel.y, b.omega, b.force.x, b.force.y,
                b.torque,
            ]);
        }
        for j in &self.joints {
            out.extend_from_slice(&[j.motor_torque, j.impulse.x, j.impulse.y, j.limit_impulse]);
        }
        for c in &self.contacts {
            out.extend_from_slice(&[c.normal_impulse, c.tangent_impulse]);
        }
        out
    }

    /// Restore dynamic state captured by [`World::save_state`] onto a
    /// world with identical topology (same body/joint/contact counts).
    pub fn load_state(&mut self, state: &[f32]) {
        let expect = self.bodies.len() * 9 + self.joints.len() * 4 + self.contacts.len() * 2;
        assert_eq!(state.len(), expect, "world state shape mismatch");
        let mut it = state.iter().copied();
        let mut next = || it.next().unwrap();
        for b in &mut self.bodies {
            b.pos.x = next();
            b.pos.y = next();
            b.angle = next();
            b.vel.x = next();
            b.vel.y = next();
            b.omega = next();
            b.force.x = next();
            b.force.y = next();
            b.torque = next();
        }
        for j in &mut self.joints {
            j.motor_torque = next();
            j.impulse.x = next();
            j.impulse.y = next();
            j.limit_impulse = next();
        }
        for c in &mut self.contacts {
            c.normal_impulse = next();
            c.tangent_impulse = next();
        }
    }

    /// Total mechanical energy (diagnostics / tests).
    pub fn energy(&self) -> f32 {
        self.bodies
            .iter()
            .map(|b| {
                let ke = if b.inv_mass > 0.0 {
                    0.5 * b.vel.len2() / b.inv_mass + 0.5 * b.omega * b.omega / b.inv_inertia
                } else {
                    0.0
                };
                let pe = if b.inv_mass > 0.0 {
                    -self.cfg.gravity * b.pos.y / b.inv_mass
                } else {
                    0.0
                };
                ke + pe
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DT: f32 = 0.01;

    #[test]
    fn free_fall_matches_kinematics() {
        let mut w = World::new(WorldCfg {
            ground_y: -1000.0,
            damping: 0.0,
            ..Default::default()
        });
        w.add_body(Body::rod(v2(0.0, 0.0), 0.0, 1.0, 0.5, 0.05));
        for _ in 0..100 {
            w.step(DT);
        }
        // semi-implicit Euler: y = -g * dt^2 * n(n+1)/2
        let n = 100.0f32;
        let want = -9.81 * DT * DT * n * (n + 1.0) / 2.0;
        let got = w.bodies[0].pos.y;
        assert!((got - want).abs() < 0.02, "got={got} want={want}");
    }

    #[test]
    fn ground_stops_falling_body() {
        let mut w = World::new(WorldCfg::default());
        w.add_body(Body::rod(v2(0.0, 1.0), 0.0, 1.0, 0.5, 0.05));
        for _ in 0..500 {
            w.step(DT);
        }
        let b = &w.bodies[0];
        // resting on ground: endpoint y ≈ ground + radius, tiny velocity
        assert!((b.pos.y - b.radius).abs() < 0.02, "y={}", b.pos.y);
        assert!(b.vel.len() < 0.05);
    }

    #[test]
    fn revolute_joint_holds_bodies_together() {
        let mut w = World::new(WorldCfg {
            ground_y: -1000.0,
            ..Default::default()
        });
        let a = w.add_body(Body::rod(v2(0.0, 0.0), 0.0, 5.0, 0.5, 0.05));
        let b = w.add_body(Body::rod(v2(1.0, 0.0), 0.0, 1.0, 0.5, 0.05));
        w.add_joint(RevoluteJoint::new(
            a,
            b,
            v2(0.5, 0.0),
            v2(-0.5, 0.0),
            0.0,
            None,
        ));
        // give B a kick; the joint must keep anchors coincident
        w.bodies[b].vel = v2(3.0, 5.0);
        for _ in 0..300 {
            w.step(DT);
        }
        let pa = w.bodies[a].world_point(v2(0.5, 0.0));
        let pb = w.bodies[b].world_point(v2(-0.5, 0.0));
        assert!((pa - pb).len() < 0.02, "drift={}", (pa - pb).len());
    }

    #[test]
    fn pendulum_swings_under_gravity() {
        // rod pinned to a static body swings when released horizontally
        let mut w = World::new(WorldCfg {
            ground_y: -1000.0,
            damping: 0.0,
            ..Default::default()
        });
        let mut anchor = Body::rod(v2(0.0, 0.0), 0.0, 1.0, 0.1, 0.01);
        anchor.inv_mass = 0.0;
        anchor.inv_inertia = 0.0;
        let a = w.add_body(anchor);
        let b = w.add_body(Body::rod(v2(0.5, 0.0), 0.0, 1.0, 0.5, 0.02));
        w.add_joint(RevoluteJoint::new(a, b, Vec2::ZERO, v2(-0.5, 0.0), 0.0, None));
        for _ in 0..60 {
            w.step(DT);
        }
        // should have swung downward (angle decreased, y below start)
        assert!(w.bodies[b].pos.y < -0.05, "y={}", w.bodies[b].pos.y);
    }

    #[test]
    fn joint_limits_bound_angle() {
        let mut w = World::new(WorldCfg {
            ground_y: -1000.0,
            ..Default::default()
        });
        let mut anchor = Body::rod(v2(0.0, 0.0), 0.0, 1.0, 0.1, 0.01);
        anchor.inv_mass = 0.0;
        anchor.inv_inertia = 0.0;
        let a = w.add_body(anchor);
        let b = w.add_body(Body::rod(v2(0.5, 0.0), 0.0, 1.0, 0.5, 0.02));
        let j = w.add_joint(RevoluteJoint::new(
            a,
            b,
            Vec2::ZERO,
            v2(-0.5, 0.0),
            0.0,
            Some((-0.5, 0.5)),
        ));
        // strong motor trying to spin it past the limit
        for _ in 0..500 {
            w.set_motor(j, 50.0);
            w.step(DT);
        }
        let angle = w.joints[j].angle(&w.bodies);
        assert!(angle < 0.7, "angle={angle} exceeded limit");
    }

    #[test]
    fn motor_torque_spins_joint() {
        let mut w = World::new(WorldCfg {
            ground_y: -1000.0,
            gravity: 0.0,
            ..Default::default()
        });
        let mut anchor = Body::rod(v2(0.0, 0.0), 0.0, 1.0, 0.1, 0.01);
        anchor.inv_mass = 0.0;
        anchor.inv_inertia = 0.0;
        let a = w.add_body(anchor);
        let b = w.add_body(Body::rod(v2(0.5, 0.0), 0.0, 1.0, 0.5, 0.02));
        let j = w.add_joint(RevoluteJoint::new(a, b, Vec2::ZERO, v2(-0.5, 0.0), 0.0, None));
        for _ in 0..50 {
            w.set_motor(j, 2.0);
            w.step(DT);
        }
        assert!(w.joints[j].speed(&w.bodies) > 0.1);
    }

    #[test]
    fn determinism_bitwise() {
        let build = || {
            let mut w = World::new(WorldCfg::default());
            let a = w.add_body(Body::rod(v2(0.0, 0.6), 0.3, 2.0, 0.5, 0.05));
            let b = w.add_body(Body::rod(v2(1.0, 0.6), -0.2, 1.0, 0.4, 0.05));
            w.add_joint(RevoluteJoint::new(
                a,
                b,
                v2(0.5, 0.0),
                v2(-0.4, 0.0),
                -0.5,
                Some((-1.0, 1.0)),
            ));
            w
        };
        let mut w1 = build();
        let mut w2 = build();
        for i in 0..200 {
            let tau = ((i as f32) * 0.1).sin();
            w1.set_motor(0, tau);
            w2.set_motor(0, tau);
            w1.step(DT);
            w2.step(DT);
        }
        assert_eq!(w1.bodies[0].pos, w2.bodies[0].pos);
        assert_eq!(w1.bodies[1].angle, w2.bodies[1].angle);
    }

    #[test]
    fn stack_stays_finite_under_abuse() {
        // random-ish torques on a 3-link chain must not blow up
        let mut w = World::new(WorldCfg::default());
        let mut prev = w.add_body(Body::rod(v2(0.0, 0.5), 0.0, 3.0, 0.5, 0.05));
        for i in 0..3 {
            let nb = w.add_body(Body::rod(
                v2(1.0 + i as f32, 0.5),
                0.0,
                1.0,
                0.4,
                0.05,
            ));
            w.add_joint(RevoluteJoint::new(
                prev,
                nb,
                v2(0.5, 0.0),
                v2(-0.4, 0.0),
                0.0,
                Some((-1.2, 1.2)),
            ));
            prev = nb;
        }
        let mut x = 0u64;
        for _ in 0..2000 {
            for j in 0..w.joints.len() {
                x = crate::util::rng::splitmix64(x);
                let tau = ((x % 200) as f32 / 100.0 - 1.0) * 10.0;
                w.set_motor(j, tau);
            }
            w.step(DT);
        }
        for b in &w.bodies {
            assert!(b.pos.x.is_finite() && b.pos.y.is_finite());
            assert!(b.vel.len() <= w.cfg.max_vel + 1.0);
            assert!(b.pos.y > -1.0, "sank through ground: {}", b.pos.y);
        }
    }
}
