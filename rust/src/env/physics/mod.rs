//! Planar rigid-body physics substrate (the MuJoCo substitute).
//!
//! `vec2` — 2-D vector math; `world` — bodies, motorized revolute joints
//! with limits, ground contacts with friction, sequential-impulse solver;
//! `batch_world` — the same solver over M lockstep worlds stored as
//! structure-of-arrays columns (the batched env engine's substrate).
//! Built from scratch per DESIGN.md §3: the paper's systems claims need a
//! CPU-bound, learnable locomotion substrate, not bit-exact MuJoCo.

pub mod batch_world;
pub mod vec2;
pub mod world;

pub use batch_world::BatchedWorld;
pub use vec2::{v2, Vec2};
pub use world::{Body, RevoluteJoint, World, WorldCfg};
