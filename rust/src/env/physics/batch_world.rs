//! Column-major batched physics: M lockstep copies of one `World`
//! topology, stored as structure-of-arrays columns and advanced with one
//! [`BatchedWorld::step`] sweep.
//!
//! Every per-lane quantity (`pos.x`, `omega`, joint impulses, contact
//! warm-starts, …) lives in its own `[item * M]` column with lane as the
//! fast axis, so the integrator phases stream contiguously and run
//! through the `nn::kernels` `axpy` microkernels. The solver phases
//! (sequential impulses, joint limits, ground contacts) are a mechanical
//! item-outer/lane-inner transcription of `world::World::step`: same
//! operation order, same rounding, every branch inside the lane loop —
//! which makes each lane **bitwise identical** to an independent scalar
//! `World` stepped from the same state (lanes never interact).
//!
//! Topology (body/joint constants, `WorldCfg`) is shared across lanes
//! and captured once from a template `World`; per-lane dynamic state
//! moves in and out via [`BatchedWorld::save_lane`] /
//! [`BatchedWorld::load_lane`] using the exact `World::save_state` flat
//! layout (engine-portable checkpoints).

use super::world::{World, WorldCfg};
use crate::nn::kernels;

/// M lockstep worlds with shared topology and SoA per-lane state.
pub struct BatchedWorld {
    cfg: WorldCfg,
    m: usize,
    // ---- per-body constants (shared by all lanes)
    half_len: Vec<f32>,
    radius: Vec<f32>,
    inv_mass: Vec<f32>,
    inv_inertia: Vec<f32>,
    // ---- per-joint constants
    body_a: Vec<usize>,
    body_b: Vec<usize>,
    anchor_ax: Vec<f32>,
    anchor_ay: Vec<f32>,
    anchor_bx: Vec<f32>,
    anchor_by: Vec<f32>,
    ref_angle: Vec<f32>,
    limit: Vec<Option<(f32, f32)>>,
    // ---- per-body-per-lane state columns, index = body * m + lane
    pos_x: Vec<f32>,
    pos_y: Vec<f32>,
    angle: Vec<f32>,
    vel_x: Vec<f32>,
    vel_y: Vec<f32>,
    omega: Vec<f32>,
    force_x: Vec<f32>,
    force_y: Vec<f32>,
    torque: Vec<f32>,
    // ---- per-joint-per-lane solver state, index = joint * m + lane
    motor_torque: Vec<f32>,
    imp_x: Vec<f32>,
    imp_y: Vec<f32>,
    limit_imp: Vec<f32>,
    // ---- per-contact-per-lane warm starts, index = (body*2+ep) * m + lane
    contact_n: Vec<f32>,
    contact_t: Vec<f32>,
}

impl BatchedWorld {
    /// Replicate `template`'s topology and complete dynamic state
    /// (including solver warm-starts, via the `save_state` layout) into
    /// M identical lanes.
    pub fn from_template(template: &World, m: usize) -> BatchedWorld {
        let nb = template.bodies.len();
        let nj = template.joints.len();
        let mut bw = BatchedWorld {
            cfg: template.cfg.clone(),
            m,
            half_len: template.bodies.iter().map(|b| b.half_len).collect(),
            radius: template.bodies.iter().map(|b| b.radius).collect(),
            inv_mass: template.bodies.iter().map(|b| b.inv_mass).collect(),
            inv_inertia: template.bodies.iter().map(|b| b.inv_inertia).collect(),
            body_a: template.joints.iter().map(|j| j.body_a).collect(),
            body_b: template.joints.iter().map(|j| j.body_b).collect(),
            anchor_ax: template.joints.iter().map(|j| j.anchor_a.x).collect(),
            anchor_ay: template.joints.iter().map(|j| j.anchor_a.y).collect(),
            anchor_bx: template.joints.iter().map(|j| j.anchor_b.x).collect(),
            anchor_by: template.joints.iter().map(|j| j.anchor_b.y).collect(),
            ref_angle: template.joints.iter().map(|j| j.ref_angle).collect(),
            limit: template.joints.iter().map(|j| j.limit).collect(),
            pos_x: vec![0.0; nb * m],
            pos_y: vec![0.0; nb * m],
            angle: vec![0.0; nb * m],
            vel_x: vec![0.0; nb * m],
            vel_y: vec![0.0; nb * m],
            omega: vec![0.0; nb * m],
            force_x: vec![0.0; nb * m],
            force_y: vec![0.0; nb * m],
            torque: vec![0.0; nb * m],
            motor_torque: vec![0.0; nj * m],
            imp_x: vec![0.0; nj * m],
            imp_y: vec![0.0; nj * m],
            limit_imp: vec![0.0; nj * m],
            contact_n: vec![0.0; nb * 2 * m],
            contact_t: vec![0.0; nb * 2 * m],
        };
        let state = template.save_state();
        for lane in 0..m {
            bw.load_lane(lane, &state);
        }
        bw
    }

    pub fn num_lanes(&self) -> usize {
        self.m
    }

    pub fn num_bodies(&self) -> usize {
        self.half_len.len()
    }

    pub fn num_joints(&self) -> usize {
        self.body_a.len()
    }

    /// Flat f32 length of one lane's state (the `World::save_state` len).
    pub fn lane_state_len(&self) -> usize {
        self.num_bodies() * 9 + self.num_joints() * 4 + self.num_bodies() * 2 * 2
    }

    #[inline]
    fn bi(&self, body: usize, lane: usize) -> usize {
        body * self.m + lane
    }

    /// Apply a motor torque to joint `j` of lane `lane` for the next step.
    pub fn set_motor(&mut self, j: usize, lane: usize, torque: f32) {
        self.motor_torque[j * self.m + lane] = torque;
    }

    pub fn body_pos_x(&self, body: usize, lane: usize) -> f32 {
        self.pos_x[self.bi(body, lane)]
    }

    pub fn body_pos_y(&self, body: usize, lane: usize) -> f32 {
        self.pos_y[self.bi(body, lane)]
    }

    pub fn body_angle(&self, body: usize, lane: usize) -> f32 {
        self.angle[self.bi(body, lane)]
    }

    pub fn body_vel_x(&self, body: usize, lane: usize) -> f32 {
        self.vel_x[self.bi(body, lane)]
    }

    pub fn body_vel_y(&self, body: usize, lane: usize) -> f32 {
        self.vel_y[self.bi(body, lane)]
    }

    pub fn body_omega(&self, body: usize, lane: usize) -> f32 {
        self.omega[self.bi(body, lane)]
    }

    /// Joint angle of lane `lane` (matches `RevoluteJoint::angle`).
    pub fn joint_angle(&self, j: usize, lane: usize) -> f32 {
        self.angle[self.bi(self.body_b[j], lane)] - self.angle[self.bi(self.body_a[j], lane)]
            - self.ref_angle[j]
    }

    /// Joint angular velocity of lane `lane` (matches `RevoluteJoint::speed`).
    pub fn joint_speed(&self, j: usize, lane: usize) -> f32 {
        self.omega[self.bi(self.body_b[j], lane)] - self.omega[self.bi(self.body_a[j], lane)]
    }

    /// Advance all M lanes one fixed timestep — the item-outer/lane-inner
    /// transcription of `World::step` (see the module docs).
    pub fn step(&mut self, dt: f32) {
        let m = self.m;
        let cfg = self.cfg.clone();

        // --- integrate velocities (gravity, applied forces, motors, damping)
        for b in 0..self.num_bodies() {
            let im = self.inv_mass[b];
            let ii = self.inv_inertia[b];
            let s = b * m;
            if im > 0.0 {
                let d = 1.0 / (1.0 + cfg.damping * dt);
                for l in 0..m {
                    let i = s + l;
                    self.vel_x[i] += (0.0 + self.force_x[i] * im) * dt;
                    self.vel_y[i] += (cfg.gravity + self.force_y[i] * im) * dt;
                    self.omega[i] += self.torque[i] * ii * dt;
                    self.vel_x[i] *= d;
                    self.vel_y[i] *= d;
                    self.omega[i] *= d;
                }
            }
            self.force_x[s..s + m].fill(0.0);
            self.force_y[s..s + m].fill(0.0);
            self.torque[s..s + m].fill(0.0);
        }
        for j in 0..self.num_joints() {
            let (a, bb) = (self.body_a[j], self.body_b[j]);
            let (iia, iib) = (self.inv_inertia[a], self.inv_inertia[bb]);
            for l in 0..m {
                let tau = self.motor_torque[j * m + l];
                self.omega[a * m + l] -= tau * iia * dt;
                self.omega[bb * m + l] += tau * iib * dt;
            }
        }

        // --- solve velocity constraints (joints + contacts), warm-started
        for _ in 0..cfg.velocity_iters {
            self.solve_joints(dt);
            self.solve_contacts(dt);
        }

        // --- integrate positions + clamp runaway velocities
        for b in 0..self.num_bodies() {
            let s = b * m;
            for l in 0..m {
                let i = s + l;
                let vx = self.vel_x[i];
                let vy = self.vel_y[i];
                let sp = (vx * vx + vy * vy).sqrt();
                if sp > cfg.max_vel {
                    self.vel_x[i] = vx * (cfg.max_vel / sp);
                    self.vel_y[i] = vy * (cfg.max_vel / sp);
                }
                self.omega[i] = self.omega[i].clamp(-cfg.max_omega, cfg.max_omega);
            }
            // pos += vel·dt, angle += ω·dt — contiguous lane columns
            // through the dispatched integrator kernel
            kernels::axpy(dt, &self.vel_x[s..s + m], &mut self.pos_x[s..s + m]);
            kernels::axpy(dt, &self.vel_y[s..s + m], &mut self.pos_y[s..s + m]);
            kernels::axpy(dt, &self.omega[s..s + m], &mut self.angle[s..s + m]);
        }
    }

    fn solve_joints(&mut self, dt: f32) {
        let m = self.m;
        let baumgarte = self.cfg.baumgarte;
        for j in 0..self.num_joints() {
            let (ia, ib) = (self.body_a[j], self.body_b[j]);
            let (ax, ay) = (self.anchor_ax[j], self.anchor_ay[j]);
            let (bx, by) = (self.anchor_bx[j], self.anchor_by[j]);
            let limit = self.limit[j];
            let ref_angle = self.ref_angle[j];
            let (ima, iia) = (self.inv_mass[ia], self.inv_inertia[ia]);
            let (imb, iib) = (self.inv_mass[ib], self.inv_inertia[ib]);
            for l in 0..m {
                let ai = ia * m + l;
                let bi = ib * m + l;
                let (pax, pay, aa) = (self.pos_x[ai], self.pos_y[ai], self.angle[ai]);
                let (vax, vay, wa) = (self.vel_x[ai], self.vel_y[ai], self.omega[ai]);
                let (pbx, pby, ab) = (self.pos_x[bi], self.pos_y[bi], self.angle[bi]);
                let (vbx, vby, wb) = (self.vel_x[bi], self.vel_y[bi], self.omega[bi]);
                // ra = anchor_a.rotate(aa), rb = anchor_b.rotate(ab)
                let (sa, ca) = aa.sin_cos();
                let ra_x = ca * ax - sa * ay;
                let ra_y = sa * ax + ca * ay;
                let (sb, cb) = ab.sin_cos();
                let rb_x = cb * bx - sb * by;
                let rb_y = sb * bx + cb * by;

                // cdot = vb + wb×rb - va - wa×ra (left-associated, like
                // the Vec2 expression in the scalar solver; w×r is
                // (-w·r.y, w·r.x))
                let csb_x = -wb * rb_y;
                let csb_y = wb * rb_x;
                let csa_x = -wa * ra_y;
                let csa_y = wa * ra_x;
                let cdot_x = ((vbx + csb_x) - vax) - csa_x;
                let cdot_y = ((vby + csb_y) - vay) - csa_y;
                let c_x = (pbx + rb_x) - (pax + ra_x);
                let c_y = (pby + rb_y) - (pay + ra_y);
                let bias_x = c_x * (baumgarte / dt);
                let bias_y = c_y * (baumgarte / dt);

                let k11 = ima + imb + iia * ra_y * ra_y + iib * rb_y * rb_y;
                let k12 = -iia * ra_x * ra_y - iib * rb_x * rb_y;
                let k22 = ima + imb + iia * ra_x * ra_x + iib * rb_x * rb_x;
                let det = k11 * k22 - k12 * k12;
                if det.abs() < 1e-12 {
                    continue;
                }
                let rhs_x = -(cdot_x + bias_x);
                let rhs_y = -(cdot_y + bias_y);
                let imp_x = (k22 * rhs_x - k12 * rhs_y) / det;
                let imp_y = (k11 * rhs_y - k12 * rhs_x) / det;

                self.vel_x[ai] -= imp_x * ima;
                self.vel_y[ai] -= imp_y * ima;
                self.omega[ai] -= iia * (ra_x * imp_y - ra_y * imp_x);
                self.vel_x[bi] += imp_x * imb;
                self.vel_y[bi] += imp_y * imb;
                self.omega[bi] += iib * (rb_x * imp_y - rb_y * imp_x);
                self.imp_x[j * m + l] += imp_x;
                self.imp_y[j * m + l] += imp_y;

                // --- angle limits (inequality on relative angle)
                if let Some((lo, hi)) = limit {
                    let angle = ab - aa - ref_angle;
                    let wrel = self.omega[bi] - self.omega[ai];
                    let ii = iia + iib;
                    if ii > 0.0 {
                        let mut imp_l = 0.0f32;
                        if angle < lo {
                            let cdot = wrel + (angle - lo) * (baumgarte / dt);
                            imp_l = (-cdot / ii).max(0.0);
                        } else if angle > hi {
                            let cdot = wrel + (angle - hi) * (baumgarte / dt);
                            imp_l = (-cdot / ii).min(0.0);
                        }
                        if imp_l != 0.0 {
                            self.omega[ai] -= iia * imp_l;
                            self.omega[bi] += iib * imp_l;
                            self.limit_imp[j * m + l] += imp_l;
                        }
                    }
                }
            }
        }
    }

    fn solve_contacts(&mut self, dt: f32) {
        let m = self.m;
        let cfg = self.cfg.clone();
        for b in 0..self.num_bodies() {
            let hl = self.half_len[b];
            let radius = self.radius[b];
            let im = self.inv_mass[b];
            let ii = self.inv_inertia[b];
            // endpoint order matches `Body::endpoints`: -half_len, +half_len
            for (ei, lx) in [-hl, hl].into_iter().enumerate() {
                let ci = b * 2 + ei;
                for l in 0..m {
                    let i = b * m + l;
                    let cil = ci * m + l;
                    // ep = pos + v2(lx, 0).rotate(angle)
                    let (s, c) = self.angle[i].sin_cos();
                    let ly = 0.0f32;
                    let ex = self.pos_x[i] + (c * lx - s * ly);
                    let ey = self.pos_y[i] + (s * lx + c * ly);
                    let pen = (cfg.ground_y + radius) - ey;
                    if pen < 0.0 {
                        self.contact_n[cil] = 0.0;
                        self.contact_t[cil] = 0.0;
                        continue;
                    }
                    let r_x = ex - self.pos_x[i];
                    let r_y = ey - self.pos_y[i];
                    // vn = velocity_at(ep).y = vel.y + ω·r.x
                    let vn = self.vel_y[i] + self.omega[i] * r_x;
                    let kn = im + ii * r_x * r_x;
                    if kn <= 0.0 {
                        continue;
                    }
                    let bias = -cfg.baumgarte / dt * (pen - cfg.contact_slop).max(0.0);
                    let mut dpn = -(vn + bias) / kn;
                    let old = self.contact_n[cil];
                    let new = (old + dpn).max(0.0);
                    dpn = new - old;
                    self.contact_n[cil] = new;
                    self.vel_y[i] += dpn * im;
                    self.omega[i] += ii * r_x * dpn;

                    // friction along x, clamped by μ · Pn
                    // vt = velocity_at(ep).x = vel.x + (-ω·r.y), with the
                    // impulses above already applied
                    let cs_x = -self.omega[i] * r_y;
                    let vt = self.vel_x[i] + cs_x;
                    let kt = im + ii * r_y * r_y;
                    if kt <= 0.0 {
                        continue;
                    }
                    let mut dpt = -vt / kt;
                    let max_f = cfg.friction * self.contact_n[cil];
                    let old_t = self.contact_t[cil];
                    let new_t = (old_t + dpt).clamp(-max_f, max_f);
                    dpt = new_t - old_t;
                    self.contact_t[cil] = new_t;
                    self.vel_x[i] += dpt * im;
                    self.omega[i] -= ii * r_y * dpt;
                }
            }
        }
    }

    /// Serialize lane `lane` in the exact `World::save_state` layout.
    pub fn save_lane(&self, lane: usize) -> Vec<f32> {
        let m = self.m;
        let mut out = Vec::with_capacity(self.lane_state_len());
        for b in 0..self.num_bodies() {
            let i = b * m + lane;
            out.extend_from_slice(&[
                self.pos_x[i],
                self.pos_y[i],
                self.angle[i],
                self.vel_x[i],
                self.vel_y[i],
                self.omega[i],
                self.force_x[i],
                self.force_y[i],
                self.torque[i],
            ]);
        }
        for j in 0..self.num_joints() {
            let i = j * m + lane;
            out.extend_from_slice(&[
                self.motor_torque[i],
                self.imp_x[i],
                self.imp_y[i],
                self.limit_imp[i],
            ]);
        }
        for ci in 0..self.num_bodies() * 2 {
            let i = ci * m + lane;
            out.extend_from_slice(&[self.contact_n[i], self.contact_t[i]]);
        }
        out
    }

    /// Restore lane `lane` from a `World::save_state` payload.
    pub fn load_lane(&mut self, lane: usize, state: &[f32]) {
        assert_eq!(
            state.len(),
            self.lane_state_len(),
            "batched world lane state shape mismatch"
        );
        let m = self.m;
        let mut it = state.iter().copied();
        let mut next = || it.next().unwrap();
        for b in 0..self.half_len.len() {
            let i = b * m + lane;
            self.pos_x[i] = next();
            self.pos_y[i] = next();
            self.angle[i] = next();
            self.vel_x[i] = next();
            self.vel_y[i] = next();
            self.omega[i] = next();
            self.force_x[i] = next();
            self.force_y[i] = next();
            self.torque[i] = next();
        }
        for j in 0..self.body_a.len() {
            let i = j * m + lane;
            self.motor_torque[i] = next();
            self.imp_x[i] = next();
            self.imp_y[i] = next();
            self.limit_imp[i] = next();
        }
        for ci in 0..self.half_len.len() * 2 {
            let i = ci * m + lane;
            self.contact_n[i] = next();
            self.contact_t[i] = next();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::physics::world::Body;
    use crate::env::physics::{v2, World};

    /// A small two-body articulated world with ground contact — enough to
    /// exercise every solver phase (joints, limits, contacts, friction).
    fn template() -> World {
        let mut w = World::new(WorldCfg::default());
        let a = w.add_body(Body::rod(v2(0.0, 0.6), 0.0, 2.0, 0.4, 0.05));
        let b = w.add_body(Body::rod(
            v2(0.4, 0.3),
            std::f32::consts::FRAC_PI_2,
            0.5,
            0.3,
            0.04,
        ));
        w.add_joint(crate::env::physics::RevoluteJoint::new(
            a,
            b,
            v2(0.4, 0.0),
            v2(0.3, 0.0),
            std::f32::consts::FRAC_PI_2,
            Some((-0.8, 0.8)),
        ));
        w.reset_solver_state();
        w
    }

    #[test]
    fn lanes_match_scalar_world_bitwise() {
        let m = 3;
        let mut bw = BatchedWorld::from_template(&template(), m);
        // de-correlate the lanes, then drive scalar references from the
        // exact same lane states
        let mut scalars: Vec<World> = Vec::new();
        for lane in 0..m {
            let mut w = template();
            let mut st = w.save_state();
            for (k, v) in st.iter_mut().enumerate() {
                *v += 0.01 * (lane as f32 + 1.0) * ((k % 5) as f32 - 2.0);
            }
            w.load_state(&st);
            bw.load_lane(lane, &st);
            scalars.push(w);
        }
        for step in 0..200 {
            for (lane, w) in scalars.iter_mut().enumerate() {
                let tau = 0.4 * ((step + lane) as f32 * 0.37).sin();
                w.set_motor(0, tau);
                bw.set_motor(0, lane, tau);
            }
            for w in scalars.iter_mut() {
                w.step(0.01);
            }
            bw.step(0.01);
            for (lane, w) in scalars.iter().enumerate() {
                let want = w.save_state();
                let got = bw.save_lane(lane);
                assert_eq!(want.len(), got.len());
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "step {step} lane {lane} state[{k}]: scalar {a} vs batched {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_state_round_trips() {
        let m = 2;
        let mut bw = BatchedWorld::from_template(&template(), m);
        let mut st = bw.save_lane(1);
        for (k, v) in st.iter_mut().enumerate() {
            *v = k as f32 * 0.125;
        }
        bw.load_lane(1, &st);
        assert_eq!(bw.save_lane(1), st);
        // lane 0 untouched
        assert_eq!(bw.save_lane(0), template().save_state());
    }
}
