//! 2-D vector math for the planar rigid-body engine.

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Plain 2-D vector (f32; the engine is f32 end-to-end like the nets).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

pub const fn v2(x: f32, y: f32) -> Vec2 {
    Vec2 { x, y }
}

impl Vec2 {
    pub const ZERO: Vec2 = v2(0.0, 0.0);

    #[inline]
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    /// 2-D cross product (scalar z-component).
    #[inline]
    pub fn cross(self, o: Vec2) -> f32 {
        self.x * o.y - self.y * o.x
    }

    /// scalar ω × r  (angular velocity crossed with a lever arm).
    #[inline]
    pub fn cross_scalar(w: f32, r: Vec2) -> Vec2 {
        v2(-w * r.y, w * r.x)
    }

    #[inline]
    pub fn len(self) -> f32 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn len2(self) -> f32 {
        self.dot(self)
    }

    /// Rotate by angle (radians).
    #[inline]
    pub fn rotate(self, angle: f32) -> Vec2 {
        let (s, c) = angle.sin_cos();
        v2(c * self.x - s * self.y, s * self.x + c * self.y)
    }

    #[inline]
    pub fn perp(self) -> Vec2 {
        v2(-self.y, self.x)
    }
}

impl Add for Vec2 {
    type Output = Vec2;
    #[inline]
    fn add(self, o: Vec2) -> Vec2 {
        v2(self.x + o.x, self.y + o.y)
    }
}

impl AddAssign for Vec2 {
    #[inline]
    fn add_assign(&mut self, o: Vec2) {
        self.x += o.x;
        self.y += o.y;
    }
}

impl Sub for Vec2 {
    type Output = Vec2;
    #[inline]
    fn sub(self, o: Vec2) -> Vec2 {
        v2(self.x - o.x, self.y - o.y)
    }
}

impl Mul<f32> for Vec2 {
    type Output = Vec2;
    #[inline]
    fn mul(self, s: f32) -> Vec2 {
        v2(self.x * s, self.y * s)
    }
}

impl Neg for Vec2 {
    type Output = Vec2;
    #[inline]
    fn neg(self) -> Vec2 {
        v2(-self.x, -self.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_cross_known() {
        let a = v2(1.0, 2.0);
        let b = v2(3.0, 4.0);
        assert_eq!(a.dot(b), 11.0);
        assert_eq!(a.cross(b), -2.0);
    }

    #[test]
    fn rotate_quarter_turn() {
        let r = v2(1.0, 0.0).rotate(std::f32::consts::FRAC_PI_2);
        assert!((r.x - 0.0).abs() < 1e-6 && (r.y - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cross_scalar_is_perp_times_w() {
        let r = v2(2.0, 1.0);
        let got = Vec2::cross_scalar(3.0, r);
        assert_eq!(got, v2(-3.0, 6.0));
    }

    #[test]
    fn arithmetic() {
        assert_eq!(v2(1.0, 2.0) + v2(3.0, 4.0), v2(4.0, 6.0));
        assert_eq!(v2(1.0, 2.0) - v2(3.0, 4.0), v2(-2.0, -2.0));
        assert_eq!(v2(1.0, 2.0) * 2.0, v2(2.0, 4.0));
        assert_eq!(-v2(1.0, -2.0), v2(-1.0, 2.0));
    }
}
