//! Env wrappers: composable decorators over `Env` (reward scaling, action
//! repeat, observation clipping, episode statistics).

use super::{Env, Step};
use crate::util::rng::Pcg64;

/// Scale rewards by a constant (common PPO trick for wide-range rewards).
pub struct RewardScale<E: Env> {
    pub inner: E,
    pub scale: f32,
}

impl<E: Env> Env for RewardScale<E> {
    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }
    fn act_dim(&self) -> usize {
        self.inner.act_dim()
    }
    fn max_episode_steps(&self) -> usize {
        self.inner.max_episode_steps()
    }
    fn name(&self) -> &'static str {
        self.inner.name()
    }
    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.inner.reset(rng, obs)
    }
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let s = self.inner.step(action, obs);
        Step {
            reward: s.reward * self.scale,
            done: s.done,
        }
    }
    fn save_state(&self) -> Vec<f32> {
        self.inner.save_state()
    }
    fn load_state(&mut self, state: &[f32]) {
        self.inner.load_state(state)
    }
}

/// Repeat each action `k` times, summing rewards (frame-skip at the
/// wrapper level; terminal cuts the repeat short).
pub struct ActionRepeat<E: Env> {
    pub inner: E,
    pub k: usize,
}

impl<E: Env> Env for ActionRepeat<E> {
    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }
    fn act_dim(&self) -> usize {
        self.inner.act_dim()
    }
    fn max_episode_steps(&self) -> usize {
        (self.inner.max_episode_steps() + self.k - 1) / self.k
    }
    fn name(&self) -> &'static str {
        self.inner.name()
    }
    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.inner.reset(rng, obs)
    }
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let mut total = 0.0;
        for _ in 0..self.k {
            let s = self.inner.step(action, obs);
            total += s.reward;
            if s.done {
                return Step {
                    reward: total,
                    done: true,
                };
            }
        }
        Step {
            reward: total,
            done: false,
        }
    }
    fn save_state(&self) -> Vec<f32> {
        self.inner.save_state()
    }
    fn load_state(&mut self, state: &[f32]) {
        self.inner.load_state(state)
    }
}

/// Clip observations into [-bound, bound] (guards the nets against the
/// rare physics-solver spike).
pub struct ObsClip<E: Env> {
    pub inner: E,
    pub bound: f32,
}

impl<E: Env> Env for ObsClip<E> {
    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }
    fn act_dim(&self) -> usize {
        self.inner.act_dim()
    }
    fn max_episode_steps(&self) -> usize {
        self.inner.max_episode_steps()
    }
    fn name(&self) -> &'static str {
        self.inner.name()
    }
    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.inner.reset(rng, obs);
        for v in obs.iter_mut() {
            *v = v.clamp(-self.bound, self.bound);
        }
    }
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let s = self.inner.step(action, obs);
        for v in obs.iter_mut() {
            *v = v.clamp(-self.bound, self.bound);
        }
        s
    }
    fn save_state(&self) -> Vec<f32> {
        self.inner.save_state()
    }
    fn load_state(&mut self, state: &[f32]) {
        self.inner.load_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::pendulum::Pendulum;

    #[test]
    fn reward_scale_multiplies() {
        let mut env = RewardScale {
            inner: Pendulum::default(),
            scale: 0.5,
        };
        let mut base = Pendulum::default();
        let mut rng1 = Pcg64::new(0);
        let mut rng2 = Pcg64::new(0);
        let mut o1 = [0.0f32; 3];
        let mut o2 = [0.0f32; 3];
        env.reset(&mut rng1, &mut o1);
        base.reset(&mut rng2, &mut o2);
        let r1 = env.step(&[0.3], &mut o1).reward;
        let r2 = base.step(&[0.3], &mut o2).reward;
        assert!((r1 - 0.5 * r2).abs() < 1e-6);
    }

    #[test]
    fn action_repeat_sums_rewards() {
        let mut env = ActionRepeat {
            inner: Pendulum::default(),
            k: 4,
        };
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 3];
        env.reset(&mut rng, &mut obs);
        let r = env.step(&[0.0], &mut obs).reward;
        assert!(r <= 0.0); // 4 summed costs
        assert_eq!(env.max_episode_steps(), 50);
    }

    #[test]
    fn obs_clip_bounds_observations() {
        let mut env = ObsClip {
            inner: Pendulum::default(),
            bound: 0.5,
        };
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 3];
        env.reset(&mut rng, &mut obs);
        for _ in 0..50 {
            env.step(&[1.0], &mut obs);
            assert!(obs.iter().all(|v| v.abs() <= 0.5));
        }
    }
}
