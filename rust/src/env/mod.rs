//! Environment substrate: the `Env` trait, concrete continuous-control
//! tasks (pendulum, cartpole, reacher, half-cheetah on the planar physics
//! engine), wrappers, the vectorized [`vec_env::VecEnv`] layer, and a
//! name-based registry.
//!
//! Conventions (enforced by `env::conformance` tests):
//!   * actions live in `[-1, 1]^act_dim`; envs clip then scale internally;
//!   * observations are finite f32;
//!   * `reset` draws initial state from the env's own distribution using
//!     the caller-supplied RNG (reproducible per sampler stream);
//!   * episodes end after `max_episode_steps()` (`VecEnv` enforces the
//!     cap and marks the boundary as a *time-limit truncation*, which GAE
//!     bootstraps through, vs a true `done` which it does not).
//!
//! Vectorized sampling: each sampler worker owns a [`vec_env::VecEnv`] of
//! `envs_per_sampler` homogeneous instances and drives all of them with
//! ONE batched policy forward per sim tick (see `coordinator::sampler`).
//! Per-env RNG streams make the batching observationally transparent: an
//! env's trajectory is bitwise-identical at any vector width.
//!
//! Since PR 9 the registry envs also ship a structure-of-arrays
//! [`batch::BatchedEnv`] implementation (state as `[M]`-wide columns, one
//! `step_all` sweep through the `nn/kernels` microkernels). `VecEnv` is a
//! thin adapter over either engine; in exact kernel mode the two are
//! bitwise interchangeable (asserted by `env::conformance`).

pub mod batch;
pub mod cartpole;
pub mod conformance;
pub mod halfcheetah;
pub mod pendulum;
pub mod physics;
pub mod reacher;
pub mod registry;
pub mod vec_env;
pub mod wrappers;

use crate::util::rng::Pcg64;

/// Result of one environment step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Step {
    pub reward: f32,
    /// True terminal state (failure/goal) — GAE must NOT bootstrap through.
    pub done: bool,
}

/// A single environment instance. `Send` so sampler threads can own one.
pub trait Env: Send {
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;

    /// Episode cap the sampler enforces (time-limit truncation).
    fn max_episode_steps(&self) -> usize;

    /// Reset to a fresh initial state; writes the observation into `obs`.
    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]);

    /// Apply `action` (clipped to [-1,1] by the caller), advance one step,
    /// write the next observation into `obs`.
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step;

    /// Environment name (for logs/metrics).
    fn name(&self) -> &'static str;

    /// Serialize the env's complete dynamic state as flat f32s, such that
    /// [`Env::load_state`] on a same-typed instance reproduces future
    /// trajectories bitwise. Powers worker respawn snapshots and durable
    /// checkpoints (`runtime::checkpoint`). The default returns empty —
    /// fine for stateless test doubles, wrong for real envs, so every
    /// registry env overrides it (asserted by the conformance-style
    /// round-trip tests in `vec_env`).
    fn save_state(&self) -> Vec<f32> {
        Vec::new()
    }

    /// Restore state captured by [`Env::save_state`] on a same-typed env.
    fn load_state(&mut self, _state: &[f32]) {}
}

/// Clip an action slice into [-1, 1] in place (sampler-side helper).
pub fn clip_action(action: &mut [f32]) {
    for a in action.iter_mut() {
        *a = a.clamp(-1.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_action_clamps() {
        let mut a = [-3.0, 0.5, 2.0];
        clip_action(&mut a);
        assert_eq!(a, [-1.0, 0.5, 1.0]);
    }
}
