//! Two-link planar reacher (gym `Reacher-v2` semantics, analytic dynamics).
//!
//! A 2-DoF arm in the horizontal plane (no gravity) must place its
//! fingertip on a random target. Obs(10) = [cos q1, cos q2, sin q1, sin q2,
//! target x, target y, q̇1, q̇2, (fingertip − target) x, y]; action =
//! joint torques in [-1, 1] × gear; reward = −‖fingertip − target‖ −
//! ‖action‖²; 50-step episodes.
//!
//! Dynamics: standard two-link manipulator equations
//! M(q) q̈ + C(q, q̇) q̇ = τ, integrated semi-implicitly.

use super::batch::{BatchStep, BatchedEnv};
use super::{Env, Step};
use crate::nn::kernels;
use crate::util::rng::Pcg64;

pub struct Reacher {
    q: [f32; 2],
    qd: [f32; 2],
    target: [f32; 2],
    l1: f32,
    l2: f32,
    m1: f32,
    m2: f32,
    gear: f32,
    dt: f32,
    damping: f32,
}

impl Default for Reacher {
    fn default() -> Self {
        Self {
            q: [0.0; 2],
            qd: [0.0; 2],
            target: [0.1, 0.1],
            l1: 0.1,
            l2: 0.11,
            m1: 0.05,
            m2: 0.05,
            gear: 0.05,
            dt: 0.02,
            damping: 1.0,
        }
    }
}

impl Reacher {
    pub fn fingertip(&self) -> [f32; 2] {
        let x = self.l1 * self.q[0].cos() + self.l2 * (self.q[0] + self.q[1]).cos();
        let y = self.l1 * self.q[0].sin() + self.l2 * (self.q[0] + self.q[1]).sin();
        [x, y]
    }

    fn write_obs(&self, obs: &mut [f32]) {
        let tip = self.fingertip();
        obs[0] = self.q[0].cos();
        obs[1] = self.q[1].cos();
        obs[2] = self.q[0].sin();
        obs[3] = self.q[1].sin();
        obs[4] = self.target[0];
        obs[5] = self.target[1];
        obs[6] = self.qd[0];
        obs[7] = self.qd[1];
        obs[8] = tip[0] - self.target[0];
        obs[9] = tip[1] - self.target[1];
    }
}

impl Env for Reacher {
    fn obs_dim(&self) -> usize {
        10
    }

    fn act_dim(&self) -> usize {
        2
    }

    fn max_episode_steps(&self) -> usize {
        50
    }

    fn name(&self) -> &'static str {
        "reacher"
    }

    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.q = [
            rng.uniform(-std::f32::consts::PI, std::f32::consts::PI),
            rng.uniform(-std::f32::consts::PI, std::f32::consts::PI),
        ];
        self.qd = [rng.uniform(-0.1, 0.1), rng.uniform(-0.1, 0.1)];
        // target inside the reachable annulus
        loop {
            let t = [rng.uniform(-0.2, 0.2), rng.uniform(-0.2, 0.2)];
            let r = (t[0] * t[0] + t[1] * t[1]).sqrt();
            if r <= self.l1 + self.l2 {
                self.target = t;
                break;
            }
        }
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let tau = [
            action[0].clamp(-1.0, 1.0) * self.gear,
            action[1].clamp(-1.0, 1.0) * self.gear,
        ];

        // two-link dynamics (point masses at link ends)
        let (l1, l2, m1, m2) = (self.l1, self.l2, self.m1, self.m2);
        let c2 = self.q[1].cos();
        let s2 = self.q[1].sin();
        let m11 = (m1 + m2) * l1 * l1 + m2 * l2 * l2 + 2.0 * m2 * l1 * l2 * c2;
        let m12 = m2 * l2 * l2 + m2 * l1 * l2 * c2;
        let m22 = m2 * l2 * l2;
        // Coriolis/centrifugal
        let h = m2 * l1 * l2 * s2;
        let c1 = -h * self.qd[1] * (2.0 * self.qd[0] + self.qd[1]);
        let c2t = h * self.qd[0] * self.qd[0];

        let rhs1 = tau[0] - c1 - self.damping * 1e-3 * self.qd[0];
        let rhs2 = tau[1] - c2t - self.damping * 1e-3 * self.qd[1];
        let det = m11 * m22 - m12 * m12;
        let qdd1 = (m22 * rhs1 - m12 * rhs2) / det;
        let qdd2 = (m11 * rhs2 - m12 * rhs1) / det;

        self.qd[0] = (self.qd[0] + qdd1 * self.dt).clamp(-50.0, 50.0);
        self.qd[1] = (self.qd[1] + qdd2 * self.dt).clamp(-50.0, 50.0);
        self.q[0] += self.qd[0] * self.dt;
        self.q[1] += self.qd[1] * self.dt;

        let tip = self.fingertip();
        let dx = tip[0] - self.target[0];
        let dy = tip[1] - self.target[1];
        let dist = (dx * dx + dy * dy).sqrt();
        let ctrl = action[0].clamp(-1.0, 1.0).powi(2) + action[1].clamp(-1.0, 1.0).powi(2);

        self.write_obs(obs);
        Step {
            reward: -dist - ctrl * 0.1,
            done: false,
        }
    }

    fn save_state(&self) -> Vec<f32> {
        vec![
            self.q[0],
            self.q[1],
            self.qd[0],
            self.qd[1],
            self.target[0],
            self.target[1],
        ]
    }

    fn load_state(&mut self, state: &[f32]) {
        self.q = [state[0], state[1]];
        self.qd = [state[2], state[3]];
        self.target = [state[4], state[5]];
    }
}

/// SoA batched reacher: joint angles/velocities and targets live in
/// `[M]`-wide columns. The mass-matrix solve stays scalar per lane; the
/// semi-implicit integrator runs through `kernels::axpy`/`axpy_clamp`
/// column-at-a-time (bitwise equal to the scalar updates), and
/// `reset_lane` consumes the RNG in the scalar draw order including the
/// target rejection loop.
pub struct BatchedReacher {
    q0: Vec<f32>,
    q1: Vec<f32>,
    qd0: Vec<f32>,
    qd1: Vec<f32>,
    tx: Vec<f32>,
    ty: Vec<f32>,
    /// Scratch columns: per-lane joint accelerations this sweep.
    qdd1: Vec<f32>,
    qdd2: Vec<f32>,
    out: Vec<BatchStep>,
    p: Reacher,
}

impl BatchedReacher {
    pub fn new(m: usize) -> Self {
        Self {
            q0: vec![0.0; m],
            q1: vec![0.0; m],
            qd0: vec![0.0; m],
            qd1: vec![0.0; m],
            tx: vec![0.1; m],
            ty: vec![0.1; m],
            qdd1: vec![0.0; m],
            qdd2: vec![0.0; m],
            out: vec![BatchStep::default(); m],
            p: Reacher::default(),
        }
    }

    fn fingertip_lane(&self, lane: usize) -> [f32; 2] {
        let x = self.p.l1 * self.q0[lane].cos()
            + self.p.l2 * (self.q0[lane] + self.q1[lane]).cos();
        let y = self.p.l1 * self.q0[lane].sin()
            + self.p.l2 * (self.q0[lane] + self.q1[lane]).sin();
        [x, y]
    }

    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        let tip = self.fingertip_lane(lane);
        obs[0] = self.q0[lane].cos();
        obs[1] = self.q1[lane].cos();
        obs[2] = self.q0[lane].sin();
        obs[3] = self.q1[lane].sin();
        obs[4] = self.tx[lane];
        obs[5] = self.ty[lane];
        obs[6] = self.qd0[lane];
        obs[7] = self.qd1[lane];
        obs[8] = tip[0] - self.tx[lane];
        obs[9] = tip[1] - self.ty[lane];
    }
}

impl BatchedEnv for BatchedReacher {
    fn num_envs(&self) -> usize {
        self.q0.len()
    }

    fn obs_dim(&self) -> usize {
        10
    }

    fn act_dim(&self) -> usize {
        2
    }

    fn max_episode_steps(&self) -> usize {
        50
    }

    fn name(&self) -> &'static str {
        "reacher"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg64, obs_row: &mut [f32]) {
        self.q0[lane] = rng.uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.q1[lane] = rng.uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.qd0[lane] = rng.uniform(-0.1, 0.1);
        self.qd1[lane] = rng.uniform(-0.1, 0.1);
        // target inside the reachable annulus — same rejection loop (and
        // therefore the same number of RNG draws) as the scalar env
        loop {
            let t = [rng.uniform(-0.2, 0.2), rng.uniform(-0.2, 0.2)];
            let r = (t[0] * t[0] + t[1] * t[1]).sqrt();
            if r <= self.p.l1 + self.p.l2 {
                self.tx[lane] = t[0];
                self.ty[lane] = t[1];
                break;
            }
        }
        self.write_obs_lane(lane, obs_row);
    }

    fn step_all(&mut self, actions: &[f32], obs_out: &mut [f32]) -> &[BatchStep] {
        let m = self.q0.len();
        debug_assert_eq!(actions.len(), m * 2);
        debug_assert_eq!(obs_out.len(), m * 10);
        let (l1, l2, m1, m2) = (self.p.l1, self.p.l2, self.p.m1, self.p.m2);
        let (gear, dt, damping) = (self.p.gear, self.p.dt, self.p.damping);
        for lane in 0..m {
            let tau = [
                actions[lane * 2].clamp(-1.0, 1.0) * gear,
                actions[lane * 2 + 1].clamp(-1.0, 1.0) * gear,
            ];
            let c2 = self.q1[lane].cos();
            let s2 = self.q1[lane].sin();
            let m11 = (m1 + m2) * l1 * l1 + m2 * l2 * l2 + 2.0 * m2 * l1 * l2 * c2;
            let m12 = m2 * l2 * l2 + m2 * l1 * l2 * c2;
            let m22 = m2 * l2 * l2;
            let h = m2 * l1 * l2 * s2;
            let c1 = -h * self.qd1[lane] * (2.0 * self.qd0[lane] + self.qd1[lane]);
            let c2t = h * self.qd0[lane] * self.qd0[lane];
            let rhs1 = tau[0] - c1 - damping * 1e-3 * self.qd0[lane];
            let rhs2 = tau[1] - c2t - damping * 1e-3 * self.qd1[lane];
            let det = m11 * m22 - m12 * m12;
            self.qdd1[lane] = (m22 * rhs1 - m12 * rhs2) / det;
            self.qdd2[lane] = (m11 * rhs2 - m12 * rhs1) / det;
        }
        kernels::axpy_clamp(dt, &self.qdd1, &mut self.qd0, -50.0, 50.0);
        kernels::axpy_clamp(dt, &self.qdd2, &mut self.qd1, -50.0, 50.0);
        kernels::axpy(dt, &self.qd0, &mut self.q0);
        kernels::axpy(dt, &self.qd1, &mut self.q1);
        for lane in 0..m {
            let tip = self.fingertip_lane(lane);
            let dx = tip[0] - self.tx[lane];
            let dy = tip[1] - self.ty[lane];
            let dist = (dx * dx + dy * dy).sqrt();
            let ctrl = actions[lane * 2].clamp(-1.0, 1.0).powi(2)
                + actions[lane * 2 + 1].clamp(-1.0, 1.0).powi(2);
            self.out[lane] = BatchStep {
                reward: -dist - ctrl * 0.1,
                done: false,
            };
            self.write_obs_lane(lane, &mut obs_out[lane * 10..(lane + 1) * 10]);
        }
        &self.out
    }

    fn save_lane(&self, lane: usize) -> Vec<f32> {
        vec![
            self.q0[lane],
            self.q1[lane],
            self.qd0[lane],
            self.qd1[lane],
            self.tx[lane],
            self.ty[lane],
        ]
    }

    fn load_lane(&mut self, lane: usize, state: &[f32]) {
        self.q0[lane] = state[0];
        self.q1[lane] = state[1];
        self.qd0[lane] = state[2];
        self.qd1[lane] = state[3];
        self.tx[lane] = state[4];
        self.ty[lane] = state[5];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingertip_at_stretched_pose() {
        let r = Reacher {
            q: [0.0, 0.0],
            ..Default::default()
        };
        let tip = r.fingertip();
        assert!((tip[0] - 0.21).abs() < 1e-6);
        assert!(tip[1].abs() < 1e-6);
    }

    #[test]
    fn target_always_reachable() {
        let mut env = Reacher::default();
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 10];
        for _ in 0..100 {
            env.reset(&mut rng, &mut obs);
            let r = (env.target[0].powi(2) + env.target[1].powi(2)).sqrt();
            assert!(r <= env.l1 + env.l2 + 1e-6);
        }
    }

    #[test]
    fn reward_improves_when_tip_approaches_target() {
        let mut env = Reacher {
            q: [0.3, 0.2],
            qd: [0.0, 0.0],
            target: [0.15, 0.1],
            ..Default::default()
        };
        // reward with zero action at two distances: move tip onto target
        let mut obs = [0.0f32; 10];
        let far = env.step(&[0.0, 0.0], &mut obs).reward;
        // teleport near target
        env.q = [0.588, 0.0]; // tip ≈ (0.175, 0.116)
        env.qd = [0.0, 0.0];
        let near = env.step(&[0.0, 0.0], &mut obs).reward;
        assert!(near > far, "near={near} far={far}");
    }

    #[test]
    fn torque_accelerates_joints() {
        let mut env = Reacher::default();
        let mut obs = [0.0f32; 10];
        env.step(&[1.0, 0.0], &mut obs);
        assert!(env.qd[0] != 0.0);
    }

    #[test]
    fn dynamics_stay_finite() {
        let mut env = Reacher::default();
        let mut rng = Pcg64::new(3);
        let mut obs = [0.0f32; 10];
        env.reset(&mut rng, &mut obs);
        for i in 0..1000 {
            let a = [((i as f32) * 0.7).sin(), ((i as f32) * 1.3).cos()];
            env.step(&a, &mut obs);
        }
        assert!(obs.iter().all(|v| v.is_finite()));
    }
}
