//! Continuous-action cart-pole balance.
//!
//! Obs = [x, ẋ, θ, θ̇]; action = horizontal force in [-1, 1] × `force_mag`.
//! Reward 1.0 per step alive; terminal when the pole falls past 12° or the
//! cart leaves ±2.4 m; 500-step cap. This is the one preset env with true
//! terminal states, so it exercises the GAE done-vs-truncation distinction.

use super::batch::{BatchStep, BatchedEnv};
use super::{Env, Step};
use crate::nn::kernels;
use crate::util::rng::Pcg64;

pub struct CartPole {
    x: f32,
    x_dot: f32,
    theta: f32,
    theta_dot: f32,
    gravity: f32,
    mass_cart: f32,
    mass_pole: f32,
    pole_half_len: f32,
    force_mag: f32,
    dt: f32,
    x_limit: f32,
    theta_limit: f32,
}

impl Default for CartPole {
    fn default() -> Self {
        Self {
            x: 0.0,
            x_dot: 0.0,
            theta: 0.0,
            theta_dot: 0.0,
            gravity: 9.8,
            mass_cart: 1.0,
            mass_pole: 0.1,
            pole_half_len: 0.5,
            force_mag: 10.0,
            dt: 0.02,
            x_limit: 2.4,
            theta_limit: 12.0f32.to_radians(),
        }
    }
}

impl CartPole {
    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.x;
        obs[1] = self.x_dot;
        obs[2] = self.theta;
        obs[3] = self.theta_dot;
    }

    fn fallen(&self) -> bool {
        self.x.abs() > self.x_limit || self.theta.abs() > self.theta_limit
    }
}

impl Env for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn max_episode_steps(&self) -> usize {
        500
    }

    fn name(&self) -> &'static str {
        "cartpole"
    }

    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.x = rng.uniform(-0.05, 0.05);
        self.x_dot = rng.uniform(-0.05, 0.05);
        self.theta = rng.uniform(-0.05, 0.05);
        self.theta_dot = rng.uniform(-0.05, 0.05);
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let force = action[0].clamp(-1.0, 1.0) * self.force_mag;
        let total_mass = self.mass_cart + self.mass_pole;
        let pole_ml = self.mass_pole * self.pole_half_len;
        let (sin_t, cos_t) = self.theta.sin_cos();

        let temp = (force + pole_ml * self.theta_dot * self.theta_dot * sin_t) / total_mass;
        let theta_acc = (self.gravity * sin_t - cos_t * temp)
            / (self.pole_half_len
                * (4.0 / 3.0 - self.mass_pole * cos_t * cos_t / total_mass));
        let x_acc = temp - pole_ml * theta_acc * cos_t / total_mass;

        self.x += self.dt * self.x_dot;
        self.x_dot += self.dt * x_acc;
        self.theta += self.dt * self.theta_dot;
        self.theta_dot += self.dt * theta_acc;

        self.write_obs(obs);
        Step {
            reward: 1.0,
            done: self.fallen(),
        }
    }

    fn save_state(&self) -> Vec<f32> {
        vec![self.x, self.x_dot, self.theta, self.theta_dot]
    }

    fn load_state(&mut self, state: &[f32]) {
        self.x = state[0];
        self.x_dot = state[1];
        self.theta = state[2];
        self.theta_dot = state[3];
    }
}

/// SoA batched cart-pole: the four state variables live in `[M]`-wide
/// columns; the semi-implicit Euler update runs column-at-a-time through
/// `kernels::axpy` (bitwise equal to the scalar `+= dt · v` updates),
/// accelerations and the terminal check stay scalar per lane.
pub struct BatchedCartPole {
    x: Vec<f32>,
    x_dot: Vec<f32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    /// Scratch columns: per-lane accelerations this sweep.
    x_acc: Vec<f32>,
    theta_acc: Vec<f32>,
    out: Vec<BatchStep>,
    p: CartPole,
}

impl BatchedCartPole {
    pub fn new(m: usize) -> Self {
        Self {
            x: vec![0.0; m],
            x_dot: vec![0.0; m],
            theta: vec![0.0; m],
            theta_dot: vec![0.0; m],
            x_acc: vec![0.0; m],
            theta_acc: vec![0.0; m],
            out: vec![BatchStep::default(); m],
            p: CartPole::default(),
        }
    }

    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        obs[0] = self.x[lane];
        obs[1] = self.x_dot[lane];
        obs[2] = self.theta[lane];
        obs[3] = self.theta_dot[lane];
    }
}

impl BatchedEnv for BatchedCartPole {
    fn num_envs(&self) -> usize {
        self.x.len()
    }

    fn obs_dim(&self) -> usize {
        4
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn max_episode_steps(&self) -> usize {
        500
    }

    fn name(&self) -> &'static str {
        "cartpole"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg64, obs_row: &mut [f32]) {
        self.x[lane] = rng.uniform(-0.05, 0.05);
        self.x_dot[lane] = rng.uniform(-0.05, 0.05);
        self.theta[lane] = rng.uniform(-0.05, 0.05);
        self.theta_dot[lane] = rng.uniform(-0.05, 0.05);
        self.write_obs_lane(lane, obs_row);
    }

    fn step_all(&mut self, actions: &[f32], obs_out: &mut [f32]) -> &[BatchStep] {
        let m = self.x.len();
        debug_assert_eq!(actions.len(), m);
        debug_assert_eq!(obs_out.len(), m * 4);
        let (gravity, mass_pole, pole_half_len, force_mag) = (
            self.p.gravity,
            self.p.mass_pole,
            self.p.pole_half_len,
            self.p.force_mag,
        );
        let total_mass = self.p.mass_cart + mass_pole;
        let pole_ml = mass_pole * pole_half_len;
        for lane in 0..m {
            let force = actions[lane].clamp(-1.0, 1.0) * force_mag;
            let (sin_t, cos_t) = self.theta[lane].sin_cos();
            let td = self.theta_dot[lane];
            let temp = (force + pole_ml * td * td * sin_t) / total_mass;
            let theta_acc = (gravity * sin_t - cos_t * temp)
                / (pole_half_len
                    * (4.0 / 3.0 - mass_pole * cos_t * cos_t / total_mass));
            self.theta_acc[lane] = theta_acc;
            self.x_acc[lane] = temp - pole_ml * theta_acc * cos_t / total_mass;
        }
        // the scalar env's exact update order: x uses the OLD ẋ, θ the
        // OLD θ̇ — column order below preserves that per lane
        let dt = self.p.dt;
        kernels::axpy(dt, &self.x_dot, &mut self.x);
        kernels::axpy(dt, &self.x_acc, &mut self.x_dot);
        kernels::axpy(dt, &self.theta_dot, &mut self.theta);
        kernels::axpy(dt, &self.theta_acc, &mut self.theta_dot);
        for lane in 0..m {
            obs_out[lane * 4] = self.x[lane];
            obs_out[lane * 4 + 1] = self.x_dot[lane];
            obs_out[lane * 4 + 2] = self.theta[lane];
            obs_out[lane * 4 + 3] = self.theta_dot[lane];
            self.out[lane] = BatchStep {
                reward: 1.0,
                done: self.x[lane].abs() > self.p.x_limit
                    || self.theta[lane].abs() > self.p.theta_limit,
            };
        }
        &self.out
    }

    fn save_lane(&self, lane: usize) -> Vec<f32> {
        vec![
            self.x[lane],
            self.x_dot[lane],
            self.theta[lane],
            self.theta_dot[lane],
        ]
    }

    fn load_lane(&mut self, lane: usize, state: &[f32]) {
        self.x[lane] = state[0];
        self.x_dot[lane] = state[1];
        self.theta[lane] = state[2];
        self.theta_dot[lane] = state[3];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_near_upright() {
        let mut env = CartPole::default();
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut rng, &mut obs);
        assert!(obs.iter().all(|v| v.abs() <= 0.05));
    }

    #[test]
    fn uncontrolled_pole_eventually_falls() {
        let mut env = CartPole::default();
        let mut rng = Pcg64::new(1);
        let mut obs = [0.0f32; 4];
        env.reset(&mut rng, &mut obs);
        let mut fell = false;
        for _ in 0..500 {
            if env.step(&[0.0], &mut obs).done {
                fell = true;
                break;
            }
        }
        assert!(fell, "pole never fell without control");
    }

    #[test]
    fn force_pushes_cart() {
        let mut env = CartPole::default();
        let mut obs = [0.0f32; 4];
        for _ in 0..10 {
            env.step(&[1.0], &mut obs);
        }
        assert!(env.x_dot > 0.0);
    }

    #[test]
    fn done_at_position_limit() {
        let mut env = CartPole {
            x: 2.39,
            x_dot: 10.0,
            ..Default::default()
        };
        let mut obs = [0.0f32; 4];
        let s = env.step(&[1.0], &mut obs);
        assert!(s.done);
    }

    #[test]
    fn reward_is_one_per_step() {
        let mut env = CartPole::default();
        let mut obs = [0.0f32; 4];
        assert_eq!(env.step(&[0.0], &mut obs).reward, 1.0);
    }
}
