//! HalfCheetah-Lite: planar cheetah locomotion on the rigid-body engine —
//! WALL-E's substitute for MuJoCo `HalfCheetah-v2` (DESIGN.md §3).
//!
//! Seven rods (torso + back/front thigh, shin, foot) connected by six
//! motorized revolute joints with MuJoCo-like limits and gear ratios.
//! Matching the original task interface exactly:
//!   * obs (17) = [torso height, torso pitch, 6 joint angles,
//!                 torso vx, vy, pitch rate, 6 joint speeds]
//!   * act (6)  = normalized joint torques in [-1, 1] × gear
//!   * reward   = forward torso velocity − 0.1 ‖action‖²
//!   * 1000-step episodes, no early termination.
//!
//! Physics runs at dt = 0.01 with frame_skip = 5 (control dt = 0.05 s),
//! the same discretization as the original.

use super::batch::{BatchStep, BatchedEnv};
use super::physics::{v2, BatchedWorld, Body, RevoluteJoint, World, WorldCfg};
use super::{Env, Step};
use crate::util::rng::Pcg64;

const N_JOINTS: usize = 6;
const FRAME_SKIP: usize = 5;
const DT: f32 = 0.01;

/// Per-joint gear (torque scale). MuJoCo uses [120, 90, 60, 120, 60, 30];
/// scaled down for our lighter 2-D bodies.
const GEARS: [f32; N_JOINTS] = [60.0, 45.0, 30.0, 60.0, 30.0, 15.0];

/// Joint limits (radians), MuJoCo-like: bthigh, bshin, bfoot, fthigh,
/// fshin, ffoot.
const LIMITS: [(f32, f32); N_JOINTS] = [
    (-0.52, 1.05),
    (-0.78, 0.78),
    (-0.40, 0.78),
    (-1.00, 0.70),
    (-1.20, 0.87),
    (-0.50, 0.50),
];

/// Limb (mass, half_len): back thigh/shin/foot, front thigh/shin/foot.
const LIMBS: [(f32, f32); N_JOINTS] = [
    (1.54, 0.145),
    (1.58, 0.15),
    (1.07, 0.094),
    (1.43, 0.133),
    (1.18, 0.106),
    (0.84, 0.07),
];

const TORSO_MASS: f32 = 6.36;
const TORSO_HALF_LEN: f32 = 0.5;
const INIT_HEIGHT: f32 = 0.58;

pub struct HalfCheetah {
    world: World,
    steps: usize,
}

impl Default for HalfCheetah {
    fn default() -> Self {
        let mut hc = HalfCheetah {
            world: build_world(),
            steps: 0,
        };
        hc.world.reset_solver_state();
        hc
    }
}

fn build_world() -> World {
    let cfg = WorldCfg {
        gravity: -9.81,
        ground_y: 0.0,
        friction: 0.9,
        velocity_iters: 14,
        baumgarte: 0.2,
        contact_slop: 0.005,
        damping: 0.05,
        max_vel: 30.0,
        max_omega: 30.0,
    };
    let mut w = World::new(cfg);
    // torso: rod along +x at standing height
    let torso = w.add_body(Body::rod(
        v2(0.0, INIT_HEIGHT),
        0.0,
        TORSO_MASS,
        TORSO_HALF_LEN,
        0.046,
    ));

    // back leg hangs from the rear end, front leg from the front end
    let hips = [v2(-TORSO_HALF_LEN, 0.0), v2(TORSO_HALF_LEN, 0.0)];
    for (leg, hip_local) in hips.iter().enumerate() {
        let mut parent = torso;
        let mut parent_anchor = *hip_local;
        let mut anchor_world = match leg {
            0 => v2(-TORSO_HALF_LEN, INIT_HEIGHT),
            _ => v2(TORSO_HALF_LEN, INIT_HEIGHT),
        };
        for seg in 0..3 {
            let (mass, hl) = LIMBS[leg * 3 + seg];
            // limb hangs straight down: center hl below the anchor, with the
            // local +x end at the anchor (angle = +π/2 rotates +x upward)
            let center = anchor_world - v2(0.0, hl);
            let body = w.add_body(Body::rod(
                center,
                std::f32::consts::FRAC_PI_2,
                mass,
                hl,
                0.04,
            ));
            let parent_angle = if parent == torso {
                0.0
            } else {
                std::f32::consts::FRAC_PI_2
            };
            let ref_angle = std::f32::consts::FRAC_PI_2 - parent_angle;
            let (lo, hi) = LIMITS[leg * 3 + seg];
            w.add_joint(RevoluteJoint::new(
                parent,
                body,
                parent_anchor,
                v2(hl, 0.0),
                ref_angle,
                Some((lo, hi)),
            ));
            parent = body;
            parent_anchor = v2(-hl, 0.0); // next segment attaches at distal end
            anchor_world = anchor_world - v2(0.0, 2.0 * hl);
        }
    }
    w
}

impl HalfCheetah {
    fn torso(&self) -> &Body {
        &self.world.bodies[0]
    }

    fn write_obs(&self, obs: &mut [f32]) {
        let t = self.torso();
        obs[0] = t.pos.y;
        obs[1] = t.angle;
        for j in 0..N_JOINTS {
            obs[2 + j] = self.world.joints[j].angle(&self.world.bodies);
        }
        obs[8] = t.vel.x;
        obs[9] = t.vel.y;
        obs[10] = t.omega;
        for j in 0..N_JOINTS {
            obs[11 + j] = self.world.joints[j].speed(&self.world.bodies);
        }
    }
}

impl Env for HalfCheetah {
    fn obs_dim(&self) -> usize {
        17
    }

    fn act_dim(&self) -> usize {
        N_JOINTS
    }

    fn max_episode_steps(&self) -> usize {
        1000
    }

    fn name(&self) -> &'static str {
        "halfcheetah"
    }

    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.world = build_world();
        self.world.reset_solver_state();
        self.steps = 0;
        // small random perturbations, as MuJoCo does on qpos/qvel
        for b in &mut self.world.bodies {
            b.pos.x += rng.uniform(-0.005, 0.005);
            b.pos.y += rng.uniform(-0.005, 0.005);
            b.angle += rng.uniform(-0.02, 0.02);
            b.vel = v2(rng.uniform(-0.05, 0.05), rng.uniform(-0.05, 0.05));
            b.omega = rng.uniform(-0.05, 0.05);
        }
        // settle contacts for a few passive steps so the start is stable
        for _ in 0..5 {
            self.world.step(DT);
        }
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let x_before = self.torso().pos.x;
        let mut ctrl_cost = 0.0f32;
        for _ in 0..FRAME_SKIP {
            for j in 0..N_JOINTS {
                let a = action[j].clamp(-1.0, 1.0);
                self.world.set_motor(j, a * GEARS[j]);
            }
            self.world.step(DT);
        }
        for j in 0..N_JOINTS {
            let a = action[j].clamp(-1.0, 1.0);
            ctrl_cost += 0.1 * a * a;
        }
        let x_after = self.torso().pos.x;
        let forward_vel = (x_after - x_before) / (DT * FRAME_SKIP as f32);
        self.steps += 1;
        self.write_obs(obs);
        Step {
            reward: forward_vel - ctrl_cost,
            done: false,
        }
    }

    fn save_state(&self) -> Vec<f32> {
        // world dynamic state + the step counter (episodes cap at 1000,
        // far inside f32's exact-integer range)
        let mut s = self.world.save_state();
        s.push(self.steps as f32);
        s
    }

    fn load_state(&mut self, state: &[f32]) {
        let (world, tail) = state.split_at(state.len() - 1);
        self.world.load_state(world);
        self.steps = tail[0] as usize;
    }
}

/// SoA batched half-cheetah: M lockstep copies of the seven-rod world
/// inside one [`BatchedWorld`], advanced by a single solver sweep per
/// physics tick. Lane resets rebuild the canonical scalar world (same
/// RNG draw order, same five settle steps) and scatter its state into
/// the lane's columns, so every lane is bitwise identical to a scalar
/// [`HalfCheetah`] on the same stream.
pub struct BatchedHalfCheetah {
    world: BatchedWorld,
    steps: Vec<usize>,
    /// Scratch column: per-lane torso x before the frame-skip burst.
    x_before: Vec<f32>,
    out: Vec<BatchStep>,
}

impl BatchedHalfCheetah {
    pub fn new(m: usize) -> Self {
        let mut template = build_world();
        template.reset_solver_state();
        Self {
            world: BatchedWorld::from_template(&template, m),
            steps: vec![0; m],
            x_before: vec![0.0; m],
            out: vec![BatchStep::default(); m],
        }
    }

    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        obs[0] = self.world.body_pos_y(0, lane);
        obs[1] = self.world.body_angle(0, lane);
        for j in 0..N_JOINTS {
            obs[2 + j] = self.world.joint_angle(j, lane);
        }
        obs[8] = self.world.body_vel_x(0, lane);
        obs[9] = self.world.body_vel_y(0, lane);
        obs[10] = self.world.body_omega(0, lane);
        for j in 0..N_JOINTS {
            obs[11 + j] = self.world.joint_speed(j, lane);
        }
    }
}

impl BatchedEnv for BatchedHalfCheetah {
    fn num_envs(&self) -> usize {
        self.steps.len()
    }

    fn obs_dim(&self) -> usize {
        17
    }

    fn act_dim(&self) -> usize {
        N_JOINTS
    }

    fn max_episode_steps(&self) -> usize {
        1000
    }

    fn name(&self) -> &'static str {
        "halfcheetah"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg64, obs_row: &mut [f32]) {
        // run the scalar reset (identical RNG draws + settle steps) in a
        // scratch world, then scatter its state into this lane's columns
        let mut w = build_world();
        w.reset_solver_state();
        self.steps[lane] = 0;
        for b in &mut w.bodies {
            b.pos.x += rng.uniform(-0.005, 0.005);
            b.pos.y += rng.uniform(-0.005, 0.005);
            b.angle += rng.uniform(-0.02, 0.02);
            b.vel = v2(rng.uniform(-0.05, 0.05), rng.uniform(-0.05, 0.05));
            b.omega = rng.uniform(-0.05, 0.05);
        }
        for _ in 0..5 {
            w.step(DT);
        }
        self.world.load_lane(lane, &w.save_state());
        self.write_obs_lane(lane, obs_row);
    }

    fn step_all(&mut self, actions: &[f32], obs_out: &mut [f32]) -> &[BatchStep] {
        let m = self.steps.len();
        debug_assert_eq!(actions.len(), m * N_JOINTS);
        debug_assert_eq!(obs_out.len(), m * 17);
        for lane in 0..m {
            self.x_before[lane] = self.world.body_pos_x(0, lane);
        }
        for _ in 0..FRAME_SKIP {
            for j in 0..N_JOINTS {
                for lane in 0..m {
                    let a = actions[lane * N_JOINTS + j].clamp(-1.0, 1.0);
                    self.world.set_motor(j, lane, a * GEARS[j]);
                }
            }
            self.world.step(DT);
        }
        for lane in 0..m {
            let mut ctrl_cost = 0.0f32;
            for j in 0..N_JOINTS {
                let a = actions[lane * N_JOINTS + j].clamp(-1.0, 1.0);
                ctrl_cost += 0.1 * a * a;
            }
            let x_after = self.world.body_pos_x(0, lane);
            let forward_vel = (x_after - self.x_before[lane]) / (DT * FRAME_SKIP as f32);
            self.steps[lane] += 1;
            self.out[lane] = BatchStep {
                reward: forward_vel - ctrl_cost,
                done: false,
            };
            self.write_obs_lane(lane, &mut obs_out[lane * 17..(lane + 1) * 17]);
        }
        &self.out
    }

    fn save_lane(&self, lane: usize) -> Vec<f32> {
        let mut s = self.world.save_lane(lane);
        s.push(self.steps[lane] as f32);
        s
    }

    fn load_lane(&mut self, lane: usize, state: &[f32]) {
        let (world, tail) = state.split_at(state.len() - 1);
        self.world.load_lane(lane, world);
        self.steps[lane] = tail[0] as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_dims_match_preset() {
        let env = HalfCheetah::default();
        assert_eq!(env.obs_dim(), 17);
        assert_eq!(env.act_dim(), 6);
        assert_eq!(env.max_episode_steps(), 1000);
    }

    #[test]
    fn settles_on_ground_without_action() {
        let mut env = HalfCheetah::default();
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 17];
        env.reset(&mut rng, &mut obs);
        for _ in 0..100 {
            env.step(&[0.0; 6], &mut obs);
        }
        let h = obs[0];
        assert!(h > 0.05 && h < 1.0, "torso height {h}");
        assert!(obs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn reward_is_velocity_minus_ctrl_cost() {
        let mut env = HalfCheetah::default();
        let mut rng = Pcg64::new(1);
        let mut obs = [0.0f32; 17];
        env.reset(&mut rng, &mut obs);
        let x0 = env.torso().pos.x;
        let a = [0.5f32, -0.5, 0.2, 0.1, -0.3, 0.4];
        let s = env.step(&a, &mut obs);
        let x1 = env.torso().pos.x;
        let vel = (x1 - x0) / 0.05;
        let ctrl: f32 = a.iter().map(|x| 0.1 * x * x).sum();
        assert!((s.reward - (vel - ctrl)).abs() < 1e-5);
    }

    #[test]
    fn survives_random_torque_abuse() {
        let mut env = HalfCheetah::default();
        let mut rng = Pcg64::new(2);
        let mut obs = [0.0f32; 17];
        env.reset(&mut rng, &mut obs);
        let mut a = [0.0f32; 6];
        for _ in 0..1000 {
            for x in a.iter_mut() {
                *x = rng.uniform(-1.0, 1.0);
            }
            let s = env.step(&a, &mut obs);
            assert!(s.reward.is_finite());
            assert!(obs.iter().all(|v| v.is_finite()));
        }
        // body must not have sunk through the floor or launched into orbit
        assert!(obs[0] > -0.5 && obs[0] < 5.0, "height={}", obs[0]);
    }

    #[test]
    fn reset_is_reproducible_per_seed() {
        let mut e1 = HalfCheetah::default();
        let mut e2 = HalfCheetah::default();
        let mut o1 = [0.0f32; 17];
        let mut o2 = [0.0f32; 17];
        e1.reset(&mut Pcg64::new(7), &mut o1);
        e2.reset(&mut Pcg64::new(7), &mut o2);
        assert_eq!(o1, o2);
        // and stepping with the same actions stays identical
        let a = [0.3f32, -0.2, 0.1, 0.4, -0.1, 0.2];
        let s1 = e1.step(&a, &mut o1);
        let s2 = e2.step(&a, &mut o2);
        assert_eq!(s1, s2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn state_round_trip_continues_bitwise() {
        let mut live = HalfCheetah::default();
        let mut rng = Pcg64::new(9);
        let mut obs = [0.0f32; 17];
        live.reset(&mut rng, &mut obs);
        let a = [0.4f32, -0.3, 0.2, -0.1, 0.5, -0.2];
        for _ in 0..40 {
            live.step(&a, &mut obs);
        }
        let saved = live.save_state();
        // restore into a FRESH instance (the checkpoint scenario)
        let mut restored = HalfCheetah::default();
        restored.load_state(&saved);
        assert_eq!(restored.steps, live.steps);
        let mut o1 = [0.0f32; 17];
        let mut o2 = [0.0f32; 17];
        for _ in 0..40 {
            let s1 = live.step(&a, &mut o1);
            let s2 = restored.step(&a, &mut o2);
            assert_eq!(s1, s2);
            assert_eq!(o1, o2);
        }
    }

    #[test]
    fn constant_forward_gait_moves_somewhere() {
        // not asserting locomotion quality — only that torques move the body
        let mut env = HalfCheetah::default();
        let mut rng = Pcg64::new(3);
        let mut obs = [0.0f32; 17];
        env.reset(&mut rng, &mut obs);
        let x0 = env.torso().pos.x;
        for i in 0..200 {
            let phase = i as f32 * 0.3;
            let a = [
                phase.sin(),
                (phase + 1.0).sin(),
                (phase + 2.0).sin(),
                -phase.sin(),
                -(phase + 1.0).sin(),
                -(phase + 2.0).sin(),
            ];
            env.step(&a, &mut obs);
        }
        assert!((env.torso().pos.x - x0).abs() > 0.01);
    }
}
