//! Classic torque-limited pendulum swing-up (gym `Pendulum-v1` semantics).
//!
//! Obs = [cos θ, sin θ, θ̇]; action = normalized torque in [-1, 1] scaled
//! by `max_torque`; reward = -(θ² + 0.1 θ̇² + 0.001 u²); 200-step episodes,
//! no terminal states. Closed-form dynamics — the cheapest env, used by
//! quickstart, tests and DDPG examples.

use super::batch::{BatchStep, BatchedEnv};
use super::{Env, Step};
use crate::nn::kernels;
use crate::util::rng::Pcg64;

pub struct Pendulum {
    theta: f32,
    theta_dot: f32,
    g: f32,
    m: f32,
    l: f32,
    dt: f32,
    max_torque: f32,
    max_speed: f32,
}

impl Default for Pendulum {
    fn default() -> Self {
        Self {
            theta: 0.0,
            theta_dot: 0.0,
            g: 10.0,
            m: 1.0,
            l: 1.0,
            dt: 0.05,
            max_torque: 2.0,
            max_speed: 8.0,
        }
    }
}

impl Pendulum {
    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.theta.cos();
        obs[1] = self.theta.sin();
        obs[2] = self.theta_dot;
    }
}

/// Wrap an angle into [-π, π].
pub fn angle_normalize(x: f32) -> f32 {
    let two_pi = 2.0 * std::f32::consts::PI;
    let y = (x + std::f32::consts::PI).rem_euclid(two_pi);
    y - std::f32::consts::PI
}

impl Env for Pendulum {
    fn obs_dim(&self) -> usize {
        3
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn max_episode_steps(&self) -> usize {
        200
    }

    fn name(&self) -> &'static str {
        "pendulum"
    }

    fn reset(&mut self, rng: &mut Pcg64, obs: &mut [f32]) {
        self.theta = rng.uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.theta_dot = rng.uniform(-1.0, 1.0);
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let u = action[0].clamp(-1.0, 1.0) * self.max_torque;
        let th = angle_normalize(self.theta);
        let cost = th * th + 0.1 * self.theta_dot * self.theta_dot + 0.001 * u * u;

        // θ̈ = 3g/(2l) sin θ + 3/(m l²) u   (θ = 0 is upright)
        let acc = 3.0 * self.g / (2.0 * self.l) * self.theta.sin()
            + 3.0 / (self.m * self.l * self.l) * u;
        self.theta_dot = (self.theta_dot + acc * self.dt)
            .clamp(-self.max_speed, self.max_speed);
        self.theta += self.theta_dot * self.dt;

        self.write_obs(obs);
        Step {
            reward: -cost,
            done: false,
        }
    }

    fn save_state(&self) -> Vec<f32> {
        vec![self.theta, self.theta_dot]
    }

    fn load_state(&mut self, state: &[f32]) {
        self.theta = state[0];
        self.theta_dot = state[1];
    }
}

/// SoA batched pendulum: θ and θ̇ live in `[M]`-wide columns, one sweep
/// advances all lanes. The integrator columns run through the
/// `nn::kernels` `axpy`/`axpy_clamp` microkernels (bitwise equal to the
/// scalar update in every arm/mode); transcendentals stay scalar per
/// lane, so each lane reproduces [`Pendulum`] bit for bit.
pub struct BatchedPendulum {
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    /// Scratch column: per-lane angular acceleration this sweep.
    acc: Vec<f32>,
    out: Vec<BatchStep>,
    p: Pendulum,
}

impl BatchedPendulum {
    pub fn new(m: usize) -> Self {
        Self {
            theta: vec![0.0; m],
            theta_dot: vec![0.0; m],
            acc: vec![0.0; m],
            out: vec![BatchStep::default(); m],
            p: Pendulum::default(),
        }
    }

    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        obs[0] = self.theta[lane].cos();
        obs[1] = self.theta[lane].sin();
        obs[2] = self.theta_dot[lane];
    }
}

impl BatchedEnv for BatchedPendulum {
    fn num_envs(&self) -> usize {
        self.theta.len()
    }

    fn obs_dim(&self) -> usize {
        3
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn max_episode_steps(&self) -> usize {
        200
    }

    fn name(&self) -> &'static str {
        "pendulum"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg64, obs_row: &mut [f32]) {
        self.theta[lane] = rng.uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.theta_dot[lane] = rng.uniform(-1.0, 1.0);
        self.write_obs_lane(lane, obs_row);
    }

    fn step_all(&mut self, actions: &[f32], obs_out: &mut [f32]) -> &[BatchStep] {
        let m = self.theta.len();
        debug_assert_eq!(actions.len(), m);
        debug_assert_eq!(obs_out.len(), m * 3);
        let (g, ml, l, dt) = (self.p.g, self.p.m, self.p.l, self.p.dt);
        for lane in 0..m {
            let u = actions[lane].clamp(-1.0, 1.0) * self.p.max_torque;
            let th = angle_normalize(self.theta[lane]);
            let td = self.theta_dot[lane];
            let cost = th * th + 0.1 * td * td + 0.001 * u * u;
            self.acc[lane] =
                3.0 * g / (2.0 * l) * self.theta[lane].sin() + 3.0 / (ml * l * l) * u;
            self.out[lane] = BatchStep {
                reward: -cost,
                done: false,
            };
        }
        // θ̇ = clamp(θ̇ + θ̈·dt), then θ += θ̇·dt — same rounding as the
        // scalar env (a·x is commutative bitwise).
        kernels::axpy_clamp(
            dt,
            &self.acc,
            &mut self.theta_dot,
            -self.p.max_speed,
            self.p.max_speed,
        );
        kernels::axpy(dt, &self.theta_dot, &mut self.theta);
        for lane in 0..m {
            obs_out[lane * 3] = self.theta[lane].cos();
            obs_out[lane * 3 + 1] = self.theta[lane].sin();
            obs_out[lane * 3 + 2] = self.theta_dot[lane];
        }
        &self.out
    }

    fn save_lane(&self, lane: usize) -> Vec<f32> {
        vec![self.theta[lane], self.theta_dot[lane]]
    }

    fn load_lane(&mut self, lane: usize, state: &[f32]) {
        self.theta[lane] = state[0];
        self.theta_dot[lane] = state[1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_is_unit_circle_plus_speed() {
        let mut env = Pendulum::default();
        let mut rng = Pcg64::new(0);
        let mut obs = [0.0f32; 3];
        env.reset(&mut rng, &mut obs);
        let r = obs[0] * obs[0] + obs[1] * obs[1];
        assert!((r - 1.0).abs() < 1e-5);
    }

    #[test]
    fn reward_is_negative_cost() {
        let mut env = Pendulum::default();
        let mut rng = Pcg64::new(1);
        let mut obs = [0.0f32; 3];
        env.reset(&mut rng, &mut obs);
        let s = env.step(&[0.0], &mut obs);
        assert!(s.reward <= 0.0);
        assert!(!s.done);
    }

    #[test]
    fn upright_zero_velocity_is_near_zero_cost() {
        let mut env = Pendulum {
            theta: 0.0,
            theta_dot: 0.0,
            ..Default::default()
        };
        let mut obs = [0.0f32; 3];
        let s = env.step(&[0.0], &mut obs);
        assert!(s.reward > -0.01, "reward={}", s.reward);
    }

    #[test]
    fn hanging_pendulum_accelerates_downward() {
        // θ = π (hanging): sin θ ≈ 0 at exactly π, so nudge slightly
        let mut env = Pendulum {
            theta: 2.0,
            theta_dot: 0.0,
            ..Default::default()
        };
        let mut obs = [0.0f32; 3];
        env.step(&[0.0], &mut obs);
        assert!(env.theta_dot > 0.0); // gravity pulls toward π
    }

    #[test]
    fn angle_normalize_wraps() {
        // 3π wraps to ±π (both represent the same angle)
        assert!((angle_normalize(3.0 * std::f32::consts::PI).abs() - std::f32::consts::PI).abs() < 1e-5);
        assert!((angle_normalize(0.3) - 0.3).abs() < 1e-6);
        assert!((angle_normalize(-4.0 * std::f32::consts::PI)).abs() < 1e-4);
        // always lands in [-π, π]
        for i in -20..20 {
            let a = angle_normalize(i as f32 * 0.7);
            assert!((-std::f32::consts::PI..=std::f32::consts::PI).contains(&a));
        }
    }

    #[test]
    fn torque_saturates_at_max() {
        let mut e1 = Pendulum {
            theta: 1.0,
            ..Default::default()
        };
        let mut e2 = Pendulum {
            theta: 1.0,
            ..Default::default()
        };
        let mut obs = [0.0f32; 3];
        e1.step(&[1.0], &mut obs);
        e2.step(&[100.0], &mut obs); // must clip to same torque
        assert_eq!(e1.theta_dot, e2.theta_dot);
    }
}
