//! Cross-env conformance suite and reusable lockstep harness.
//!
//! Two layers live here:
//!
//! 1. **Scalar `Env` contract tests** (bottom of the file): every
//!    registered environment must satisfy the `Env` contract (finite
//!    observations, declared dims, reproducible resets, clipped-action
//!    tolerance). They run over the registry so a new env is
//!    automatically covered.
//! 2. **Batched-conformance harness** ([`drive_lockstep_pair`] /
//!    [`assert_engines_agree`]): public, reusable drivers that prove two
//!    `VecEnv`s are *bitwise interchangeable* — same per-tick step infos,
//!    observations, episode accounting, reset-on-done ordering, and
//!    time-limit truncation boundaries. The in-tree tests use them to
//!    pin the SoA [`BatchedEnv`](super::batch::BatchedEnv) engine against
//!    the legacy per-env scalar engine for every registry env at ragged
//!    vector widths; external `Env`/`BatchedEnv` implementations (and
//!    wrapper stacks) can call the same functions from their own tests.
//!
//! The harness makes no assumption about the active kernel arm: under
//! exact kernel mode (the default, and both CI legs — auto-detected SIMD
//! and `WALLE_KERNELS=scalar`) the batched engine's `nn/kernels` sweeps
//! are bitwise identical to the scalar loops, so every assertion here
//! holds on any machine.

use super::vec_env::{VecEnv, VecStepInfo};
use crate::util::rng::Pcg64;

/// Episode-boundary tally from one [`drive_lockstep_pair`] run. Callers
/// assert on these to prove the run actually exercised the semantics
/// they care about (a run with zero boundaries proves nothing about
/// reset ordering).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LockstepStats {
    /// Lockstep ticks driven.
    pub ticks: usize,
    /// True terminals observed (summed over lanes).
    pub terminals: usize,
    /// Time-limit truncations observed (summed over lanes).
    pub truncations: usize,
}

/// RNG stream base for the harness's per-lane action streams — far above
/// the `first_stream + i` env-dynamics streams any realistic M reaches,
/// so action draws never alias env resets.
pub const ACTION_STREAM_BASE: u64 = 0xAC00;

/// Drive two same-shape `VecEnv`s in lockstep with a shared random
/// action stream and assert they stay **bitwise identical**: per-tick
/// [`VecStepInfo`]s, the full observation buffer, per-lane `ep_len` /
/// `ep_return`, and the fresh observations after every reset-on-done
/// (resets are issued *after* both sides' post-step s' has been
/// compared, preserving the sampler's bootstrap ordering).
///
/// Panics with a labeled message on the first divergence. Returns the
/// episode-boundary tally so callers can assert coverage.
pub fn drive_lockstep_pair(
    a: &mut VecEnv,
    b: &mut VecEnv,
    action_seed: u64,
    ticks: usize,
) -> LockstepStats {
    let m = a.num_envs();
    let act_dim = a.act_dim();
    assert_eq!(m, b.num_envs(), "lockstep pair: vector widths differ");
    assert_eq!(act_dim, b.act_dim(), "lockstep pair: act dims differ");
    assert_eq!(a.obs_dim(), b.obs_dim(), "lockstep pair: obs dims differ");
    assert_eq!(
        a.max_episode_steps(),
        b.max_episode_steps(),
        "lockstep pair: episode caps differ"
    );
    let name = a.name();

    a.reset_all();
    b.reset_all();
    assert_obs_eq(a, b, name, 0, "reset_all");

    let mut act_rngs: Vec<Pcg64> = (0..m)
        .map(|i| Pcg64::with_stream(action_seed, ACTION_STREAM_BASE + i as u64))
        .collect();
    let mut actions = vec![0.0f32; m * act_dim];
    let mut ia = vec![VecStepInfo::default(); m];
    let mut ib = vec![VecStepInfo::default(); m];
    let mut stats = LockstepStats::default();

    for tick in 0..ticks {
        for (i, rng) in act_rngs.iter_mut().enumerate() {
            rng.fill_uniform(&mut actions[i * act_dim..(i + 1) * act_dim], -1.0, 1.0);
        }
        a.step_all(&actions, &mut ia);
        b.step_all(&actions, &mut ib);
        stats.ticks += 1;
        for i in 0..m {
            assert!(
                ia[i].reward.to_bits() == ib[i].reward.to_bits()
                    && ia[i].terminal == ib[i].terminal
                    && ia[i].truncated == ib[i].truncated,
                "{name} lane {i} tick {tick}: step info diverged ({:?} vs {:?})",
                ia[i],
                ib[i]
            );
            assert_eq!(
                a.ep_len(i),
                b.ep_len(i),
                "{name} lane {i} tick {tick}: ep_len diverged"
            );
            assert!(
                a.ep_return(i).to_bits() == b.ep_return(i).to_bits(),
                "{name} lane {i} tick {tick}: ep_return not bitwise equal \
                 ({} vs {})",
                a.ep_return(i),
                b.ep_return(i)
            );
        }
        // compare the post-step buffer (the bootstrap s' rows) BEFORE any
        // reset touches it — the ordering every consumer depends on
        assert_obs_eq(a, b, name, tick, "post-step");
        for i in 0..m {
            if ia[i].ended() {
                if ia[i].terminal {
                    stats.terminals += 1;
                } else {
                    stats.truncations += 1;
                }
                a.reset_env(i);
                b.reset_env(i);
                assert!(
                    bits_eq(a.obs_row(i), b.obs_row(i)),
                    "{name} lane {i} tick {tick}: reset obs diverged"
                );
            }
        }
    }
    stats
}

/// Assert that the SoA batched engine and the legacy per-env scalar
/// engine produce bitwise-identical trajectories for registry env
/// `name` at vector width `m` over `ticks` lockstep ticks. Both sides
/// get env-dynamics streams `1..=m` from `seed` — the same layout
/// `VecEnv::from_registry` hands a sampler worker.
pub fn assert_engines_agree(name: &str, m: usize, seed: u64, ticks: usize) -> LockstepStats {
    use super::batch::EnvEngine;
    let mut batched = VecEnv::from_registry_with(name, m, seed, 1, EnvEngine::Batched)
        .unwrap_or_else(|e| panic!("{name}: batched engine: {e}"));
    let mut scalar = VecEnv::from_registry_with(name, m, seed, 1, EnvEngine::Scalar)
        .unwrap_or_else(|e| panic!("{name}: scalar engine: {e}"));
    assert_eq!(batched.engine(), EnvEngine::Batched);
    assert_eq!(scalar.engine(), EnvEngine::Scalar);
    drive_lockstep_pair(&mut batched, &mut scalar, seed ^ 0xACAC, ticks)
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn assert_obs_eq(a: &VecEnv, b: &VecEnv, name: &str, tick: usize, at: &str) {
    for i in 0..a.num_envs() {
        assert!(
            bits_eq(a.obs_row(i), b.obs_row(i)),
            "{name} lane {i} tick {tick}: {at} obs diverged\n  a: {:?}\n  b: {:?}",
            a.obs_row(i),
            b.obs_row(i)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::batch::EnvEngine;
    use crate::env::pendulum::Pendulum;
    use crate::env::registry::{make_env, ENV_NAMES};
    use crate::env::wrappers::{ObsClip, RewardScale};

    // ---- batched-conformance suite (PR 9) -------------------------------

    /// Tentpole invariant: for every registry env, at ragged vector
    /// widths, one SoA `step_all` sweep is bitwise equal to M
    /// independently stepped scalar envs — including reset-on-done
    /// ordering and truncation boundaries.
    #[test]
    fn batched_engine_matches_scalar_engine_bitwise() {
        for name in ENV_NAMES {
            let cap = make_env(name).unwrap().max_episode_steps();
            for m in [1usize, 3, 5] {
                // cross ≥2 truncation boundaries where the cap is short,
                // ≥1 where physics makes long runs expensive
                let ticks = if cap <= 300 { cap * 2 + 17 } else { cap + 17 };
                let stats = assert_engines_agree(name, m, 11, ticks);
                assert!(
                    stats.terminals + stats.truncations > 0,
                    "{name} m={m}: run crossed no episode boundary — \
                     reset-on-done semantics untested"
                );
            }
        }
    }

    /// The time-limit boundary must fire at exactly `max_episode_steps`
    /// on BOTH engines (never terminal for pendulum, never a step early
    /// or late).
    #[test]
    fn truncation_fires_exactly_at_cap_on_both_engines() {
        for engine in [EnvEngine::Batched, EnvEngine::Scalar] {
            let m = 2;
            let mut venv = VecEnv::from_registry_with("pendulum", m, 5, 1, engine).unwrap();
            venv.reset_all();
            let cap = venv.max_episode_steps();
            let mut infos = vec![VecStepInfo::default(); m];
            let actions = vec![0.0f32; m];
            for t in 1..=cap {
                venv.step_all(&actions, &mut infos);
                for i in 0..m {
                    assert!(!infos[i].terminal, "{engine:?}: pendulum never terminates");
                    assert_eq!(
                        infos[i].truncated,
                        t == cap,
                        "{engine:?} lane {i}: truncation at step {t} (cap {cap})"
                    );
                }
            }
        }
    }

    /// `step_all` must leave the terminal s' in the observation buffer —
    /// the reset state appears only after the caller's explicit
    /// `reset_env`, on both engines (the GAE-bootstrap ordering).
    #[test]
    fn terminal_rows_hold_bootstrap_obs_until_reset() {
        for engine in [EnvEngine::Batched, EnvEngine::Scalar] {
            let mut venv = VecEnv::from_registry_with("cartpole", 1, 3, 1, engine).unwrap();
            venv.reset_all();
            let mut act_rng = Pcg64::with_stream(3, ACTION_STREAM_BASE);
            let mut actions = vec![0.0f32; venv.act_dim()];
            let mut infos = vec![VecStepInfo::default(); 1];
            let mut saw_terminal = false;
            for _ in 0..2000 {
                act_rng.fill_uniform(&mut actions, -1.0, 1.0);
                venv.step_all(&actions, &mut infos);
                if infos[0].terminal {
                    saw_terminal = true;
                    let boot = venv.obs_row(0).to_vec();
                    venv.reset_env(0);
                    assert_ne!(
                        venv.obs_row(0),
                        &boot[..],
                        "{engine:?}: reset_env must redraw the row (terminal \
                         cartpole state is outside the reset distribution)"
                    );
                    break;
                }
                if infos[0].ended() {
                    venv.reset_env(0);
                }
            }
            assert!(saw_terminal, "{engine:?}: cartpole never terminated");
        }
    }

    /// Wrapper stacks (any third-party `Env` impl) ride the scalar
    /// engine; with identity-semantics wrappers the stack must match the
    /// batched engine of the bare env bitwise — the harness works across
    /// engines AND across wrapper layers.
    #[test]
    fn wrapper_stack_on_scalar_engine_matches_batched_bare_env() {
        let m = 3;
        let seed = 17u64;
        let envs: Vec<Box<dyn crate::env::Env>> = (0..m)
            .map(|_| {
                Box::new(RewardScale {
                    inner: ObsClip {
                        inner: Pendulum::default(),
                        bound: 1e30,
                    },
                    scale: 1.0,
                }) as Box<dyn crate::env::Env>
            })
            .collect();
        let rngs: Vec<Pcg64> = (0..m as u64)
            .map(|i| Pcg64::with_stream(seed, 1 + i))
            .collect();
        let mut stack = VecEnv::new(envs, rngs).unwrap();
        assert_eq!(stack.engine(), EnvEngine::Scalar, "wrapper stacks are scalar");
        let mut bare =
            VecEnv::from_registry_with("pendulum", m, seed, 1, EnvEngine::Batched).unwrap();
        let cap = bare.max_episode_steps();
        let stats = drive_lockstep_pair(&mut stack, &mut bare, seed ^ 0xACAC, cap + 9);
        assert!(stats.truncations > 0);
    }

    // ---- scalar Env contract suite --------------------------------------

    #[test]
    fn observations_always_finite_and_right_sized() {
        for name in ENV_NAMES {
            let mut env = make_env(name).unwrap();
            let mut rng = Pcg64::new(42);
            let mut obs = vec![0.0f32; env.obs_dim()];
            let mut act = vec![0.0f32; env.act_dim()];
            env.reset(&mut rng, &mut obs);
            assert!(obs.iter().all(|v| v.is_finite()), "{name} reset obs");
            for i in 0..200 {
                for a in act.iter_mut() {
                    *a = rng.uniform(-1.0, 1.0);
                }
                let s = env.step(&act, &mut obs);
                assert!(s.reward.is_finite(), "{name} step {i} reward");
                assert!(
                    obs.iter().all(|v| v.is_finite()),
                    "{name} step {i} obs not finite"
                );
                if s.done {
                    env.reset(&mut rng, &mut obs);
                }
            }
        }
    }

    #[test]
    fn resets_reproducible_from_seed() {
        for name in ENV_NAMES {
            let mut e1 = make_env(name).unwrap();
            let mut e2 = make_env(name).unwrap();
            let mut o1 = vec![0.0f32; e1.obs_dim()];
            let mut o2 = vec![0.0f32; e2.obs_dim()];
            e1.reset(&mut Pcg64::new(123), &mut o1);
            e2.reset(&mut Pcg64::new(123), &mut o2);
            assert_eq!(o1, o2, "{name} reset not deterministic");
        }
    }

    #[test]
    fn rollouts_reproducible_from_seed() {
        for name in ENV_NAMES {
            let run = || {
                let mut env = make_env(name).unwrap();
                let mut rng = Pcg64::new(9);
                let mut obs = vec![0.0f32; env.obs_dim()];
                let mut act = vec![0.0f32; env.act_dim()];
                env.reset(&mut rng, &mut obs);
                let mut total = 0.0f32;
                for _ in 0..100 {
                    for a in act.iter_mut() {
                        *a = rng.uniform(-1.0, 1.0);
                    }
                    let s = env.step(&act, &mut obs);
                    total += s.reward;
                    if s.done {
                        env.reset(&mut rng, &mut obs);
                    }
                }
                (total, obs)
            };
            let (r1, o1) = run();
            let (r2, o2) = run();
            assert_eq!(r1, r2, "{name} rollout reward not deterministic");
            assert_eq!(o1, o2, "{name} rollout obs not deterministic");
        }
    }

    #[test]
    fn out_of_range_actions_are_tolerated() {
        for name in ENV_NAMES {
            let mut env = make_env(name).unwrap();
            let mut rng = Pcg64::new(5);
            let mut obs = vec![0.0f32; env.obs_dim()];
            env.reset(&mut rng, &mut obs);
            let huge = vec![1e6f32; env.act_dim()];
            for _ in 0..20 {
                let s = env.step(&huge, &mut obs);
                assert!(s.reward.is_finite(), "{name} blew up on huge action");
                if s.done {
                    env.reset(&mut rng, &mut obs);
                }
            }
        }
    }

    #[test]
    fn episode_caps_are_positive_and_sane() {
        for name in ENV_NAMES {
            let env = make_env(name).unwrap();
            let cap = env.max_episode_steps();
            assert!(cap >= 50 && cap <= 1000, "{name} cap {cap}");
        }
    }
}
