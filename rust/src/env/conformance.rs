//! Cross-env conformance suite: every registered environment must satisfy
//! the `Env` contract (finite observations, declared dims, reproducible
//! resets, clipped-action tolerance). Runs over the registry so a new env
//! is automatically covered.

#[cfg(test)]
mod tests {
    use crate::env::registry::{make_env, ENV_NAMES};
    use crate::util::rng::Pcg64;

    #[test]
    fn observations_always_finite_and_right_sized() {
        for name in ENV_NAMES {
            let mut env = make_env(name).unwrap();
            let mut rng = Pcg64::new(42);
            let mut obs = vec![0.0f32; env.obs_dim()];
            let mut act = vec![0.0f32; env.act_dim()];
            env.reset(&mut rng, &mut obs);
            assert!(obs.iter().all(|v| v.is_finite()), "{name} reset obs");
            for i in 0..200 {
                for a in act.iter_mut() {
                    *a = rng.uniform(-1.0, 1.0);
                }
                let s = env.step(&act, &mut obs);
                assert!(s.reward.is_finite(), "{name} step {i} reward");
                assert!(
                    obs.iter().all(|v| v.is_finite()),
                    "{name} step {i} obs not finite"
                );
                if s.done {
                    env.reset(&mut rng, &mut obs);
                }
            }
        }
    }

    #[test]
    fn resets_reproducible_from_seed() {
        for name in ENV_NAMES {
            let mut e1 = make_env(name).unwrap();
            let mut e2 = make_env(name).unwrap();
            let mut o1 = vec![0.0f32; e1.obs_dim()];
            let mut o2 = vec![0.0f32; e2.obs_dim()];
            e1.reset(&mut Pcg64::new(123), &mut o1);
            e2.reset(&mut Pcg64::new(123), &mut o2);
            assert_eq!(o1, o2, "{name} reset not deterministic");
        }
    }

    #[test]
    fn rollouts_reproducible_from_seed() {
        for name in ENV_NAMES {
            let run = || {
                let mut env = make_env(name).unwrap();
                let mut rng = Pcg64::new(9);
                let mut obs = vec![0.0f32; env.obs_dim()];
                let mut act = vec![0.0f32; env.act_dim()];
                env.reset(&mut rng, &mut obs);
                let mut total = 0.0f32;
                for _ in 0..100 {
                    for a in act.iter_mut() {
                        *a = rng.uniform(-1.0, 1.0);
                    }
                    let s = env.step(&act, &mut obs);
                    total += s.reward;
                    if s.done {
                        env.reset(&mut rng, &mut obs);
                    }
                }
                (total, obs)
            };
            let (r1, o1) = run();
            let (r2, o2) = run();
            assert_eq!(r1, r2, "{name} rollout reward not deterministic");
            assert_eq!(o1, o2, "{name} rollout obs not deterministic");
        }
    }

    #[test]
    fn out_of_range_actions_are_tolerated() {
        for name in ENV_NAMES {
            let mut env = make_env(name).unwrap();
            let mut rng = Pcg64::new(5);
            let mut obs = vec![0.0f32; env.obs_dim()];
            env.reset(&mut rng, &mut obs);
            let huge = vec![1e6f32; env.act_dim()];
            for _ in 0..20 {
                let s = env.step(&huge, &mut obs);
                assert!(s.reward.is_finite(), "{name} blew up on huge action");
                if s.done {
                    env.reset(&mut rng, &mut obs);
                }
            }
        }
    }

    #[test]
    fn episode_caps_are_positive_and_sane() {
        for name in ENV_NAMES {
            let env = make_env(name).unwrap();
            let cap = env.max_episode_steps();
            assert!(cap >= 50 && cap <= 1000, "{name} cap {cap}");
        }
    }
}
