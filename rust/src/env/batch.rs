//! Structure-of-arrays batched environments: the fleet-wide lockstep env
//! engine (WarpDrive direction — make the *environment* data-parallel,
//! not just the policy).
//!
//! A [`BatchedEnv`] holds the state of M homogeneous environments as
//! `[M]`-wide columns (one contiguous lane array per physical quantity)
//! and advances all of them in ONE [`BatchedEnv::step_all`] sweep. The
//! per-lane arithmetic runs column-at-a-time through the `nn::kernels`
//! microkernels (`axpy` / `axpy_clamp` integrator steps, dispatched to
//! the scalar reference arm or the SIMD arms), while transcendentals stay
//! scalar-per-lane (libm, like `tanh` in the policy kernels) — so in
//! exact mode every lane is **bitwise identical** to an independent
//! scalar [`Env`](super::Env) stepped with the same RNG stream, at any
//! vector width, on any arm (asserted per registered env by
//! `env::conformance`).
//!
//! # Contract
//!
//! * Lane `i` of a `BatchedEnv` must reproduce, bit for bit, the
//!   trajectory of the same-named scalar env driven by the same RNG
//!   stream: same state-update order, same rounding, same RNG draw order
//!   on [`BatchedEnv::reset_lane`].
//! * `step_all` never resets: finished lanes hold the terminal/truncated
//!   observation s' until the caller resets them (the
//!   [`VecEnv`](super::vec_env::VecEnv) ordering). Episode accounting
//!   (step counts, truncation) stays in `VecEnv`, identical for both
//!   engines.
//! * `step_all` writes next observations row-major (`[M * obs_dim]`)
//!   straight into the caller's buffer — which in the sampler hot loop is
//!   a view of the recycled inference `SlabBuffers` obs slab (zero-copy
//!   handoff; see `coordinator::sampler`).
//! * [`BatchedEnv::save_lane`] / [`BatchedEnv::load_lane`] use the SAME
//!   flat-f32 layout as the scalar env's `save_state` / `load_state`, so
//!   checkpoints and respawn snapshots are portable across engines (a
//!   snapshot taken under `--env-engine batched` restores under
//!   `--env-engine scalar` and vice versa).
//!
//! # Engine selection
//!
//! Like the kernel lane set, the env engine is process-global and
//! resolved once, on first use: batched for every registry env unless
//! overridden. The `WALLE_ENV_ENGINE` environment variable (`scalar` |
//! `batched` | `auto`) overrides detection; the orchestrator sets the
//! engine from `TrainConfig::env_engine` before spawning workers (same
//! pattern as `kernels::set_mode`). Concurrent tests that need a specific
//! engine should build it explicitly via
//! [`VecEnv::from_registry_with`](super::vec_env::VecEnv::from_registry_with)
//! instead of flipping the global.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::rng::Pcg64;

/// Result of one lockstep sweep for one lane (mirrors [`super::Step`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchStep {
    pub reward: f32,
    /// True terminal state — GAE must NOT bootstrap through.
    pub done: bool,
}

/// M homogeneous environments stored as structure-of-arrays columns and
/// advanced in one sweep. See the module docs for the bitwise contract.
pub trait BatchedEnv: Send {
    /// Vector width M (fixed at construction).
    fn num_envs(&self) -> usize;

    fn obs_dim(&self) -> usize;

    fn act_dim(&self) -> usize;

    /// Episode cap the caller (`VecEnv`) enforces as truncation.
    fn max_episode_steps(&self) -> usize;

    /// Environment name — equals the scalar env's `name()`.
    fn name(&self) -> &'static str;

    /// Reset lane `lane` only, drawing from `rng` in exactly the order
    /// the scalar env's `reset` draws, and write its fresh observation
    /// into `obs_row` (`[obs_dim]`).
    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg64, obs_row: &mut [f32]);

    /// Advance all M lanes one step with `actions` (`[M * act_dim]`,
    /// already clipped by the caller), writing next observations
    /// row-major into `obs_out` (`[M * obs_dim]`). Returns per-lane
    /// outcomes. Never auto-resets.
    fn step_all(&mut self, actions: &[f32], obs_out: &mut [f32]) -> &[BatchStep];

    /// Serialize lane `lane` in the scalar env's `save_state` layout.
    fn save_lane(&self, lane: usize) -> Vec<f32>;

    /// Restore lane `lane` from a scalar-layout state payload.
    fn load_lane(&mut self, lane: usize, state: &[f32]);
}

/// Which env engine `VecEnv::from_registry` builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvEngine {
    /// SoA lockstep engine (the default for registry envs).
    Batched,
    /// Legacy per-env scalar stepping (reference arm; also the only
    /// option for wrapper stacks and third-party scalar envs).
    Scalar,
}

impl EnvEngine {
    pub fn name(self) -> &'static str {
        match self {
            EnvEngine::Batched => "batched",
            EnvEngine::Scalar => "scalar",
        }
    }
}

const ENGINE_UNSET: u8 = u8::MAX;
static ENGINE: AtomicU8 = AtomicU8::new(ENGINE_UNSET);

fn engine_to_u8(e: EnvEngine) -> u8 {
    match e {
        EnvEngine::Batched => 0,
        EnvEngine::Scalar => 1,
    }
}

fn engine_from_u8(v: u8) -> EnvEngine {
    match v {
        1 => EnvEngine::Scalar,
        _ => EnvEngine::Batched,
    }
}

fn detect() -> EnvEngine {
    match std::env::var("WALLE_ENV_ENGINE").ok().as_deref() {
        Some("scalar") => EnvEngine::Scalar,
        // "batched"/"auto"/unset/anything else: the SoA engine (unknown
        // values must not silently fall back to scalar in production)
        _ => EnvEngine::Batched,
    }
}

/// The process-wide active env engine (resolved once, on first use).
pub fn active_engine() -> EnvEngine {
    let v = ENGINE.load(Ordering::Relaxed);
    if v != ENGINE_UNSET {
        return engine_from_u8(v);
    }
    let e = detect();
    ENGINE.store(engine_to_u8(e), Ordering::Relaxed);
    e
}

/// Force the env engine process-wide (orchestrator / benches / tests).
/// Call before any `VecEnv::from_registry`; like `kernels::set_mode`
/// this is process-global, so concurrent tests must build explicit
/// engines via `VecEnv::from_registry_with` instead.
pub fn set_engine(e: EnvEngine) {
    ENGINE.store(engine_to_u8(e), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names_round_trip() {
        for e in [EnvEngine::Batched, EnvEngine::Scalar] {
            assert_eq!(engine_from_u8(engine_to_u8(e)), e);
        }
        assert_eq!(EnvEngine::Batched.name(), "batched");
        assert_eq!(EnvEngine::Scalar.name(), "scalar");
    }

    #[test]
    fn unknown_byte_defaults_to_batched() {
        assert_eq!(engine_from_u8(200), EnvEngine::Batched);
    }
}
