//! Name-based environment registry: maps preset names (the same names the
//! AOT artifacts use) to constructors, so the launcher, benches and tests
//! all build envs through one path.

use super::batch::BatchedEnv;
use super::cartpole::{BatchedCartPole, CartPole};
use super::halfcheetah::{BatchedHalfCheetah, HalfCheetah};
use super::pendulum::{BatchedPendulum, Pendulum};
use super::reacher::{BatchedReacher, Reacher};
use super::Env;

/// All registered env names, in preset order.
pub const ENV_NAMES: [&str; 4] = ["pendulum", "cartpole", "reacher", "halfcheetah"];

/// Construct an env by name. Returns `None` for unknown names.
pub fn make_env(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "pendulum" => Some(Box::new(Pendulum::default())),
        "cartpole" => Some(Box::new(CartPole::default())),
        "reacher" => Some(Box::new(Reacher::default())),
        "halfcheetah" => Some(Box::new(HalfCheetah::default())),
        _ => None,
    }
}

/// Construct the SoA batched engine for a registered env at vector width
/// `m`. Every registry env has one; `None` only for unknown names.
pub fn make_batched_env(name: &str, m: usize) -> Option<Box<dyn BatchedEnv>> {
    match name {
        "pendulum" => Some(Box::new(BatchedPendulum::new(m))),
        "cartpole" => Some(Box::new(BatchedCartPole::new(m))),
        "reacher" => Some(Box::new(BatchedReacher::new(m))),
        "halfcheetah" => Some(Box::new(BatchedHalfCheetah::new(m))),
        _ => None,
    }
}

/// (obs_dim, act_dim) for a registered env.
pub fn env_dims(name: &str) -> Option<(usize, usize)> {
    let e = make_env(name)?;
    Some((e.obs_dim(), e.act_dim()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_construct() {
        for name in ENV_NAMES {
            let env = make_env(name).unwrap();
            assert_eq!(env.name(), name);
            assert!(env.obs_dim() > 0 && env.act_dim() > 0);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(make_env("mujoco").is_none());
        assert!(make_batched_env("mujoco", 2).is_none());
    }

    #[test]
    fn every_env_has_a_batched_engine_with_matching_dims() {
        for name in ENV_NAMES {
            let be = make_batched_env(name, 3).unwrap();
            let e = make_env(name).unwrap();
            assert_eq!(be.name(), name);
            assert_eq!(be.num_envs(), 3);
            assert_eq!((be.obs_dim(), be.act_dim()), (e.obs_dim(), e.act_dim()));
            assert_eq!(be.max_episode_steps(), e.max_episode_steps());
        }
    }

    #[test]
    fn dims_match_aot_presets() {
        // must agree with python/compile/aot.py PRESETS
        assert_eq!(env_dims("pendulum"), Some((3, 1)));
        assert_eq!(env_dims("cartpole"), Some((4, 1)));
        assert_eq!(env_dims("reacher"), Some((10, 2)));
        assert_eq!(env_dims("halfcheetah"), Some((17, 6)));
    }
}
