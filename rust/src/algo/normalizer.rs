//! Running observation normalization shared through the policy queue.
//!
//! The learner owns a mutable [`RunningNorm`] updated from every chunk it
//! consumes; each policy publication includes a frozen [`NormSnapshot`]
//! that samplers apply to raw observations before the policy sees them.
//! Normalizing on the *sampler* side keeps the policy's input distribution
//! consistent between acting and learning.

use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::stats::Welford;
use anyhow::Result;

/// Per-dimension running mean/std (Welford).
#[derive(Debug, Clone)]
pub struct RunningNorm {
    dims: Vec<Welford>,
    clip: f32,
}

impl RunningNorm {
    pub fn new(dim: usize, clip: f32) -> Self {
        Self {
            dims: vec![Welford::default(); dim],
            clip,
        }
    }

    pub fn dim(&self) -> usize {
        self.dims.len()
    }

    /// Update from a row-major batch [n * dim].
    pub fn update(&mut self, batch: &[f32]) {
        let d = self.dims.len();
        assert_eq!(batch.len() % d, 0);
        for row in batch.chunks_exact(d) {
            for (w, &x) in self.dims.iter_mut().zip(row) {
                w.push(x as f64);
            }
        }
    }

    /// Merge sampler-side accumulators (parallel Welford).
    pub fn merge(&mut self, other: &RunningNorm) {
        assert_eq!(self.dims.len(), other.dims.len());
        for (a, b) in self.dims.iter_mut().zip(&other.dims) {
            a.merge(b);
        }
    }

    pub fn count(&self) -> u64 {
        self.dims.first().map_or(0, |w| w.n)
    }

    /// Serialize the full accumulator state (clip + per-dimension Welford
    /// registers) into a checkpoint blob. [`RunningNorm::load_state`]
    /// restores it bitwise, so a resumed learner normalizes exactly as
    /// the interrupted one would have.
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.put_f32(self.clip);
        w.put_usize(self.dims.len());
        for d in &self.dims {
            let (n, mean, m2) = d.raw();
            w.put_u64(n);
            w.put_f64(mean);
            w.put_f64(m2);
        }
    }

    /// Rebuild a normalizer from [`RunningNorm::save_state`] output.
    pub fn load_state(r: &mut ByteReader) -> Result<RunningNorm> {
        let clip = r.read_f32()?;
        let dim = r.read_usize()?;
        let mut dims = Vec::with_capacity(dim);
        for _ in 0..dim {
            let n = r.read_u64()?;
            let mean = r.read_f64()?;
            let m2 = r.read_f64()?;
            dims.push(Welford::from_raw(n, mean, m2));
        }
        Ok(RunningNorm { dims, clip })
    }

    pub fn snapshot(&self) -> NormSnapshot {
        NormSnapshot {
            mean: self.dims.iter().map(|w| w.mean() as f32).collect(),
            inv_std: self
                .dims
                .iter()
                .map(|w| {
                    let s = w.std();
                    if !s.is_finite() || s < 1e-6 {
                        1.0
                    } else {
                        (1.0 / s) as f32
                    }
                })
                .collect(),
            clip: self.clip,
            count: self.count(),
        }
    }
}

/// Frozen normalization parameters applied by samplers.
#[derive(Debug, Clone, PartialEq)]
pub struct NormSnapshot {
    pub mean: Vec<f32>,
    pub inv_std: Vec<f32>,
    pub clip: f32,
    pub count: u64,
}

impl NormSnapshot {
    /// Identity transform (used before any data has been seen).
    pub fn identity(dim: usize) -> Self {
        Self {
            mean: vec![0.0; dim],
            inv_std: vec![1.0; dim],
            clip: 10.0,
            count: 0,
        }
    }

    /// Normalize one observation in place.
    pub fn apply(&self, obs: &mut [f32]) {
        // Until enough data has accumulated, pass through unchanged — a
        // mean estimated from a handful of samples does more harm than good.
        if self.count < 64 {
            return;
        }
        for i in 0..obs.len() {
            let z = (obs[i] - self.mean[i]) * self.inv_std[i];
            obs[i] = z.clamp(-self.clip, self.clip);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity_before_warmup() {
        let norm = RunningNorm::new(2, 5.0);
        let snap = norm.snapshot();
        let mut obs = [3.0f32, -4.0];
        snap.apply(&mut obs);
        assert_eq!(obs, [3.0, -4.0]);
    }

    #[test]
    fn standardizes_after_enough_data() {
        let mut norm = RunningNorm::new(1, 10.0);
        let mut rng = Pcg64::new(0);
        let data: Vec<f32> = (0..10_000).map(|_| 5.0 + 2.0 * rng.normal()).collect();
        norm.update(&data);
        let snap = norm.snapshot();
        // an observation at the mean maps to ~0; one std away maps to ~1
        let mut at_mean = [5.0f32];
        snap.apply(&mut at_mean);
        assert!(at_mean[0].abs() < 0.1, "{}", at_mean[0]);
        let mut at_std = [7.0f32];
        snap.apply(&mut at_std);
        assert!((at_std[0] - 1.0).abs() < 0.1, "{}", at_std[0]);
    }

    #[test]
    fn clipping_bounds_output() {
        let mut norm = RunningNorm::new(1, 3.0);
        let data: Vec<f32> = (0..1000).map(|i| (i % 10) as f32).collect();
        norm.update(&data);
        let mut outlier = [1e6f32];
        norm.snapshot().apply(&mut outlier);
        assert!(outlier[0] <= 3.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Pcg64::new(1);
        let data: Vec<f32> = (0..600).map(|_| rng.normal() * 3.0 + 1.0).collect();
        let mut all = RunningNorm::new(3, 10.0);
        all.update(&data);
        let mut a = RunningNorm::new(3, 10.0);
        let mut b = RunningNorm::new(3, 10.0);
        a.update(&data[..300]);
        b.update(&data[300..]);
        a.merge(&b);
        let (sa, sb) = (a.snapshot(), all.snapshot());
        for i in 0..3 {
            assert!((sa.mean[i] - sb.mean[i]).abs() < 1e-4);
            assert!((sa.inv_std[i] - sb.inv_std[i]).abs() < 1e-4);
        }
        assert_eq!(a.count(), 200); // 600 values / 3 dims
    }

    #[test]
    fn state_round_trip_is_bitwise() {
        let mut norm = RunningNorm::new(3, 5.0);
        let mut rng = Pcg64::new(4);
        let data: Vec<f32> = (0..900).map(|_| rng.normal() * 2.0 - 1.0).collect();
        norm.update(&data);
        let mut w = crate::util::bytes::ByteWriter::new();
        norm.save_state(&mut w);
        let buf = w.into_vec();
        let mut r = crate::util::bytes::ByteReader::new(&buf);
        let mut back = RunningNorm::load_state(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(norm.snapshot(), back.snapshot());
        // continued updates agree bitwise too
        let more: Vec<f32> = (0..90).map(|_| rng.normal()).collect();
        norm.update(&more);
        back.update(&more);
        assert_eq!(norm.snapshot(), back.snapshot());
    }

    #[test]
    fn degenerate_dim_keeps_unit_scale() {
        let mut norm = RunningNorm::new(2, 10.0);
        // dim 1 constant — std 0 must not produce inf
        let data: Vec<f32> = (0..200).flat_map(|i| [i as f32, 7.0]).collect();
        norm.update(&data);
        let snap = norm.snapshot();
        assert_eq!(snap.inv_std[1], 1.0);
        let mut obs = [0.0f32, 7.0];
        snap.apply(&mut obs);
        assert!(obs[1].abs() < 1e-5);
    }
}
