//! RL algorithm cores: GAE, rollout data structures, PPO/DDPG update
//! logic, and observation normalization. All algorithm math that is not
//! network compute lives here; the network compute goes through
//! `runtime::*Backend` (XLA artifacts or the native mirror).

pub mod ddpg;
pub mod gae;
pub mod normalizer;
pub mod ppo;
pub mod rollout;
