//! RL algorithm cores behind ONE abstraction: [`api::Algorithm`] is the
//! trait every pipeline stage (sampler loop, shared-inference pool,
//! learner driver, orchestrator, eval) is generic over; [`ppo`],
//! [`ddpg`], [`td3`], and [`sac`] implement it. GAE, rollout data
//! structures, and observation normalization live alongside. All
//! algorithm math that is not network compute lives here; the network
//! compute goes through `runtime::*Backend` (XLA artifacts or the native
//! mirror).

pub mod api;
pub mod ddpg;
pub mod gae;
pub mod normalizer;
pub mod ppo;
pub mod rollout;
pub mod sac;
pub mod td3;

pub use api::{AlgoSampler, Algorithm, LearnerDriver};
