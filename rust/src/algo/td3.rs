//! TD3 — Twin Delayed Deep Deterministic policy gradient (Fujimoto et
//! al., 2018) — implemented **entirely against the [`Algorithm`] trait**:
//! the worked example that the `Session` + trait redesign carries its
//! weight. No edits to `coordinator/sampler.rs`,
//! `coordinator/orchestrator.rs`, or `runtime/inference_server.rs` were
//! needed to land it; the only registration points are the
//! `config::Algo::Td3` variant and the `algo::api::algorithm_from_config`
//! match arm (see `docs/API.md` for the add-your-own-algorithm
//! walkthrough built on this file).
//!
//! TD3 refines DDPG with three tricks:
//! 1. **Twin critics** — two independently initialized Q networks; the
//!    TD target uses `min(Q1', Q2')`, damping the overestimation bias of
//!    a single bootstrapped critic.
//! 2. **Delayed policy updates** — the actor (and all three target
//!    networks) step once per `policy_delay` critic updates, letting the
//!    critics settle before the actor chases them.
//! 3. **Target-policy smoothing** — the target action is
//!    `clamp(μ'(s') + clamp(ε, ±noise_clip), ±1)` with
//!    `ε ~ N(0, target_noise²)`, smoothing the value estimate over a
//!    small action neighborhood.
//!
//! Sampler side, TD3 *is* a deterministic-policy algorithm: it reuses
//! [`DeterministicSampler`] (Gaussian exploration noise, replay chunks
//! with a trailing s' row) on its own RNG stream family, and the same
//! deterministic actor network as DDPG — so the shared inference pool
//! serves it through the existing `make_ddpg_actor_shared` backend hook.
//! Because the actor network is DDPG-shaped, `--backend xla` works out of
//! the box: the sampler and eval paths reuse the compiled `act_ddpg_b{B}`
//! AOT artifacts unchanged. Learner side, the twin-critic math always
//! runs on the native `nn::mlp` kernels regardless of backend (the only
//! remaining xla gate is learner-side: `learner_threads > 1` needs the
//! grained native reduction, so `TrainConfig::validate` still rejects
//! that combination).

use crate::algo::api::{AlgoSampler, Algorithm, LearnerDriver};
use crate::algo::ddpg::{make_det_local_actor, make_det_server_actor, DeterministicSampler};
use crate::algo::normalizer::RunningNorm;
use crate::algo::rollout::{ChunkEnd, ExperienceChunk};
use crate::config::{Algo, ReplayStrategy, Td3Cfg, TrainConfig};
use crate::coordinator::learn_pool::{grain_ranges, run_grains, tree_reduce, tree_reduce_scalar};
use crate::coordinator::metrics::IterationMetrics;
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::SamplerCfg;
use crate::nn::adam::{Adam, AdamCfg};
use crate::nn::layout::{actor_layout, critic_layout, ParamLayout};
use crate::nn::mlp::{self, NetShape};
use crate::nn::tensor::Mat;
use crate::replay::shard::{ReplayRng, ShardSample, ShardedReplay};
use crate::runtime::{ActorBackend, BackendFactory, ServerActor};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Stream-id base for TD3 exploration-noise RNGs (disjoint from PPO's
/// `1 << 32` and DDPG's `1 << 33` so switching algorithms never aliases
/// noise streams).
const TD3_NOISE_STREAM_BASE: u64 = 1 << 34;

/// RNG stream id of the learner (minibatch sampling + target smoothing).
const TD3_LEARNER_STREAM: u64 = 0x7D3;

/// TD3's [`Algorithm`] registration.
#[derive(Debug, Clone, Default)]
pub struct Td3 {
    pub cfg: Td3Cfg,
}

impl Algorithm for Td3 {
    fn id(&self) -> Algo {
        Algo::Td3
    }

    fn make_sampler(&self, scfg: &SamplerCfg, m: usize, act_dim: usize) -> Box<dyn AlgoSampler> {
        // same deterministic-policy hooks as DDPG, on TD3's own streams
        Box::new(DeterministicSampler::new(
            scfg,
            m,
            act_dim,
            TD3_NOISE_STREAM_BASE,
            self.cfg.explore_noise,
        ))
    }

    fn make_local_actor(
        &self,
        factory: &dyn BackendFactory,
        rows: usize,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        // TD3's actor network is the DDPG deterministic actor
        make_det_local_actor(factory, rows)
    }

    fn make_server_actor(
        &self,
        factory: &dyn BackendFactory,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn ServerActor>> {
        make_det_server_actor(factory, max_rows)
    }

    fn make_eval_actor(
        &self,
        factory: &dyn BackendFactory,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        make_det_local_actor(factory, 1)
    }

    fn make_learner(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> anyhow::Result<Box<dyn LearnerDriver>> {
        Ok(Box::new(Td3Learner::with_topology(
            factory.obs_dim(),
            factory.act_dim(),
            &cfg.hidden,
            cfg.td3.replay_capacity,
            cfg.seed,
            cfg.replay_shards,
            cfg.replay_strategy,
            cfg.learner_threads,
        )))
    }

    fn policy_param_count(&self, factory: &dyn BackendFactory, cfg: &TrainConfig) -> usize {
        actor_layout(factory.obs_dim(), factory.act_dim(), &cfg.hidden).total()
    }

    fn hyperparams(&self, cfg: &TrainConfig) -> Json {
        cfg.td3.to_json()
    }

    fn apply_to(&self, cfg: &mut TrainConfig) {
        cfg.algo = Algo::Td3;
        cfg.td3 = self.cfg.clone();
    }

    fn quantizer(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> Option<crate::coordinator::policy_store::Quantizer> {
        Some(crate::algo::ddpg::det_actor_quantizer(factory, cfg))
    }
}

/// Aggregated statistics for one TD3 update round.
#[derive(Debug, Clone, Copy, Default)]
pub struct Td3UpdateStats {
    /// Mean twin-critic TD loss (both critics averaged).
    pub q_loss: f32,
    /// Mean actor loss over the (delayed) policy steps; 0 if none ran.
    pub pi_loss: f32,
    /// Critic updates performed.
    pub updates: usize,
    /// Delayed actor/target updates performed.
    pub actor_updates: usize,
}

/// Flat parameter + Adam state for TD3's five networks (actor, twin
/// critics, and their Polyak-averaged targets).
pub struct Td3State {
    pub actor: Vec<f32>,
    pub critic1: Vec<f32>,
    pub critic2: Vec<f32>,
    pub targ_actor: Vec<f32>,
    pub targ_critic1: Vec<f32>,
    pub targ_critic2: Vec<f32>,
    am: Vec<f32>,
    av: Vec<f32>,
    c1m: Vec<f32>,
    c1v: Vec<f32>,
    c2m: Vec<f32>,
    c2v: Vec<f32>,
    /// Adam step counters (separate: the actor steps `policy_delay`
    /// times less often, so its bias correction must track its own t).
    actor_t: u64,
    critic_t: u64,
}

impl Td3State {
    fn new(actor: Vec<f32>, critic1: Vec<f32>, critic2: Vec<f32>) -> Td3State {
        let (pa, pc) = (actor.len(), critic1.len());
        debug_assert_eq!(critic1.len(), critic2.len());
        Td3State {
            targ_actor: actor.clone(),
            targ_critic1: critic1.clone(),
            targ_critic2: critic2.clone(),
            actor,
            critic1,
            critic2,
            am: vec![0.0; pa],
            av: vec![0.0; pa],
            c1m: vec![0.0; pc],
            c1v: vec![0.0; pc],
            c2m: vec![0.0; pc],
            c2v: vec![0.0; pc],
            actor_t: 0,
            critic_t: 0,
        }
    }
}

/// TD3 learner: replay collection identical to DDPG's (the sampler
/// hooks produce the same trailing-s'-row chunks), with the twin-critic
/// / delayed-actor / smoothed-target update rule on the native kernels.
pub struct Td3Learner {
    pub state: Td3State,
    replay: ShardedReplay,
    /// Seed-addressable minibatch draw stream (shard-count invariant,
    /// checkpointable as two u64s).
    replay_rng: ReplayRng,
    /// Gradient-grain workers (pure wall-clock knob: updates are bitwise
    /// identical for every value — see `coordinator::learn_pool`).
    threads: usize,
    norm: RunningNorm,
    rng: Pcg64,
    total_steps: u64,
    wall: Stopwatch,
    obs_dim: usize,
    act_dim: usize,
    alayout: ParamLayout,
    clayout: ParamLayout,
    shape: NetShape,
    adam: AdamCfg,
    /// Critic updates since learner construction (drives the delay).
    update_count: u64,
}

impl Td3Learner {
    /// Single-shard, uniform, single-thread learner (unit-test default).
    pub fn new(
        obs_dim: usize,
        act_dim: usize,
        hidden: &[usize],
        replay_capacity: usize,
        seed: u64,
    ) -> Td3Learner {
        Self::with_topology(
            obs_dim,
            act_dim,
            hidden,
            replay_capacity,
            seed,
            1,
            ReplayStrategy::Uniform,
            1,
        )
    }

    /// Full topology constructor (the `Algorithm::make_learner` path):
    /// striped replay shards, uniform/prioritized draws, and the
    /// gradient-grain worker count.
    #[allow(clippy::too_many_arguments)]
    pub fn with_topology(
        obs_dim: usize,
        act_dim: usize,
        hidden: &[usize],
        replay_capacity: usize,
        seed: u64,
        replay_shards: usize,
        strategy: ReplayStrategy,
        learner_threads: usize,
    ) -> Td3Learner {
        let alayout = actor_layout(obs_dim, act_dim, hidden);
        let clayout = critic_layout(obs_dim, act_dim, hidden);
        // one init stream, three draws: actor, critic1, critic2 — the
        // twin critics start independently initialized by construction
        let mut init = Pcg64::new(seed);
        let actor = alayout.init_flat(&mut init);
        let critic1 = clayout.init_flat(&mut init);
        let critic2 = clayout.init_flat(&mut init);
        Td3Learner {
            state: Td3State::new(actor, critic1, critic2),
            replay: ShardedReplay::new(replay_capacity, obs_dim, act_dim, replay_shards, strategy),
            replay_rng: ReplayRng::new(seed),
            threads: learner_threads.max(1),
            norm: RunningNorm::new(obs_dim, 10.0),
            rng: Pcg64::with_stream(seed, TD3_LEARNER_STREAM),
            total_steps: 0,
            wall: Stopwatch::start(),
            obs_dim,
            act_dim,
            alayout,
            clayout,
            shape: NetShape::new(obs_dim, act_dim, hidden),
            adam: AdamCfg::default(),
            update_count: 0,
        }
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Shared-reference replay access (inserts take `&self`): benches and
    /// tests fill the buffer directly through this.
    pub fn replay(&self) -> &ShardedReplay {
        &self.replay
    }

    /// Insert a chunk's transitions (chunk.obs has len+1 rows; the
    /// trailing row is s' of the final transition — the
    /// [`DeterministicSampler`] contract, shared with DDPG).
    fn absorb_chunk(&mut self, c: &ExperienceChunk) {
        let o = self.obs_dim;
        let a = self.act_dim;
        let len = c.len();
        debug_assert_eq!(c.obs.len(), (len + 1) * o, "td3 chunk missing next-obs row");
        for i in 0..len {
            let obs = &c.obs[i * o..(i + 1) * o];
            let next = &c.obs[(i + 1) * o..(i + 2) * o];
            let act = &c.act[i * a..(i + 1) * a];
            let done = c.end == ChunkEnd::Terminal && i == len - 1;
            self.replay.push(obs, act, c.rew[i], next, done);
        }
        if let Some(stats) = &c.obs_stats {
            self.norm.merge(stats);
        }
    }

    /// Run `cfg.updates_per_iter` twin-critic updates (with delayed
    /// actor/target steps) sampling from the sharded replay buffer.
    /// No-op while the buffer is below the warmup threshold.
    ///
    /// The gradient computation is grain-decomposed
    /// (`coordinator::learn_pool`): the target-smoothing noise is
    /// pre-drawn sequentially in row-major order, every grain's partial
    /// is scaled by `1/B`, and the partials combine under a fixed-order
    /// tree reduction — so the updated parameters are **bitwise identical
    /// for every `learner_threads`** (serial at L = 1 runs the same
    /// grains). Importance weights apply to the value regressions only;
    /// critic-1 TD residuals feed prioritized-replay updates.
    pub fn update(&mut self, cfg: &Td3Cfg) -> anyhow::Result<Td3UpdateStats> {
        if self.replay.len() < cfg.warmup_steps.max(cfg.batch) {
            return Ok(Td3UpdateStats::default());
        }
        let b = cfg.batch;
        let (o, a) = (self.obs_dim, self.act_dim);
        let inv_n = 1.0 / b as f32;
        let mut sample = ShardSample::default();
        let mut eps = vec![0.0f32; b * a];
        let mut agg = Td3UpdateStats::default();
        for _ in 0..cfg.updates_per_iter {
            self.replay.sample_into(b, &mut self.replay_rng, &mut sample);

            // pre-draw the clipped smoothing noise sequentially (row-major)
            // so RNG consumption is independent of the grain layout
            for e in eps.iter_mut() {
                *e = (cfg.target_noise * self.rng.normal()).clamp(-cfg.noise_clip, cfg.noise_clip);
            }
            let ranges = grain_ranges(b);

            // --- per-grain TD target + twin critic gradient partials:
            //     target = r + γ(1-d) min(Q1'(s', ã), Q2'(s', ã)),
            //     ã = clamp(μ'(s') + clamp(ε, ±noise_clip), ±1)
            let (g1, l1, g2, l2, residuals) = {
                let st = &self.state;
                let smp = &sample;
                let noise = &eps;
                let (alayout, clayout, shape) = (&self.alayout, &self.clayout, &self.shape);
                let parts = run_grains(ranges.len(), self.threads, |g| {
                    let (s, e) = ranges[g];
                    let rows = e - s;
                    let next_g = Mat::from_vec(rows, o, smp.next_obs[s * o..e * o].to_vec());
                    let mut na = mlp::ddpg_actor(alayout, &st.targ_actor, shape, &next_g);
                    for (v, &n) in na.data.iter_mut().zip(&noise[s * a..e * a]) {
                        *v = (*v + n).clamp(-1.0, 1.0);
                    }
                    let q1 = mlp::ddpg_critic(clayout, &st.targ_critic1, shape, &next_g, &na);
                    let q2 = mlp::ddpg_critic(clayout, &st.targ_critic2, shape, &next_g, &na);
                    let target: Vec<f32> = (0..rows)
                        .map(|i| {
                            smp.rew[s + i]
                                + cfg.gamma * (1.0 - smp.done[s + i]) * q1[i].min(q2[i])
                        })
                        .collect();
                    let obs_g = Mat::from_vec(rows, o, smp.obs[s * o..e * o].to_vec());
                    let act_g = Mat::from_vec(rows, a, smp.act[s * a..e * a].to_vec());
                    let w = Some(&smp.weights[s..e]);
                    let (g1, l1, res) = mlp::ddpg_critic_grad_weighted(
                        clayout, &st.critic1, shape, &obs_g, &act_g, &target, w, inv_n,
                    );
                    let (g2, l2, _) = mlp::ddpg_critic_grad_weighted(
                        clayout, &st.critic2, shape, &obs_g, &act_g, &target, w, inv_n,
                    );
                    (g1, l1, g2, l2, res)
                });
                let n = parts.len();
                let (mut g1s, mut l1s) = (Vec::with_capacity(n), Vec::with_capacity(n));
                let (mut g2s, mut l2s) = (Vec::with_capacity(n), Vec::with_capacity(n));
                let mut residuals = Vec::with_capacity(b);
                for (g1, l1, g2, l2, res) in parts {
                    g1s.push(g1);
                    l1s.push(l1);
                    g2s.push(g2);
                    l2s.push(l2);
                    residuals.extend_from_slice(&res);
                }
                (
                    tree_reduce(g1s),
                    tree_reduce_scalar(l1s),
                    tree_reduce(g2s),
                    tree_reduce_scalar(l2s),
                    residuals,
                )
            };
            let mut c1adam = Adam {
                cfg: self.adam,
                m: std::mem::take(&mut self.state.c1m),
                v: std::mem::take(&mut self.state.c1v),
                t: self.state.critic_t,
            };
            c1adam.step(&mut self.state.critic1, &g1, cfg.lr_critic);
            self.state.c1m = c1adam.m;
            self.state.c1v = c1adam.v;
            let mut c2adam = Adam {
                cfg: self.adam,
                m: std::mem::take(&mut self.state.c2m),
                v: std::mem::take(&mut self.state.c2v),
                t: self.state.critic_t,
            };
            c2adam.step(&mut self.state.critic2, &g2, cfg.lr_critic);
            self.state.c2m = c2adam.m;
            self.state.c2v = c2adam.v;
            self.state.critic_t = c1adam.t;
            agg.q_loss += 0.5 * (l1 + l2);
            agg.updates += 1;
            self.update_count += 1;

            self.replay.update_priorities(&sample.indices, &residuals);

            // --- delayed policy + target updates (DPG through critic 1)
            if self.update_count % cfg.policy_delay as u64 == 0 {
                let (ga, pi_loss) = {
                    let st = &self.state;
                    let smp = &sample;
                    let (alayout, clayout, shape) = (&self.alayout, &self.clayout, &self.shape);
                    let parts = run_grains(ranges.len(), self.threads, |g| {
                        let (s, e) = ranges[g];
                        let rows = e - s;
                        let obs_g = Mat::from_vec(rows, o, smp.obs[s * o..e * o].to_vec());
                        mlp::ddpg_actor_grad_scaled(
                            alayout, &st.actor, clayout, &st.critic1, shape, &obs_g, inv_n,
                        )
                    });
                    let mut grads = Vec::with_capacity(parts.len());
                    let mut losses = Vec::with_capacity(parts.len());
                    for (g, l) in parts {
                        grads.push(g);
                        losses.push(l);
                    }
                    (tree_reduce(grads), tree_reduce_scalar(losses))
                };
                let mut aadam = Adam {
                    cfg: self.adam,
                    m: std::mem::take(&mut self.state.am),
                    v: std::mem::take(&mut self.state.av),
                    t: self.state.actor_t,
                };
                aadam.step(&mut self.state.actor, &ga, cfg.lr_actor);
                self.state.am = aadam.m;
                self.state.av = aadam.v;
                self.state.actor_t = aadam.t;
                polyak(&mut self.state.targ_actor, &self.state.actor, cfg.tau);
                polyak(&mut self.state.targ_critic1, &self.state.critic1, cfg.tau);
                polyak(&mut self.state.targ_critic2, &self.state.critic2, cfg.tau);
                agg.pi_loss += pi_loss;
                agg.actor_updates += 1;
            }
        }
        if agg.updates > 0 {
            agg.q_loss /= agg.updates as f32;
        }
        if agg.actor_updates > 0 {
            agg.pi_loss /= agg.actor_updates as f32;
        }
        Ok(agg)
    }
}

/// Polyak soft target update: `targ ← (1-τ)·targ + τ·online` (shared
/// with SAC).
pub(crate) fn polyak(targ: &mut [f32], online: &[f32], tau: f32) {
    for (t, w) in targ.iter_mut().zip(online) {
        *t = (1.0 - tau) * *t + tau * *w;
    }
}

impl LearnerDriver for Td3Learner {
    fn publish_initial(&self, store: &PolicyStore) {
        store.publish(self.state.actor.clone(), self.norm.snapshot());
    }

    fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        let iter_sw = Stopwatch::start();
        let collect_sw = Stopwatch::start();
        let mut n = 0usize;
        let mut returns: Vec<f32> = Vec::new();
        let mut lengths: Vec<usize> = Vec::new();
        let mut busy_per_worker: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        let mut chunks: Vec<ExperienceChunk> = Vec::new();
        while n < cfg.samples_per_iter {
            let c = queue
                .pop()
                .map_err(|_| anyhow::anyhow!("experience queue closed"))?;
            n += c.len();
            returns.extend_from_slice(&c.episode_returns);
            lengths.extend_from_slice(&c.episode_lengths);
            *busy_per_worker.entry(c.sampler_id).or_default() += c.busy_secs;
            chunks.push(c);
        }
        // canonical order before replay insertion + normalizer merges —
        // the learner's state must be a pure function of the chunk SET,
        // not of queue arrival interleaving (same rationale as PPO/DDPG)
        chunks.sort_by_key(|c| (c.policy_version, c.sampler_id, c.env_slot));
        for c in &chunks {
            self.absorb_chunk(c);
        }
        let collect_secs = collect_sw.elapsed_secs();
        let virtual_collect_secs = busy_per_worker.values().fold(0.0f64, |a, &b| a.max(b));

        let learn_sw = Stopwatch::start();
        let stats = self.update(&cfg.td3)?;
        let learn_secs = learn_sw.elapsed_secs();

        store.publish(self.state.actor.clone(), self.norm.snapshot());
        self.total_steps += n as u64;

        let mean_ep_len = if lengths.is_empty() {
            f32::NAN
        } else {
            lengths.iter().sum::<usize>() as f32 / lengths.len() as f32
        };
        Ok(IterationMetrics {
            iter,
            samples: n,
            collect_secs,
            virtual_collect_secs,
            learn_secs,
            total_secs: iter_sw.elapsed_secs(),
            mean_return: crate::util::stats::mean_f32(&returns),
            episodes: returns.len(),
            mean_ep_len,
            total_steps: self.total_steps,
            wall_secs: self.wall.elapsed_secs(),
            pi_loss: stats.pi_loss,
            v_loss: stats.q_loss,
            ..Default::default()
        })
    }

    fn final_params(&self) -> Vec<f32> {
        self.state.actor.clone()
    }

    fn final_norm(&self) -> crate::algo::normalizer::NormSnapshot {
        self.norm.snapshot()
    }

    /// Full off-policy training state INCLUDING replay contents (the
    /// versioned shard section) and the replay draw cursor, so a resumed
    /// run replays bitwise-identical minibatches.
    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.state.actor);
        w.put_f32s(&self.state.critic1);
        w.put_f32s(&self.state.critic2);
        w.put_f32s(&self.state.targ_actor);
        w.put_f32s(&self.state.targ_critic1);
        w.put_f32s(&self.state.targ_critic2);
        w.put_f32s(&self.state.am);
        w.put_f32s(&self.state.av);
        w.put_f32s(&self.state.c1m);
        w.put_f32s(&self.state.c1v);
        w.put_f32s(&self.state.c2m);
        w.put_f32s(&self.state.c2v);
        w.put_u64(self.state.actor_t);
        w.put_u64(self.state.critic_t);
        w.put_u64(self.update_count);
        let (rs, ri) = self.rng.raw_state();
        w.put_u128(rs);
        w.put_u128(ri);
        self.norm.save_state(&mut w);
        w.put_u64(self.total_steps);
        self.replay.save_state(&mut w);
        self.replay_rng.save_state(&mut w);
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let actor = r.read_f32s()?;
        anyhow::ensure!(
            actor.len() == self.state.actor.len(),
            "TD3 learner state mismatch: snapshot has {} actor params, this run has {}",
            actor.len(),
            self.state.actor.len()
        );
        self.state.actor = actor;
        self.state.critic1 = r.read_f32s()?;
        self.state.critic2 = r.read_f32s()?;
        self.state.targ_actor = r.read_f32s()?;
        self.state.targ_critic1 = r.read_f32s()?;
        self.state.targ_critic2 = r.read_f32s()?;
        self.state.am = r.read_f32s()?;
        self.state.av = r.read_f32s()?;
        self.state.c1m = r.read_f32s()?;
        self.state.c1v = r.read_f32s()?;
        self.state.c2m = r.read_f32s()?;
        self.state.c2v = r.read_f32s()?;
        self.state.actor_t = r.read_u64()?;
        self.state.critic_t = r.read_u64()?;
        self.update_count = r.read_u64()?;
        let (rs, ri) = (r.read_u128()?, r.read_u128()?);
        self.rng = Pcg64::from_raw(rs, ri);
        self.norm = RunningNorm::load_state(&mut r)?;
        self.total_steps = r.read_u64()?;
        self.replay.load_state(&mut r)?;
        self.replay_rng = ReplayRng::load_state(&mut r)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_learner(seed: u64) -> Td3Learner {
        let mut l = Td3Learner::new(2, 1, &[16, 16], 1000, seed);
        let mut rng = Pcg64::new(99);
        for _ in 0..300 {
            let o = [rng.normal(), rng.normal()];
            l.replay.push(&o, &[rng.uniform(-1.0, 1.0)], 1.0, &o, false);
        }
        l
    }

    #[test]
    fn update_noop_before_warmup() {
        let cfg = Td3Cfg {
            warmup_steps: 100,
            batch: 8,
            updates_per_iter: 5,
            ..Default::default()
        };
        let mut l = Td3Learner::new(2, 1, &[8, 8], 1000, 0);
        for i in 0..50 {
            l.replay
                .push(&[i as f32, 0.0], &[0.1], 1.0, &[i as f32 + 1.0, 0.0], false);
        }
        let before = l.state.actor.clone();
        let stats = l.update(&cfg).unwrap();
        assert_eq!(stats.updates, 0);
        assert_eq!(l.state.actor, before);
    }

    #[test]
    fn twin_critics_learn_q_and_stay_distinct() {
        // gamma = 0 makes the target exactly the reward; lr_actor = 0
        // isolates critic learning (delay still gates target updates)
        let cfg = Td3Cfg {
            warmup_steps: 10,
            batch: 16,
            updates_per_iter: 50,
            lr_actor: 0.0,
            lr_critic: 1e-2,
            gamma: 0.0,
            ..Default::default()
        };
        let mut l = filled_learner(1);
        assert_ne!(
            l.state.critic1, l.state.critic2,
            "twin critics must be independently initialized"
        );
        let first = l.update(&cfg).unwrap();
        let second = l.update(&cfg).unwrap();
        assert_eq!(first.updates, 50);
        assert!(
            second.q_loss < 0.5 * first.q_loss.max(1e-6) + 0.05,
            "q_loss did not drop: {} -> {}",
            first.q_loss,
            second.q_loss
        );
        assert_ne!(l.state.critic1, l.state.critic2, "twins must not collapse");
    }

    #[test]
    fn policy_updates_are_delayed() {
        let cfg = Td3Cfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 10,
            policy_delay: 1000, // never reached within this round
            ..Default::default()
        };
        let mut l = filled_learner(2);
        let actor_before = l.state.actor.clone();
        let targ_before = l.state.targ_critic1.clone();
        let stats = l.update(&cfg).unwrap();
        assert_eq!(stats.updates, 10);
        assert_eq!(stats.actor_updates, 0);
        assert_eq!(l.state.actor, actor_before, "delayed actor must not move");
        assert_eq!(
            l.state.targ_critic1, targ_before,
            "targets move only with the delayed step"
        );
        assert_ne!(l.state.critic1, Td3Learner::new(2, 1, &[16, 16], 10, 2).state.critic1);

        // delay 2 over 10 updates → exactly 5 actor steps
        let cfg2 = Td3Cfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 10,
            policy_delay: 2,
            ..Default::default()
        };
        let mut l2 = filled_learner(3);
        let stats2 = l2.update(&cfg2).unwrap();
        assert_eq!(stats2.actor_updates, 5);
        assert_ne!(l2.state.actor, filled_learner(3).state.actor);
    }

    #[test]
    fn target_smoothing_noise_is_clipped_and_seeded() {
        // two learners with the same seed take identical update
        // trajectories (smoothing noise comes from the seeded stream)
        let cfg = Td3Cfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 5,
            target_noise: 0.2,
            noise_clip: 0.05,
            ..Default::default()
        };
        let mut a = filled_learner(7);
        let mut b = filled_learner(7);
        a.update(&cfg).unwrap();
        b.update(&cfg).unwrap();
        assert_eq!(a.state.actor, b.state.actor);
        assert_eq!(a.state.critic1, b.state.critic1);
        assert_eq!(a.state.critic2, b.state.critic2);
    }

    #[test]
    fn publish_initial_exposes_actor_params() {
        let l = Td3Learner::new(3, 1, &[8, 8], 100, 5);
        let store = PolicyStore::new();
        LearnerDriver::publish_initial(&l, &store);
        let snap = store.latest().unwrap();
        assert_eq!(snap.version, 1);
        assert_eq!(snap.params.len(), actor_layout(3, 1, &[8, 8]).total());
        assert_eq!(&*snap.params, &l.final_params());
    }

    #[test]
    fn update_is_thread_count_invariant() {
        // batch 192 = 3 grains; published params must be bitwise equal
        // for L ∈ {1, 2, 4} (fixed grains + fixed-order tree reduction)
        let cfg = Td3Cfg {
            warmup_steps: 10,
            batch: 192,
            updates_per_iter: 4,
            policy_delay: 2,
            ..Default::default()
        };
        let run = |threads: usize| {
            let mut l =
                Td3Learner::with_topology(2, 1, &[16, 16], 1000, 11, 1, ReplayStrategy::Uniform,
                    threads);
            let mut rng = Pcg64::new(99);
            for _ in 0..300 {
                let o = [rng.normal(), rng.normal()];
                l.replay.push(&o, &[rng.uniform(-1.0, 1.0)], 1.0, &o, false);
            }
            l.update(&cfg).unwrap();
            l
        };
        let base = run(1);
        for threads in [2, 4] {
            let l = run(threads);
            for (name, a, b) in [
                ("actor", &base.state.actor, &l.state.actor),
                ("critic1", &base.state.critic1, &l.state.critic1),
                ("critic2", &base.state.critic2, &l.state.critic2),
            ] {
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{name} diverged at L={threads}"
                );
            }
        }
    }

    #[test]
    fn save_load_resumes_updates_bitwise() {
        let cfg = Td3Cfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 3,
            policy_delay: 2,
            ..Default::default()
        };
        let mut live = filled_learner(5);
        live.update(&cfg).unwrap();
        let blob = LearnerDriver::save_state(&live);

        // restored learner starts from a different seed; the blob must
        // carry everything, including replay contents + draw cursor
        let mut restored = Td3Learner::new(2, 1, &[16, 16], 1000, 123);
        LearnerDriver::load_state(&mut restored, &blob).unwrap();
        assert_eq!(restored.replay_len(), live.replay_len());
        live.update(&cfg).unwrap();
        restored.update(&cfg).unwrap();
        assert_eq!(live.state.actor, restored.state.actor);
        assert_eq!(live.state.critic1, restored.state.critic1);
        assert_eq!(live.state.critic2, restored.state.critic2);
        assert_eq!(live.update_count, restored.update_count);

        // wrong shape rejected
        let mut bad = Td3Learner::new(3, 2, &[8], 100, 0);
        assert!(LearnerDriver::load_state(&mut bad, &blob).is_err());
    }
}
