//! Generalized advantage estimation in Rust — the canonical ragged-length
//! implementation the learner uses (the L1 Pallas `gae_scan` artifact is
//! shape-specialized to the preset horizon; parity between the two is
//! checked in `rust/tests/runtime_roundtrip.rs`).
//!
//! Semantics (identical to kernels/ref.py):
//!     delta_t = r_t + gamma * cont_t * V_{t+1} - V_t
//!     adv_t   = delta_t + gamma * lam * cont_t * adv_{t+1}
//!     ret_t   = adv_t + V_t
//! `cont_t = 0` at true terminals (no bootstrap), `1` elsewhere — a
//! time-limit truncation keeps `cont = 1` and supplies V(s_T) as the
//! bootstrap value, which is exactly how the sampler labels chunks.

/// Compute GAE into caller-provided buffers.
/// rew: [T], val: [T+1] (bootstrap last), cont: [T]; adv/ret: [T] out.
pub fn gae_into(
    rew: &[f32],
    val: &[f32],
    cont: &[f32],
    gamma: f32,
    lam: f32,
    adv: &mut [f32],
    ret: &mut [f32],
) {
    let t_len = rew.len();
    assert_eq!(val.len(), t_len + 1, "val needs bootstrap entry");
    assert_eq!(cont.len(), t_len);
    assert_eq!(adv.len(), t_len);
    assert_eq!(ret.len(), t_len);
    let mut last = 0.0f32;
    for t in (0..t_len).rev() {
        let delta = rew[t] + gamma * cont[t] * val[t + 1] - val[t];
        last = delta + gamma * lam * cont[t] * last;
        adv[t] = last;
        ret[t] = last + val[t];
    }
}

/// Allocating convenience wrapper.
pub fn gae(rew: &[f32], val: &[f32], cont: &[f32], gamma: f32, lam: f32) -> (Vec<f32>, Vec<f32>) {
    let mut adv = vec![0.0; rew.len()];
    let mut ret = vec![0.0; rew.len()];
    gae_into(rew, val, cont, gamma, lam, &mut adv, &mut ret);
    (adv, ret)
}

/// Normalize advantages to zero mean / unit std in place (PPO trick).
pub fn normalize_advantages(adv: &mut [f32]) {
    if adv.is_empty() {
        return;
    }
    let n = adv.len() as f32;
    let mean = adv.iter().sum::<f32>() / n;
    let var = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-8);
    for a in adv.iter_mut() {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg64;

    #[test]
    fn single_step_episode() {
        // T=1, terminal: adv = r - V0, ret = r
        let (adv, ret) = gae(&[2.0], &[0.5, 99.0], &[0.0], 0.99, 0.95);
        assert!((adv[0] - 1.5).abs() < 1e-6);
        assert!((ret[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bootstrap_used_when_continuing() {
        // T=1, truncated (cont=1): delta = r + γ V1 - V0
        let (adv, _) = gae(&[1.0], &[0.0, 10.0], &[1.0], 0.9, 0.95);
        assert!((adv[0] - (1.0 + 0.9 * 10.0)).abs() < 1e-5);
    }

    #[test]
    fn lambda_zero_is_td_residual() {
        let rew = [1.0, -0.5, 0.25];
        let val = [0.1, 0.2, 0.3, 0.4];
        let cont = [1.0, 1.0, 1.0];
        let (adv, _) = gae(&rew, &val, &cont, 0.9, 0.0);
        for t in 0..3 {
            let delta = rew[t] + 0.9 * val[t + 1] - val[t];
            assert!((adv[t] - delta).abs() < 1e-6, "t={t}");
        }
    }

    #[test]
    fn lambda_one_is_discounted_return_minus_value() {
        // with λ=1 and cont=1: ret_t = Σ γ^k r_{t+k} + γ^{T-t} V_T
        let rew = [1.0f32, 2.0, 3.0];
        let val = [0.0f32, 0.0, 0.0, 4.0];
        let cont = [1.0f32, 1.0, 1.0];
        let g = 0.5f32;
        let (_, ret) = gae(&rew, &val, &cont, g, 1.0);
        let want0 = 1.0 + g * 2.0 + g * g * 3.0 + g * g * g * 4.0;
        assert!((ret[0] - want0).abs() < 1e-5, "{} vs {want0}", ret[0]);
    }

    #[test]
    fn terminal_cuts_credit_flow() {
        let rew = [0.0f32, 0.0, 100.0];
        let val = [0.0f32; 4];
        let cont = [1.0f32, 0.0, 1.0]; // terminal after step 1
        let (adv, _) = gae(&rew, &val, &cont, 0.99, 0.95);
        // step 0 must see nothing of the +100 beyond the terminal
        assert!(adv[0].abs() < 1e-5, "adv0={}", adv[0]);
    }

    #[test]
    fn matches_naive_quadratic_reference() {
        // O(T^2) direct sum: adv_t = Σ_k (γλ)^k Π_{j<k} cont · δ_{t+k}
        let mut rng = Pcg64::new(1);
        let t_len = 57;
        let rew: Vec<f32> = (0..t_len).map(|_| rng.normal()).collect();
        let val: Vec<f32> = (0..=t_len).map(|_| rng.normal()).collect();
        let cont: Vec<f32> = (0..t_len)
            .map(|_| if rng.next_f32() < 0.1 { 0.0 } else { 1.0 })
            .collect();
        let (gamma, lam) = (0.97f32, 0.9f32);
        let (adv, _) = gae(&rew, &val, &cont, gamma, lam);
        for t in 0..t_len {
            let mut want = 0.0f32;
            let mut w = 1.0f32;
            for k in t..t_len {
                let delta = rew[k] + gamma * cont[k] * val[k + 1] - val[k];
                want += w * delta;
                w *= gamma * lam * cont[k];
                if w == 0.0 {
                    break;
                }
            }
            assert!((adv[t] - want).abs() < 1e-3, "t={t}: {} vs {want}", adv[t]);
        }
    }

    #[test]
    fn normalize_gives_zero_mean_unit_std() {
        let mut adv: Vec<f32> = (0..100).map(|i| (i as f32) * 0.3 - 7.0).collect();
        normalize_advantages(&mut adv);
        let mean: f32 = adv.iter().sum::<f32>() / 100.0;
        let var: f32 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / 100.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-4);
    }

    /// Property: GAE is linear in rewards (for fixed val/cont).
    #[test]
    fn property_linear_in_rewards() {
        struct G;
        impl Gen for G {
            type Value = (Vec<f32>, Vec<f32>, u64);
            fn generate(&self, rng: &mut Pcg64) -> Self::Value {
                let t = 1 + rng.below(40);
                let r1: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
                let r2: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
                (r1, r2, rng.next_u64())
            }
        }
        check(7, 100, &G, |(r1, r2, seed)| {
            let t = r1.len();
            let mut rng = Pcg64::new(*seed);
            let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
            let cont: Vec<f32> = (0..t)
                .map(|_| if rng.next_f32() < 0.2 { 0.0 } else { 1.0 })
                .collect();
            let (a1, _) = gae(r1, &val, &cont, 0.99, 0.95);
            let zero_val = vec![0.0; t + 1];
            let (a2, _) = gae(r2, &zero_val, &cont, 0.99, 0.95);
            let sum: Vec<f32> = r1.iter().zip(r2).map(|(a, b)| a + b).collect();
            let (a12, _) = gae(&sum, &val, &cont, 0.99, 0.95);
            a12.iter()
                .zip(a1.iter().zip(&a2))
                .all(|(s, (x, y))| (s - (x + y)).abs() < 1e-3)
        });
    }
}
