//! SAC — Soft Actor-Critic (Haarnoja et al., 2018) — the second
//! algorithm landed **entirely against the [`Algorithm`] trait** (after
//! TD3): zero edits to `coordinator/sampler.rs`,
//! `coordinator/orchestrator.rs`, or `runtime/inference_server.rs`. Its
//! registration points are the `config::Algo::Sac` variant, the
//! `algo::api::algorithm_from_config` match arm, and two
//! `runtime::BackendFactory` hooks (`make_sac_actor` /
//! `init_sac_params`) that only the native backend implements.
//!
//! SAC is maximum-entropy off-policy RL:
//! 1. **Stochastic tanh-Gaussian actor** — the policy head emits per-dim
//!    `(mean, log_std)`; actions are reparameterized samples
//!    `a = tanh(mean + std * eps)`, so the sampler's policy-noise lane
//!    carries eps ~ N(0,1) exactly like PPO's (and a zero lane is the
//!    squashed mode, which is what eval runs).
//! 2. **Twin soft critics** — TD3's twin trick plus an entropy bonus in
//!    the target: `y = r + γ(1-d)(min(Q1',Q2')(s',a') - α·logπ(a'|s'))`
//!    with `a'` drawn from the *current* actor (SAC has no target actor).
//! 3. **Learned temperature** — `α = exp(log_α)` follows plain SGD
//!    toward the entropy target `H̄ = -act_dim`.
//!
//! Replay runs on the sharded buffer ([`crate::replay::shard`]) with the
//! seed-addressable [`ReplayRng`], so `--replay-shards` applies; the
//! update math is native-only and single-threaded for now
//! (`TrainConfig::validate` rejects `--backend xla`, `--learner-threads
//! > 1`, and `--replay-strategy prioritized` with actionable errors).

use crate::algo::api::{AlgoSampler, Algorithm, LearnerDriver, TickLanes};
use crate::algo::normalizer::{NormSnapshot, RunningNorm};
use crate::algo::rollout::{ChunkBuf, ChunkEnd, ExperienceChunk};
use crate::algo::td3::polyak;
use crate::config::{Algo, ReplayStrategy, SacCfg, TrainConfig};
use crate::coordinator::metrics::IterationMetrics;
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::SamplerCfg;
use crate::nn::adam::{Adam, AdamCfg};
use crate::nn::layout::{actor_layout, critic_layout, ParamLayout};
use crate::nn::mlp::{self, NetShape};
use crate::nn::tensor::Mat;
use crate::replay::shard::{ReplayRng, ShardSample, ShardedReplay};
use crate::runtime::{ActorBackend, BackendFactory, ServerActor, StochasticServerActor};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Stream-id base for SAC reparameterization-noise RNGs (disjoint from
/// PPO's `1 << 32`, DDPG's `1 << 33`, TD3's `1 << 34`, and the replay
/// draw family at `1 << 36`).
const SAC_NOISE_STREAM_BASE: u64 = 1 << 35;

/// RNG stream id of the learner (next-action + actor eps draws).
const SAC_LEARNER_STREAM: u64 = 0x5AC;

/// SAC's [`Algorithm`] registration.
#[derive(Debug, Clone, Default)]
pub struct Sac {
    pub cfg: SacCfg,
}

impl Algorithm for Sac {
    fn id(&self) -> Algo {
        Algo::Sac
    }

    fn make_sampler(&self, scfg: &SamplerCfg, m: usize, act_dim: usize) -> Box<dyn AlgoSampler> {
        Box::new(SacSampler {
            act_dim,
            rngs: (0..m)
                .map(|i| {
                    Pcg64::with_stream(scfg.seed, SAC_NOISE_STREAM_BASE + scfg.global_env(m, i))
                })
                .collect(),
        })
    }

    fn make_local_actor(
        &self,
        factory: &dyn BackendFactory,
        rows: usize,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        factory.make_sac_actor(rows)
    }

    fn make_server_actor(
        &self,
        factory: &dyn BackendFactory,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn ServerActor>> {
        // stochastic policy: the server forwards the workers' eps lanes
        Ok(Box::new(StochasticServerActor(
            factory.make_sac_actor(max_rows)?,
        )))
    }

    fn make_eval_actor(
        &self,
        factory: &dyn BackendFactory,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        // zero noise at eval makes action == squashed mode
        factory.make_sac_actor(1)
    }

    fn make_learner(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> anyhow::Result<Box<dyn LearnerDriver>> {
        let (actor, critic1, critic2) = factory.init_sac_params(cfg.seed)?;
        Ok(Box::new(SacLearner::with_params(
            actor,
            critic1,
            critic2,
            factory.obs_dim(),
            factory.act_dim(),
            &cfg.hidden,
            cfg.sac.replay_capacity,
            cfg.replay_shards,
            cfg.seed,
        )))
    }

    fn policy_param_count(&self, factory: &dyn BackendFactory, cfg: &TrainConfig) -> usize {
        // the published policy is the actor with its 2*act_dim head
        actor_layout(factory.obs_dim(), 2 * factory.act_dim(), &cfg.hidden).total()
    }

    fn hyperparams(&self, cfg: &TrainConfig) -> Json {
        cfg.sac.to_json()
    }

    fn apply_to(&self, cfg: &mut TrainConfig) {
        cfg.algo = Algo::Sac;
        cfg.sac = self.cfg.clone();
    }
}

/// Sampler hooks: per-env reparameterization-noise streams feeding the
/// policy-noise lane (the actor squashes, so exploration is intrinsic —
/// no additive noise), executed actions recorded for replay, and the
/// trailing normalized s' row every off-policy chunk carries.
pub struct SacSampler {
    act_dim: usize,
    rngs: Vec<Pcg64>,
}

impl AlgoSampler for SacSampler {
    fn uses_policy_noise(&self) -> bool {
        true
    }

    fn fill_policy_noise(&mut self, noise: &mut [f32]) {
        let a = self.act_dim;
        for (i, rng) in self.rngs.iter_mut().enumerate() {
            rng.fill_normal(&mut noise[i * a..(i + 1) * a]);
        }
    }

    fn record_tick(
        &mut self,
        i: usize,
        lanes: &TickLanes<'_>,
        buf: &mut ChunkBuf,
        exec: &mut [f32],
    ) {
        let a = self.act_dim;
        exec.copy_from_slice(&lanes.action[i * a..(i + 1) * a]);
        crate::env::clip_action(exec); // tanh output: clip is a no-op guard
        // replay stores the EXECUTED action; the learner recomputes logp
        // from fresh eps draws, so the aux lanes stay zero like DDPG/TD3
        buf.act.extend_from_slice(exec);
        buf.logp.push(0.0);
        buf.value.push(0.0);
    }

    fn close_chunk(
        &mut self,
        buf: &mut ChunkBuf,
        next_obs: &[f32],
        norm: &NormSnapshot,
        _end: ChunkEnd,
        _value_hint: f32,
    ) -> f32 {
        // replay reconstruction needs s' of the last row: append the
        // next obs normalized under the chunk's snapshot (len+1 rows)
        let start = buf.obs.len();
        buf.obs.extend_from_slice(next_obs);
        norm.apply(&mut buf.obs[start..]);
        0.0
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_usize(self.rngs.len());
        for rng in &self.rngs {
            let (state, inc) = rng.raw_state();
            w.put_u128(state);
            w.put_u128(inc);
        }
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let n = r.read_usize()?;
        anyhow::ensure!(
            n == self.rngs.len(),
            "sac sampler state has {n} rng lanes, expected {}",
            self.rngs.len()
        );
        for rng in self.rngs.iter_mut() {
            let state = r.read_u128()?;
            let inc = r.read_u128()?;
            *rng = Pcg64::from_raw(state, inc);
        }
        Ok(())
    }
}

/// Aggregated statistics for one SAC update round.
#[derive(Debug, Clone, Copy, Default)]
pub struct SacUpdateStats {
    /// Mean twin-critic TD loss (both critics averaged).
    pub q_loss: f32,
    /// Mean actor (policy) loss.
    pub pi_loss: f32,
    /// Temperature after the round.
    pub alpha: f32,
    /// Mean policy entropy estimate `-E[log pi]` over the round.
    pub entropy: f32,
    /// Updates performed.
    pub updates: usize,
}

/// SAC learner: sharded replay collection identical to DDPG/TD3's (the
/// chunks carry a trailing s' row), with the twin-soft-critic /
/// reparameterized-actor / learned-temperature update on the native
/// kernels.
pub struct SacLearner {
    pub actor: Vec<f32>,
    pub critic1: Vec<f32>,
    pub critic2: Vec<f32>,
    pub targ_critic1: Vec<f32>,
    pub targ_critic2: Vec<f32>,
    a_adam: Adam,
    c1_adam: Adam,
    c2_adam: Adam,
    /// Temperature, parameterized as log(alpha) so it stays positive.
    log_alpha: f32,
    target_entropy: f32,
    replay: ShardedReplay,
    replay_rng: ReplayRng,
    norm: RunningNorm,
    /// Learner eps stream (next-action draws, then actor draws, per
    /// update — a fixed consumption order, so runs are seed-reproducible).
    rng: Pcg64,
    total_steps: u64,
    wall: Stopwatch,
    obs_dim: usize,
    act_dim: usize,
    alayout: ParamLayout,
    clayout: ParamLayout,
    shape: NetShape,
}

impl SacLearner {
    /// Convenience constructor drawing fresh parameters (one init stream,
    /// three draws: actor, critic1, critic2 — matching
    /// `NativeFactory::init_sac_params`). Single replay shard.
    pub fn new(
        obs_dim: usize,
        act_dim: usize,
        hidden: &[usize],
        replay_capacity: usize,
        seed: u64,
    ) -> SacLearner {
        let mut init = Pcg64::new(seed);
        let actor = actor_layout(obs_dim, 2 * act_dim, hidden).init_flat(&mut init);
        let critic1 = critic_layout(obs_dim, act_dim, hidden).init_flat(&mut init);
        let critic2 = critic_layout(obs_dim, act_dim, hidden).init_flat(&mut init);
        Self::with_params(
            actor,
            critic1,
            critic2,
            obs_dim,
            act_dim,
            hidden,
            replay_capacity,
            1,
            seed,
        )
    }

    /// Full constructor over pre-initialized parameters (the
    /// `Algorithm::make_learner` path, which draws them through
    /// `BackendFactory::init_sac_params`).
    #[allow(clippy::too_many_arguments)]
    pub fn with_params(
        actor: Vec<f32>,
        critic1: Vec<f32>,
        critic2: Vec<f32>,
        obs_dim: usize,
        act_dim: usize,
        hidden: &[usize],
        replay_capacity: usize,
        replay_shards: usize,
        seed: u64,
    ) -> SacLearner {
        let (pa, pc) = (actor.len(), critic1.len());
        debug_assert_eq!(critic1.len(), critic2.len());
        SacLearner {
            targ_critic1: critic1.clone(),
            targ_critic2: critic2.clone(),
            actor,
            critic1,
            critic2,
            a_adam: Adam::new(pa, AdamCfg::default()),
            c1_adam: Adam::new(pc, AdamCfg::default()),
            c2_adam: Adam::new(pc, AdamCfg::default()),
            log_alpha: 0.0, // overwritten from cfg at the first update
            target_entropy: -(act_dim as f32),
            replay: ShardedReplay::new(
                replay_capacity,
                obs_dim,
                act_dim,
                replay_shards,
                ReplayStrategy::Uniform,
            ),
            replay_rng: ReplayRng::new(seed),
            norm: RunningNorm::new(obs_dim, 10.0),
            rng: Pcg64::with_stream(seed, SAC_LEARNER_STREAM),
            total_steps: 0,
            wall: Stopwatch::start(),
            obs_dim,
            act_dim,
            alayout: actor_layout(obs_dim, 2 * act_dim, hidden),
            clayout: critic_layout(obs_dim, act_dim, hidden),
            shape: NetShape::new(obs_dim, act_dim, hidden),
        }
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Current temperature.
    pub fn alpha(&self) -> f32 {
        self.log_alpha.exp()
    }

    /// Insert a chunk's transitions (chunk.obs has len+1 rows; the
    /// trailing row is s' of the final transition — the same off-policy
    /// chunk contract DDPG/TD3 use).
    fn absorb_chunk(&mut self, c: &ExperienceChunk) {
        let o = self.obs_dim;
        let a = self.act_dim;
        let len = c.len();
        debug_assert_eq!(c.obs.len(), (len + 1) * o, "sac chunk missing next-obs row");
        for i in 0..len {
            let obs = &c.obs[i * o..(i + 1) * o];
            let next = &c.obs[(i + 1) * o..(i + 2) * o];
            let act = &c.act[i * a..(i + 1) * a];
            let done = c.end == ChunkEnd::Terminal && i == len - 1;
            self.replay.push(obs, act, c.rew[i], next, done);
        }
        if let Some(stats) = &c.obs_stats {
            self.norm.merge(stats);
        }
    }

    /// One-time latch: adopt the configured initial temperature before
    /// the first gradient step (`log_alpha` can't be set at construction
    /// because the learner is built from dims + seed, not a `SacCfg`).
    fn latch_alpha(&mut self, cfg: &SacCfg) {
        if self.total_alpha_updates() == 0 {
            self.log_alpha = cfg.init_alpha.ln();
        }
    }

    fn total_alpha_updates(&self) -> u64 {
        self.a_adam.t
    }

    /// Run `cfg.updates_per_iter` soft actor-critic updates sampling from
    /// the replay buffer. No-op while the buffer is below warmup.
    pub fn update(&mut self, cfg: &SacCfg) -> anyhow::Result<SacUpdateStats> {
        if self.replay.len() < cfg.warmup_steps.max(cfg.batch) {
            return Ok(SacUpdateStats {
                alpha: self.alpha(),
                ..Default::default()
            });
        }
        self.latch_alpha(cfg);
        let b = cfg.batch;
        let (o, a) = (self.obs_dim, self.act_dim);
        let inv_n = 1.0 / b as f32;
        let mut sample = ShardSample::default();
        let mut eps = vec![0.0f32; b * a];
        let mut agg = SacUpdateStats::default();
        for _ in 0..cfg.updates_per_iter {
            self.replay.sample_into(b, &mut self.replay_rng, &mut sample);
            let alpha = self.log_alpha.exp();

            // --- soft TD target:
            //     y = r + γ(1-d)(min(Q1',Q2')(s',a') - α logπ(a'|s')),
            //     a' ~ π(·|s') from the CURRENT actor (no target actor)
            self.rng.fill_normal(&mut eps);
            let next_obs = Mat::from_vec(b, o, sample.next_obs.clone());
            let next = mlp::sac_act(&self.alayout, &self.actor, &self.shape, &next_obs, &eps);
            let q1n = mlp::ddpg_critic(
                &self.clayout,
                &self.targ_critic1,
                &self.shape,
                &next_obs,
                &next.action,
            );
            let q2n = mlp::ddpg_critic(
                &self.clayout,
                &self.targ_critic2,
                &self.shape,
                &next_obs,
                &next.action,
            );
            let target: Vec<f32> = (0..b)
                .map(|i| {
                    sample.rew[i]
                        + cfg.gamma
                            * (1.0 - sample.done[i])
                            * (q1n[i].min(q2n[i]) - alpha * next.logp[i])
                })
                .collect();

            // --- twin soft critic regression steps (shared target)
            let obs = Mat::from_vec(b, o, sample.obs.clone());
            let act = Mat::from_vec(b, a, sample.act.clone());
            let (g1, l1) = mlp::ddpg_critic_grad(
                &self.clayout,
                &self.critic1,
                &self.shape,
                &obs,
                &act,
                &target,
            );
            self.c1_adam.step(&mut self.critic1, &g1, cfg.lr_critic);
            let (g2, l2) = mlp::ddpg_critic_grad(
                &self.clayout,
                &self.critic2,
                &self.shape,
                &obs,
                &act,
                &target,
            );
            self.c2_adam.step(&mut self.critic2, &g2, cfg.lr_critic);

            // --- reparameterized actor step through the UPDATED critics
            self.rng.fill_normal(&mut eps);
            let (ga, pi_loss, logp_sum) = mlp::sac_actor_grad(
                &self.alayout,
                &self.actor,
                &self.clayout,
                &self.critic1,
                &self.critic2,
                &self.shape,
                &obs,
                &eps,
                alpha,
                inv_n,
            );
            self.a_adam.step(&mut self.actor, &ga, cfg.lr_actor);

            // --- temperature: SGD on log α; the α objective
            //     J(α) = -α (E[logπ] + H̄) has dJ/dα = -(E[logπ] + H̄)
            let mean_logp = logp_sum * inv_n;
            self.log_alpha -= cfg.lr_alpha * (-(mean_logp + self.target_entropy));

            // --- Polyak soft target updates (critics only)
            polyak(&mut self.targ_critic1, &self.critic1, cfg.tau);
            polyak(&mut self.targ_critic2, &self.critic2, cfg.tau);

            agg.q_loss += 0.5 * (l1 + l2);
            agg.pi_loss += pi_loss;
            agg.entropy += -mean_logp;
            agg.updates += 1;
        }
        if agg.updates > 0 {
            agg.q_loss /= agg.updates as f32;
            agg.pi_loss /= agg.updates as f32;
            agg.entropy /= agg.updates as f32;
        }
        agg.alpha = self.alpha();
        Ok(agg)
    }
}

impl LearnerDriver for SacLearner {
    fn publish_initial(&self, store: &PolicyStore) {
        store.publish(self.actor.clone(), self.norm.snapshot());
    }

    fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics> {
        let iter_sw = Stopwatch::start();
        let collect_sw = Stopwatch::start();
        let mut n = 0usize;
        let mut returns: Vec<f32> = Vec::new();
        let mut lengths: Vec<usize> = Vec::new();
        let mut busy_per_worker: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        let mut chunks: Vec<ExperienceChunk> = Vec::new();
        while n < cfg.samples_per_iter {
            let c = queue
                .pop()
                .map_err(|_| anyhow::anyhow!("experience queue closed"))?;
            n += c.len();
            returns.extend_from_slice(&c.episode_returns);
            lengths.extend_from_slice(&c.episode_lengths);
            *busy_per_worker.entry(c.sampler_id).or_default() += c.busy_secs;
            chunks.push(c);
        }
        // canonical order before replay insertion + normalizer merges:
        // the learner's state must be a pure function of the chunk set
        chunks.sort_by_key(|c| (c.policy_version, c.sampler_id, c.env_slot));
        for c in &chunks {
            self.absorb_chunk(c);
        }
        let collect_secs = collect_sw.elapsed_secs();
        let virtual_collect_secs = busy_per_worker.values().fold(0.0f64, |a, &b| a.max(b));

        let learn_sw = Stopwatch::start();
        let stats = self.update(&cfg.sac)?;
        let learn_secs = learn_sw.elapsed_secs();

        store.publish(self.actor.clone(), self.norm.snapshot());
        self.total_steps += n as u64;

        let mean_ep_len = if lengths.is_empty() {
            f32::NAN
        } else {
            lengths.iter().sum::<usize>() as f32 / lengths.len() as f32
        };
        Ok(IterationMetrics {
            iter,
            samples: n,
            collect_secs,
            virtual_collect_secs,
            learn_secs,
            total_secs: iter_sw.elapsed_secs(),
            mean_return: crate::util::stats::mean_f32(&returns),
            episodes: returns.len(),
            mean_ep_len,
            total_steps: self.total_steps,
            wall_secs: self.wall.elapsed_secs(),
            pi_loss: stats.pi_loss,
            v_loss: stats.q_loss,
            entropy: stats.entropy,
            ..Default::default()
        })
    }

    fn final_params(&self) -> Vec<f32> {
        self.actor.clone()
    }

    fn final_norm(&self) -> NormSnapshot {
        self.norm.snapshot()
    }

    /// Full off-policy training state INCLUDING replay contents (the
    /// versioned shard section) and the replay draw cursor, so a resumed
    /// run replays bitwise-identical minibatches.
    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.actor);
        w.put_f32s(&self.critic1);
        w.put_f32s(&self.critic2);
        w.put_f32s(&self.targ_critic1);
        w.put_f32s(&self.targ_critic2);
        for adam in [&self.a_adam, &self.c1_adam, &self.c2_adam] {
            w.put_f32s(&adam.m);
            w.put_f32s(&adam.v);
            w.put_u64(adam.t);
        }
        w.put_f32(self.log_alpha);
        let (rs, ri) = self.rng.raw_state();
        w.put_u128(rs);
        w.put_u128(ri);
        self.norm.save_state(&mut w);
        w.put_u64(self.total_steps);
        self.replay.save_state(&mut w);
        self.replay_rng.save_state(&mut w);
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let actor = r.read_f32s()?;
        anyhow::ensure!(
            actor.len() == self.actor.len(),
            "SAC learner state mismatch: snapshot has {} actor params, this run has {}",
            actor.len(),
            self.actor.len()
        );
        self.actor = actor;
        self.critic1 = r.read_f32s()?;
        self.critic2 = r.read_f32s()?;
        self.targ_critic1 = r.read_f32s()?;
        self.targ_critic2 = r.read_f32s()?;
        for adam in [&mut self.a_adam, &mut self.c1_adam, &mut self.c2_adam] {
            adam.m = r.read_f32s()?;
            adam.v = r.read_f32s()?;
            adam.t = r.read_u64()?;
        }
        self.log_alpha = r.read_f32()?;
        let (rs, ri) = (r.read_u128()?, r.read_u128()?);
        self.rng = Pcg64::from_raw(rs, ri);
        self.norm = RunningNorm::load_state(&mut r)?;
        self.total_steps = r.read_u64()?;
        self.replay.load_state(&mut r)?;
        self.replay_rng = ReplayRng::load_state(&mut r)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_learner(seed: u64) -> SacLearner {
        let l = SacLearner::new(2, 1, &[16, 16], 1000, seed);
        let mut rng = Pcg64::new(99);
        for _ in 0..300 {
            let o = [rng.normal(), rng.normal()];
            l.replay.push(&o, &[rng.uniform(-1.0, 1.0)], 1.0, &o, false);
        }
        l
    }

    #[test]
    fn update_noop_before_warmup() {
        let cfg = SacCfg {
            warmup_steps: 1000,
            batch: 8,
            updates_per_iter: 5,
            ..Default::default()
        };
        let mut l = filled_learner(0);
        let before = l.actor.clone();
        let stats = l.update(&cfg).unwrap();
        assert_eq!(stats.updates, 0);
        assert_eq!(l.actor, before);
    }

    #[test]
    fn twin_soft_critics_learn_q_and_stay_distinct() {
        // gamma = 0 kills both the bootstrap AND the entropy term in the
        // target, so y is exactly the reward; lr_actor/lr_alpha = 0
        // isolate critic learning
        let cfg = SacCfg {
            warmup_steps: 10,
            batch: 16,
            updates_per_iter: 50,
            lr_actor: 0.0,
            lr_alpha: 0.0,
            lr_critic: 1e-2,
            gamma: 0.0,
            ..Default::default()
        };
        let mut l = filled_learner(1);
        assert_ne!(
            l.critic1, l.critic2,
            "twin critics must be independently initialized"
        );
        let first = l.update(&cfg).unwrap();
        let second = l.update(&cfg).unwrap();
        assert_eq!(first.updates, 50);
        assert!(
            second.q_loss < 0.5 * first.q_loss.max(1e-6) + 0.05,
            "q_loss did not drop: {} -> {}",
            first.q_loss,
            second.q_loss
        );
        assert_ne!(l.critic1, l.critic2, "twins must not collapse");
    }

    #[test]
    fn seeded_updates_are_reproducible() {
        let cfg = SacCfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 5,
            ..Default::default()
        };
        let mut a = filled_learner(7);
        let mut b = filled_learner(7);
        a.update(&cfg).unwrap();
        b.update(&cfg).unwrap();
        assert_eq!(a.actor, b.actor);
        assert_eq!(a.critic1, b.critic1);
        assert_eq!(a.critic2, b.critic2);
        assert_eq!(a.log_alpha.to_bits(), b.log_alpha.to_bits());
    }

    #[test]
    fn temperature_adapts_from_its_configured_start() {
        let cfg = SacCfg {
            warmup_steps: 10,
            batch: 16,
            updates_per_iter: 20,
            init_alpha: 0.5,
            lr_alpha: 1e-2,
            ..Default::default()
        };
        let mut l = filled_learner(3);
        assert_eq!(l.alpha(), 1.0, "pre-latch placeholder");
        let stats = l.update(&cfg).unwrap();
        assert!(stats.alpha > 0.0 && stats.alpha.is_finite());
        assert_ne!(
            l.log_alpha,
            0.5f32.ln(),
            "learned temperature must move off init_alpha"
        );
        assert!(stats.entropy.is_finite());
    }

    #[test]
    fn save_load_resumes_updates_bitwise() {
        let cfg = SacCfg {
            warmup_steps: 10,
            batch: 8,
            updates_per_iter: 3,
            ..Default::default()
        };
        let mut live = filled_learner(5);
        live.update(&cfg).unwrap();
        let blob = LearnerDriver::save_state(&live);

        let mut restored = SacLearner::new(2, 1, &[16, 16], 1000, 123);
        LearnerDriver::load_state(&mut restored, &blob).unwrap();
        assert_eq!(restored.replay_len(), live.replay_len());
        live.update(&cfg).unwrap();
        restored.update(&cfg).unwrap();
        assert_eq!(live.actor, restored.actor, "post-resume update diverged");
        assert_eq!(live.critic1, restored.critic1);
        assert_eq!(live.log_alpha.to_bits(), restored.log_alpha.to_bits());

        // wrong shape rejected
        let mut bad = SacLearner::new(3, 2, &[8], 100, 0);
        assert!(LearnerDriver::load_state(&mut bad, &blob).is_err());
    }

    #[test]
    fn publish_initial_exposes_actor_params() {
        let l = SacLearner::new(3, 1, &[8, 8], 100, 5);
        let store = PolicyStore::new();
        LearnerDriver::publish_initial(&l, &store);
        let snap = store.latest().unwrap();
        assert_eq!(snap.version, 1);
        // the SAC head is 2 * act_dim wide (mean ++ log_std)
        assert_eq!(snap.params.len(), actor_layout(3, 2, &[8, 8]).total());
        assert_eq!(&*snap.params, &l.final_params());
    }
}
