//! Rollout data structures: experience chunks flowing sampler → learner,
//! and the flat dataset the PPO learner assembles per iteration.
//!
//! A sampler pushes [`ExperienceChunk`]s — contiguous runs of transitions
//! from ONE environment under ONE policy version. A chunk ends either at
//! an episode boundary (`terminal`), the episode cap (`truncated`), or the
//! configured chunk length (neither — continuation; `bootstrap_value`
//! carries V(s_next) so GAE can bootstrap across the cut).

/// Why a chunk ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkEnd {
    /// True terminal state (env returned done): no bootstrap.
    Terminal,
    /// Episode hit the time-limit cap: bootstrap with V(s_next).
    Truncated,
    /// Chunk length reached mid-episode: bootstrap with V(s_next).
    Continuation,
}

/// A contiguous run of transitions from one sampler.
#[derive(Debug, Clone)]
pub struct ExperienceChunk {
    pub sampler_id: usize,
    /// Which env slot of the (vectorized) sampler produced this chunk:
    /// `0..envs_per_sampler`. Chunks are per-env, so GAE segments never
    /// mix transitions from different envs.
    pub env_slot: usize,
    /// Policy version that generated this chunk (staleness tracking).
    pub policy_version: u64,
    /// Row-major [len * obs_dim].
    pub obs: Vec<f32>,
    /// Row-major [len * act_dim].
    pub act: Vec<f32>,
    pub rew: Vec<f32>,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub end: ChunkEnd,
    /// V(s_next) at the cut point (0.0 for Terminal).
    pub bootstrap_value: f32,
    /// Episode returns completed inside this chunk (for logging).
    pub episode_returns: Vec<f32>,
    /// Episode lengths matching `episode_returns`.
    pub episode_lengths: Vec<usize>,
    /// Welford statistics of the *raw* observations in this chunk; the
    /// learner merges these into the master normalizer so that obs
    /// normalization improves without shipping raw observations twice.
    pub obs_stats: Option<crate::algo::normalizer::RunningNorm>,
    /// CPU *busy* seconds this worker spent producing the chunk (env
    /// stepping + policy inference, excluding queue blocking and policy
    /// waits). Feeds the virtual-core timing model (DESIGN.md §3): on an
    /// N-core testbed the iteration's rollout time is max-over-workers of
    /// their busy time; measuring busy time directly lets a single-core
    /// CI box reproduce the paper's multi-core Figs 4-7 faithfully.
    pub busy_secs: f64,
}

impl ExperienceChunk {
    pub fn len(&self) -> usize {
        self.rew.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rew.is_empty()
    }

    /// GAE continuation mask: 1 everywhere except a 0 at the last step of
    /// a Terminal chunk.
    pub fn cont_mask(&self) -> Vec<f32> {
        let mut cont = vec![1.0; self.len()];
        if self.end == ChunkEnd::Terminal {
            if let Some(last) = cont.last_mut() {
                *last = 0.0;
            }
        }
        cont
    }

    /// Value sequence extended with the bootstrap entry (len + 1).
    pub fn values_with_bootstrap(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.len() + 1);
        v.extend_from_slice(&self.value);
        v.push(match self.end {
            ChunkEnd::Terminal => 0.0,
            _ => self.bootstrap_value,
        });
        v
    }
}

/// Buffers for an in-progress chunk (one per env slot, reused by the
/// sampler loop; algorithm hooks — `algo::api::AlgoSampler` — append the
/// per-tick lanes and close chunks through it).
pub struct ChunkBuf {
    /// Row-major normalized observation rows. DDPG-style algorithms
    /// append one trailing s' row at chunk close (the learner splits it).
    pub obs: Vec<f32>,
    /// Row-major action rows (pre-clip for PPO so `logp` matches; the
    /// executed clipped action for deterministic-policy algorithms).
    pub act: Vec<f32>,
    pub rew: Vec<f32>,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub episode_returns: Vec<f32>,
    pub episode_lengths: Vec<usize>,
    /// Raw-obs Welford stats shipped to the learner's master normalizer.
    pub stats: crate::algo::normalizer::RunningNorm,
    /// Busy seconds accumulated for the current chunk (work only).
    pub busy_secs: f64,
}

impl ChunkBuf {
    pub fn new(obs_dim: usize) -> Self {
        Self {
            obs: Vec::new(),
            act: Vec::new(),
            rew: Vec::new(),
            logp: Vec::new(),
            value: Vec::new(),
            episode_returns: Vec::new(),
            episode_lengths: Vec::new(),
            stats: crate::algo::normalizer::RunningNorm::new(obs_dim, 10.0),
            busy_secs: 0.0,
        }
    }

    /// Transitions buffered so far.
    pub fn len(&self) -> usize {
        self.rew.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rew.is_empty()
    }

    /// Drain the buffers into an [`ExperienceChunk`], resetting this
    /// buffer for the next chunk.
    pub fn take(
        &mut self,
        id: usize,
        env_slot: usize,
        version: u64,
        end: ChunkEnd,
        bootstrap: f32,
    ) -> ExperienceChunk {
        let dim = self.stats.dim();
        ExperienceChunk {
            sampler_id: id,
            env_slot,
            policy_version: version,
            obs: std::mem::take(&mut self.obs),
            act: std::mem::take(&mut self.act),
            rew: std::mem::take(&mut self.rew),
            logp: std::mem::take(&mut self.logp),
            value: std::mem::take(&mut self.value),
            end,
            bootstrap_value: bootstrap,
            episode_returns: std::mem::take(&mut self.episode_returns),
            episode_lengths: std::mem::take(&mut self.episode_lengths),
            obs_stats: Some(std::mem::replace(
                &mut self.stats,
                crate::algo::normalizer::RunningNorm::new(dim, 10.0),
            )),
            busy_secs: std::mem::take(&mut self.busy_secs),
        }
    }
}

/// Flat PPO dataset for one iteration (all chunks concatenated, with
/// advantages/returns already computed).
#[derive(Debug, Clone, Default)]
pub struct PpoDataset {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub old_logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub ret: Vec<f32>,
    pub n: usize,
}

impl PpoDataset {
    /// Assemble from chunks, computing GAE per chunk via `gae_fn`
    /// (the backend's GAE — Pallas artifact or native).
    pub fn assemble(
        chunks: &[ExperienceChunk],
        obs_dim: usize,
        act_dim: usize,
        mut gae_fn: impl FnMut(&[f32], &[f32], &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>,
    ) -> anyhow::Result<PpoDataset> {
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        let mut ds = PpoDataset {
            obs_dim,
            act_dim,
            obs: Vec::with_capacity(total * obs_dim),
            act: Vec::with_capacity(total * act_dim),
            old_logp: Vec::with_capacity(total),
            adv: Vec::with_capacity(total),
            ret: Vec::with_capacity(total),
            n: total,
        };
        for c in chunks {
            debug_assert_eq!(c.obs.len(), c.len() * obs_dim);
            debug_assert_eq!(c.act.len(), c.len() * act_dim);
            let val = c.values_with_bootstrap();
            let cont = c.cont_mask();
            let (adv, ret) = gae_fn(&c.rew, &val, &cont)?;
            ds.obs.extend_from_slice(&c.obs);
            ds.act.extend_from_slice(&c.act);
            ds.old_logp.extend_from_slice(&c.logp);
            ds.adv.extend_from_slice(&adv);
            ds.ret.extend_from_slice(&ret);
        }
        Ok(ds)
    }

    /// Gather rows by index into padded minibatch buffers; rows past
    /// `idx.len()` are zero with mask 0.
    pub fn gather_padded(
        &self,
        idx: &[usize],
        padded_rows: usize,
        obs: &mut Vec<f32>,
        act: &mut Vec<f32>,
        old_logp: &mut Vec<f32>,
        adv: &mut Vec<f32>,
        ret: &mut Vec<f32>,
        mask: &mut Vec<f32>,
    ) {
        let (o, a) = (self.obs_dim, self.act_dim);
        obs.clear();
        obs.resize(padded_rows * o, 0.0);
        act.clear();
        act.resize(padded_rows * a, 0.0);
        old_logp.clear();
        old_logp.resize(padded_rows, 0.0);
        adv.clear();
        adv.resize(padded_rows, 0.0);
        ret.clear();
        ret.resize(padded_rows, 0.0);
        mask.clear();
        mask.resize(padded_rows, 0.0);
        for (row, &i) in idx.iter().enumerate() {
            obs[row * o..(row + 1) * o].copy_from_slice(&self.obs[i * o..(i + 1) * o]);
            act[row * a..(row + 1) * a].copy_from_slice(&self.act[i * a..(i + 1) * a]);
            old_logp[row] = self.old_logp[i];
            adv[row] = self.adv[i];
            ret[row] = self.ret[i];
            mask[row] = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gae::gae;

    fn chunk(len: usize, end: ChunkEnd, bootstrap: f32) -> ExperienceChunk {
        ExperienceChunk {
            sampler_id: 0,
            env_slot: 0,
            policy_version: 1,
            obs: (0..len * 2).map(|i| i as f32).collect(),
            act: (0..len).map(|i| -(i as f32)).collect(),
            rew: vec![1.0; len],
            logp: vec![-0.5; len],
            value: vec![0.2; len],
            end,
            bootstrap_value: bootstrap,
            episode_returns: vec![],
            episode_lengths: vec![],
            obs_stats: None,
            busy_secs: 0.0,
        }
    }

    #[test]
    fn cont_mask_zero_only_for_terminal() {
        let c = chunk(4, ChunkEnd::Terminal, 0.0);
        assert_eq!(c.cont_mask(), vec![1.0, 1.0, 1.0, 0.0]);
        let c = chunk(4, ChunkEnd::Truncated, 0.7);
        assert_eq!(c.cont_mask(), vec![1.0; 4]);
        let c = chunk(4, ChunkEnd::Continuation, 0.7);
        assert_eq!(c.cont_mask(), vec![1.0; 4]);
    }

    #[test]
    fn bootstrap_value_respected() {
        let c = chunk(3, ChunkEnd::Truncated, 9.0);
        assert_eq!(c.values_with_bootstrap(), vec![0.2, 0.2, 0.2, 9.0]);
        let c = chunk(3, ChunkEnd::Terminal, 9.0);
        assert_eq!(*c.values_with_bootstrap().last().unwrap(), 0.0);
    }

    #[test]
    fn assemble_concatenates_in_order() {
        let chunks = vec![
            chunk(3, ChunkEnd::Continuation, 0.5),
            chunk(2, ChunkEnd::Terminal, 0.0),
        ];
        let ds = PpoDataset::assemble(&chunks, 2, 1, |r, v, c| Ok(gae(r, v, c, 0.99, 0.95)))
            .unwrap();
        assert_eq!(ds.n, 5);
        assert_eq!(ds.obs.len(), 10);
        assert_eq!(ds.old_logp, vec![-0.5; 5]);
        // GAE of each chunk computed independently
        let (a0, _) = gae(&[1.0; 3], &[0.2, 0.2, 0.2, 0.5], &[1.0; 3], 0.99, 0.95);
        assert!((ds.adv[0] - a0[0]).abs() < 1e-6);
    }

    #[test]
    fn gather_padded_fills_and_masks() {
        let chunks = vec![chunk(4, ChunkEnd::Terminal, 0.0)];
        let ds = PpoDataset::assemble(&chunks, 2, 1, |r, v, c| Ok(gae(r, v, c, 0.99, 0.95)))
            .unwrap();
        let (mut o, mut a, mut lp, mut ad, mut rt, mut mk) =
            (vec![], vec![], vec![], vec![], vec![], vec![]);
        ds.gather_padded(&[2, 0], 3, &mut o, &mut a, &mut lp, &mut ad, &mut rt, &mut mk);
        assert_eq!(mk, vec![1.0, 1.0, 0.0]);
        assert_eq!(&o[0..2], &[4.0, 5.0]); // row 2 of obs
        assert_eq!(&o[2..4], &[0.0, 1.0]); // row 0
        assert_eq!(&o[4..6], &[0.0, 0.0]); // padding
        assert_eq!(a[2], 0.0); // padded action
    }
}
