//! DDPG (further-work §6.1): the deterministic-policy [`Algorithm`]
//! registration + sampler hooks (shared with TD3), and the learner core —
//! replay-buffer sampling + fused actor/critic/target updates through a
//! `DdpgLearnerBackend`.

use crate::algo::api::{AlgoSampler, Algorithm, LearnerDriver, TickLanes};
use crate::algo::normalizer::NormSnapshot;
use crate::algo::rollout::{ChunkBuf, ChunkEnd};
use crate::config::{Algo, Backend, DdpgCfg, TrainConfig};
use crate::coordinator::learn_pool::{grain_ranges, run_grains, tree_reduce, tree_reduce_scalar};
use crate::coordinator::sampler::SamplerCfg;
use crate::nn::adam::{Adam, AdamCfg};
use crate::nn::layout::ParamLayout;
use crate::nn::mlp::{self, NetShape};
use crate::nn::tensor::Mat;
use crate::replay::shard::{ReplayRng, ShardSample, ShardedReplay};
use crate::runtime::{
    ActorBackend, BackendFactory, DdpgBatch, DdpgLearnerBackend, DdpgTrainState,
    DeterministicRowActor, DeterministicServerActor, ServerActor,
};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Stream-id base for DDPG exploration-noise RNGs (disjoint from PPO's
/// `1 << 32` and TD3's `1 << 34` so switching algorithms never aliases
/// noise streams).
const DDPG_NOISE_STREAM_BASE: u64 = 1 << 33;

/// DDPG's [`Algorithm`] registration: deterministic actor, Gaussian
/// exploration noise added worker-side, replay chunks carrying a
/// trailing s' obs row (no logp/value lanes, no bootstrap forwards).
#[derive(Debug, Clone, Default)]
pub struct Ddpg {
    pub cfg: DdpgCfg,
}

impl Ddpg {
    /// A DDPG instance with everything default but the exploration-noise
    /// stddev (the legacy `run_ddpg_sampler_from` wrapper's knob).
    pub fn with_explore_noise(sigma: f32) -> Ddpg {
        Ddpg {
            cfg: DdpgCfg {
                explore_noise: sigma,
                ..Default::default()
            },
        }
    }
}

impl Algorithm for Ddpg {
    fn id(&self) -> Algo {
        Algo::Ddpg
    }

    fn make_sampler(&self, scfg: &SamplerCfg, m: usize, act_dim: usize) -> Box<dyn AlgoSampler> {
        Box::new(DeterministicSampler::new(
            scfg,
            m,
            act_dim,
            DDPG_NOISE_STREAM_BASE,
            self.cfg.explore_noise,
        ))
    }

    fn make_local_actor(
        &self,
        factory: &dyn BackendFactory,
        rows: usize,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        make_det_local_actor(factory, rows)
    }

    fn make_server_actor(
        &self,
        factory: &dyn BackendFactory,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn ServerActor>> {
        make_det_server_actor(factory, max_rows)
    }

    fn make_eval_actor(
        &self,
        factory: &dyn BackendFactory,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        make_det_local_actor(factory, 1)
    }

    fn make_learner(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> anyhow::Result<Box<dyn LearnerDriver>> {
        let backend = factory.make_ddpg_learner()?;
        let (actor, critic) = factory.init_ddpg_params(cfg.seed);
        // the grained (L-invariant) engine needs the layer widths to run
        // the per-grain kernels itself; the XLA backend keeps its fused
        // full-batch train_step (validation caps it at L = 1)
        let hidden = match cfg.backend {
            Backend::Native => Some(cfg.hidden.as_slice()),
            _ => None,
        };
        Ok(Box::new(
            crate::coordinator::learner::DdpgLearner::with_topology(
                backend,
                actor,
                critic,
                factory.obs_dim(),
                factory.act_dim(),
                cfg.ddpg.replay_capacity,
                cfg.seed,
                cfg.replay_shards,
                cfg.replay_strategy,
                cfg.learner_threads,
                hidden,
            ),
        ))
    }

    fn policy_param_count(&self, factory: &dyn BackendFactory, cfg: &TrainConfig) -> usize {
        crate::nn::layout::actor_layout(factory.obs_dim(), factory.act_dim(), &cfg.hidden)
            .total()
    }

    fn hyperparams(&self, cfg: &TrainConfig) -> Json {
        cfg.ddpg.to_json()
    }

    fn apply_to(&self, cfg: &mut TrainConfig) {
        cfg.algo = Algo::Ddpg;
        cfg.ddpg = self.cfg.clone();
    }

    fn quantizer(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> Option<crate::coordinator::policy_store::Quantizer> {
        Some(det_actor_quantizer(factory, cfg))
    }
}

/// Worker-local deterministic actor sized to exactly `rows` rows,
/// adapted to the unified row interface — shared by every
/// deterministic-policy algorithm (DDPG, TD3: same actor network).
pub(crate) fn make_det_local_actor(
    factory: &dyn BackendFactory,
    rows: usize,
) -> anyhow::Result<Box<dyn ActorBackend>> {
    Ok(Box::new(DeterministicRowActor::new(
        factory.make_ddpg_actor_batched(rows)?,
        factory.obs_dim(),
        factory.act_dim(),
    )))
}

/// Shard-side deterministic fleet actor (see
/// [`make_det_local_actor`]; the server zero-fills the aux lanes).
pub(crate) fn make_det_server_actor(
    factory: &dyn BackendFactory,
    max_rows: usize,
) -> anyhow::Result<Box<dyn ServerActor>> {
    Ok(Box::new(DeterministicServerActor(
        factory.make_ddpg_actor_shared(max_rows)?,
    )))
}

/// Publish-time int8 quantizer for the deterministic actor network —
/// shared by every deterministic-policy algorithm (DDPG, TD3).
pub(crate) fn det_actor_quantizer(
    factory: &dyn BackendFactory,
    cfg: &TrainConfig,
) -> crate::coordinator::policy_store::Quantizer {
    let layout =
        crate::nn::layout::actor_layout(factory.obs_dim(), factory.act_dim(), &cfg.hidden);
    let shape = crate::nn::mlp::NetShape::new(factory.obs_dim(), factory.act_dim(), &cfg.hidden);
    Box::new(move |p| crate::nn::quant::quantize_det_actor(&layout, p, &shape))
}

/// Sampler hooks shared by every deterministic-policy algorithm (DDPG,
/// TD3): per-env exploration-noise streams added to the actor's output,
/// clipped executed actions recorded as the chunk's action rows,
/// zero-filled logp/value lanes, and a trailing normalized s' obs row
/// appended at every chunk close (the replay learner splits it).
pub struct DeterministicSampler {
    act_dim: usize,
    rngs: Vec<Pcg64>,
    ous: Vec<OuNoise>,
    /// Per-tick noise scratch ([act_dim], reused).
    noise: Vec<f32>,
}

impl DeterministicSampler {
    /// `stream_base` keeps this algorithm's exploration streams disjoint
    /// from every other stream family derived from the same seed.
    pub fn new(
        scfg: &SamplerCfg,
        m: usize,
        act_dim: usize,
        stream_base: u64,
        explore_noise: f32,
    ) -> DeterministicSampler {
        DeterministicSampler {
            act_dim,
            rngs: (0..m)
                .map(|i| Pcg64::with_stream(scfg.seed, stream_base + scfg.global_env(m, i)))
                .collect(),
            ous: (0..m)
                .map(|_| OuNoise::gaussian(act_dim, explore_noise))
                .collect(),
            noise: vec![0.0; act_dim],
        }
    }
}

impl AlgoSampler for DeterministicSampler {
    fn record_tick(
        &mut self,
        i: usize,
        lanes: &TickLanes<'_>,
        buf: &mut ChunkBuf,
        exec: &mut [f32],
    ) {
        let a = self.act_dim;
        exec.copy_from_slice(&lanes.action[i * a..(i + 1) * a]);
        self.ous[i].sample(&mut self.rngs[i], &mut self.noise);
        for (e, n) in exec.iter_mut().zip(&self.noise) {
            *e += n;
        }
        crate::env::clip_action(exec);
        buf.act.extend_from_slice(exec);
        buf.logp.push(0.0);
        buf.value.push(0.0);
    }

    fn close_chunk(
        &mut self,
        buf: &mut ChunkBuf,
        next_obs: &[f32],
        norm: &NormSnapshot,
        _end: ChunkEnd,
        _value_hint: f32,
    ) -> f32 {
        // replay reconstruction needs s' of the last row: append the
        // next obs normalized under the chunk's snapshot (len+1 rows)
        let start = buf.obs.len();
        buf.obs.extend_from_slice(next_obs);
        norm.apply(&mut buf.obs[start..]);
        0.0
    }

    fn on_episode_end(&mut self, i: usize) {
        self.ous[i].reset();
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_usize(self.rngs.len());
        for rng in &self.rngs {
            let (state, inc) = rng.raw_state();
            w.put_u128(state);
            w.put_u128(inc);
        }
        for ou in &self.ous {
            w.put_f32s(&ou.state);
        }
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let n = r.read_usize()?;
        anyhow::ensure!(
            n == self.rngs.len(),
            "deterministic sampler state has {n} rng lanes, expected {}",
            self.rngs.len()
        );
        for rng in self.rngs.iter_mut() {
            let state = r.read_u128()?;
            let inc = r.read_u128()?;
            *rng = Pcg64::from_raw(state, inc);
        }
        for ou in self.ous.iter_mut() {
            let state = r.read_f32s()?;
            anyhow::ensure!(
                state.len() == ou.state.len(),
                "ou noise state has {} dims, expected {}",
                state.len(),
                ou.state.len()
            );
            ou.state = state;
        }
        Ok(())
    }
}

/// Aggregated statistics for one DDPG update round.
#[derive(Debug, Clone, Copy, Default)]
pub struct DdpgUpdateStats {
    pub q_loss: f32,
    pub pi_loss: f32,
    pub updates: usize,
}

/// Run `cfg.updates_per_iter` fused full-batch gradient updates sampling
/// from the sharded replay buffer (no-op while the buffer is below
/// `warmup_steps`). This is the `DdpgLearnerBackend::train_step` path —
/// kept for the XLA artifacts, whose fused reduction order is theirs to
/// define; the native learner runs [`ddpg_update_grained`] instead.
pub fn ddpg_update(
    backend: &mut dyn DdpgLearnerBackend,
    state: &mut DdpgTrainState,
    replay: &ShardedReplay,
    cfg: &DdpgCfg,
    rng: &mut ReplayRng,
) -> anyhow::Result<DdpgUpdateStats> {
    if replay.len() < cfg.warmup_steps.max(cfg.batch) {
        return Ok(DdpgUpdateStats::default());
    }
    let batch = match backend.batch_size() {
        0 => cfg.batch,
        b => b,
    };
    let mut sample = ShardSample::default();
    let mut agg = DdpgUpdateStats::default();
    for _ in 0..cfg.updates_per_iter {
        replay.sample_into(batch, rng, &mut sample);
        let mb = DdpgBatch {
            obs: &sample.obs,
            act: &sample.act,
            rew: &sample.rew,
            next_obs: &sample.next_obs,
            done: &sample.done,
        };
        let (q, pi) = backend.train_step(state, cfg.lr_actor, cfg.lr_critic, &mb)?;
        agg.q_loss += q;
        agg.pi_loss += pi;
        agg.updates += 1;
    }
    if agg.updates > 0 {
        agg.q_loss /= agg.updates as f32;
        agg.pi_loss /= agg.updates as f32;
    }
    Ok(agg)
}

/// Grain-decomposed DDPG update round on the native kernels: the
/// minibatch is cut into fixed [`GRAIN_ROWS`]-row grains
/// ([`crate::coordinator::learn_pool`]), each grain's TD target +
/// gradient partial is computed independently (scaled by `1/B`, with the
/// minibatch's importance weights on the critic), and the partials
/// combine under a fixed-order tree reduction — so the updated
/// parameters are **bitwise identical for every `threads`**, including
/// `threads == 1`, which runs the same grains serially.
///
/// Update ordering mirrors the fused native backend exactly: shared Adam
/// step counter, critic step first, actor DPG gradient through the
/// *updated* critic (unweighted — IS corrections apply to the value
/// regression only), then Polyak on both targets. Critic TD residuals
/// feed [`ShardedReplay::update_priorities`] (a no-op under `Uniform`).
///
/// [`GRAIN_ROWS`]: crate::coordinator::learn_pool::GRAIN_ROWS
#[allow(clippy::too_many_arguments)]
pub fn ddpg_update_grained(
    state: &mut DdpgTrainState,
    replay: &ShardedReplay,
    cfg: &DdpgCfg,
    rng: &mut ReplayRng,
    alayout: &ParamLayout,
    clayout: &ParamLayout,
    shape: &NetShape,
    adam: AdamCfg,
    threads: usize,
) -> anyhow::Result<DdpgUpdateStats> {
    if replay.len() < cfg.warmup_steps.max(cfg.batch) {
        return Ok(DdpgUpdateStats::default());
    }
    let b = cfg.batch;
    let (o, a) = (shape.obs_dim, shape.act_dim);
    let inv_n = 1.0 / b as f32;
    let mut sample = ShardSample::default();
    let mut agg = DdpgUpdateStats::default();
    for _ in 0..cfg.updates_per_iter {
        replay.sample_into(b, rng, &mut sample);
        let ranges = grain_ranges(b);

        // --- critic: per-grain TD target + weighted gradient partials
        let (cgrad, q_loss, residuals) = {
            let st: &DdpgTrainState = state;
            let smp = &sample;
            let parts = run_grains(ranges.len(), threads, |g| {
                let (s, e) = ranges[g];
                let rows = e - s;
                let next_g = Mat::from_vec(rows, o, smp.next_obs[s * o..e * o].to_vec());
                let na = mlp::ddpg_actor(alayout, &st.targ_actor, shape, &next_g);
                let q = mlp::ddpg_critic(clayout, &st.targ_critic, shape, &next_g, &na);
                let target: Vec<f32> = (0..rows)
                    .map(|i| smp.rew[s + i] + cfg.gamma * (1.0 - smp.done[s + i]) * q[i])
                    .collect();
                let obs_g = Mat::from_vec(rows, o, smp.obs[s * o..e * o].to_vec());
                let act_g = Mat::from_vec(rows, a, smp.act[s * a..e * a].to_vec());
                mlp::ddpg_critic_grad_weighted(
                    clayout,
                    &st.critic,
                    shape,
                    &obs_g,
                    &act_g,
                    &target,
                    Some(&smp.weights[s..e]),
                    inv_n,
                )
            });
            let mut grads = Vec::with_capacity(parts.len());
            let mut losses = Vec::with_capacity(parts.len());
            let mut residuals = Vec::with_capacity(b);
            for (g, l, r) in parts {
                grads.push(g);
                losses.push(l);
                residuals.extend_from_slice(&r);
            }
            (tree_reduce(grads), tree_reduce_scalar(losses), residuals)
        };

        // shared step counter, critic first — the fused-path ordering
        state.t += 1;
        let mut cadam = Adam {
            cfg: adam,
            m: std::mem::take(&mut state.cm),
            v: std::mem::take(&mut state.cv),
            t: state.t - 1,
        };
        cadam.step(&mut state.critic, &cgrad, cfg.lr_critic);
        state.cm = cadam.m;
        state.cv = cadam.v;

        // --- actor: per-grain DPG partials through the UPDATED critic
        let (agrad, pi_loss) = {
            let st: &DdpgTrainState = state;
            let smp = &sample;
            let parts = run_grains(ranges.len(), threads, |g| {
                let (s, e) = ranges[g];
                let rows = e - s;
                let obs_g = Mat::from_vec(rows, o, smp.obs[s * o..e * o].to_vec());
                mlp::ddpg_actor_grad_scaled(
                    alayout, &st.actor, clayout, &st.critic, shape, &obs_g, inv_n,
                )
            });
            let mut grads = Vec::with_capacity(parts.len());
            let mut losses = Vec::with_capacity(parts.len());
            for (g, l) in parts {
                grads.push(g);
                losses.push(l);
            }
            (tree_reduce(grads), tree_reduce_scalar(losses))
        };
        let mut aadam = Adam {
            cfg: adam,
            m: std::mem::take(&mut state.am),
            v: std::mem::take(&mut state.av),
            t: state.t - 1,
        };
        aadam.step(&mut state.actor, &agrad, cfg.lr_actor);
        state.am = aadam.m;
        state.av = aadam.v;

        crate::algo::td3::polyak(&mut state.targ_actor, &state.actor, cfg.tau);
        crate::algo::td3::polyak(&mut state.targ_critic, &state.critic, cfg.tau);

        replay.update_priorities(&sample.indices, &residuals);

        agg.q_loss += q_loss;
        agg.pi_loss += pi_loss;
        agg.updates += 1;
    }
    if agg.updates > 0 {
        agg.q_loss /= agg.updates as f32;
        agg.pi_loss /= agg.updates as f32;
    }
    Ok(agg)
}

/// Ornstein–Uhlenbeck exploration noise (classic DDPG choice; falls back
/// to plain Gaussian when `theta == 0`).
#[derive(Debug, Clone)]
pub struct OuNoise {
    state: Vec<f32>,
    theta: f32,
    sigma: f32,
}

impl OuNoise {
    pub fn new(dim: usize, theta: f32, sigma: f32) -> Self {
        Self {
            state: vec![0.0; dim],
            theta,
            sigma,
        }
    }

    pub fn gaussian(dim: usize, sigma: f32) -> Self {
        Self::new(dim, 0.0, sigma)
    }

    pub fn reset(&mut self) {
        self.state.fill(0.0);
    }

    /// Sample the next noise vector into `out`.
    pub fn sample(&mut self, rng: &mut Pcg64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.state.len());
        for (s, o) in self.state.iter_mut().zip(out.iter_mut()) {
            if self.theta == 0.0 {
                *o = self.sigma * rng.normal();
            } else {
                *s += -self.theta * *s + self.sigma * rng.normal();
                *o = *s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PpoCfg;
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;

    use crate::config::ReplayStrategy;
    use crate::nn::layout::{actor_layout, critic_layout};

    fn filled_replay(n: usize) -> ShardedReplay {
        let replay = ShardedReplay::new(1000, 2, 1, 1, ReplayStrategy::Uniform);
        let mut rng = Pcg64::new(2);
        for _ in 0..n {
            let o = [rng.normal(), rng.normal()];
            replay.push(&o, &[rng.uniform(-1.0, 1.0)], 1.0, &o, false);
        }
        replay
    }

    #[test]
    fn update_noop_before_warmup() {
        let cfg = DdpgCfg {
            warmup_steps: 100,
            batch: 8,
            updates_per_iter: 5,
            ..Default::default()
        };
        let f = NativeFactory::new(2, 1, &[8, 8], PpoCfg::default(), cfg.clone());
        let mut backend = f.make_ddpg_learner().unwrap();
        let (a, c) = f.init_ddpg_params(0);
        let mut st = DdpgTrainState::new(a, c);
        let replay = filled_replay(50);
        let before = st.actor.clone();
        let stats = ddpg_update(
            backend.as_mut(),
            &mut st,
            &replay,
            &cfg,
            &mut ReplayRng::new(1),
        )
        .unwrap();
        assert_eq!(stats.updates, 0);
        assert_eq!(st.actor, before);
    }

    #[test]
    fn update_runs_after_warmup_and_learns_q() {
        let cfg = DdpgCfg {
            warmup_steps: 10,
            batch: 16,
            updates_per_iter: 50,
            lr_actor: 0.0, // isolate critic learning
            lr_critic: 1e-2,
            gamma: 0.0, // Q target is exactly the reward
            ..Default::default()
        };
        let f = NativeFactory::new(2, 1, &[16, 16], PpoCfg::default(), cfg.clone());
        let mut backend = f.make_ddpg_learner().unwrap();
        let (a, c) = f.init_ddpg_params(1);
        let mut st = DdpgTrainState::new(a, c);
        let replay = filled_replay(200);
        let mut rng = ReplayRng::new(2);
        let first = ddpg_update(backend.as_mut(), &mut st, &replay, &cfg, &mut rng).unwrap();
        let second = ddpg_update(backend.as_mut(), &mut st, &replay, &cfg, &mut rng).unwrap();
        assert_eq!(first.updates, 50);
        assert!(
            second.q_loss < 0.5 * first.q_loss.max(1e-6) + 0.05,
            "q_loss did not drop: {} -> {}",
            first.q_loss,
            second.q_loss
        );
    }

    #[test]
    fn grained_update_is_thread_count_invariant_and_learns() {
        // batch 192 = 3 grains; L ∈ {1, 2, 4} must produce bitwise
        // identical parameters (same grains, same tree reduction)
        let cfg = DdpgCfg {
            warmup_steps: 10,
            batch: 192,
            updates_per_iter: 4,
            lr_critic: 1e-2,
            gamma: 0.0,
            ..Default::default()
        };
        let alayout = actor_layout(2, 1, &[16, 16]);
        let clayout = critic_layout(2, 1, &[16, 16]);
        let shape = NetShape::new(2, 1, &[16, 16]);
        let run = |threads: usize| {
            let mut init = Pcg64::new(1);
            let a = alayout.init_flat(&mut init);
            let c = clayout.init_flat(&mut init);
            let mut st = DdpgTrainState::new(a, c);
            let replay = filled_replay(400);
            let stats = ddpg_update_grained(
                &mut st,
                &replay,
                &cfg,
                &mut ReplayRng::new(9),
                &alayout,
                &clayout,
                &shape,
                AdamCfg::default(),
                threads,
            )
            .unwrap();
            (st, stats)
        };
        let (base, stats1) = run(1);
        assert_eq!(stats1.updates, 4);
        let st0 = {
            let mut init = Pcg64::new(1);
            let a = alayout.init_flat(&mut init);
            let c = clayout.init_flat(&mut init);
            DdpgTrainState::new(a, c)
        };
        assert_ne!(base.actor, st0.actor, "update must move the actor");
        for threads in [2, 4] {
            let (st, _) = run(threads);
            assert_eq!(
                base.actor
                    .iter()
                    .map(|x| x.to_bits())
                    .collect::<Vec<_>>(),
                st.actor.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "actor diverged at L={threads}"
            );
            assert_eq!(
                base.critic
                    .iter()
                    .map(|x| x.to_bits())
                    .collect::<Vec<_>>(),
                st.critic.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "critic diverged at L={threads}"
            );
        }
    }

    #[test]
    fn ou_noise_is_correlated_gaussian_is_not() {
        let mut rng = Pcg64::new(3);
        let mut ou = OuNoise::new(1, 0.15, 0.2);
        let mut buf = [0.0f32];
        let mut xs = Vec::new();
        for _ in 0..2000 {
            ou.sample(&mut rng, &mut buf);
            xs.push(buf[0]);
        }
        // lag-1 autocorrelation of OU must be clearly positive
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let num: f32 = xs.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let den: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        assert!(num / den > 0.5, "OU autocorr {}", num / den);

        let mut g = OuNoise::gaussian(1, 0.2);
        let mut ys = Vec::new();
        for _ in 0..2000 {
            g.sample(&mut rng, &mut buf);
            ys.push(buf[0]);
        }
        let mean = ys.iter().sum::<f32>() / ys.len() as f32;
        let num: f32 = ys.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let den: f32 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
        assert!(num.abs() / den < 0.1, "gaussian autocorr {}", num / den);
    }

    #[test]
    fn sampler_state_round_trip_continues_noise_bitwise() {
        let scfg = SamplerCfg {
            id: 1,
            seed: 42,
            chunk_steps: 40,
            sync_budget: None,
            reward_scale: 1.0,
        };
        let mut live = DeterministicSampler::new(&scfg, 2, 3, 1 << 33, 0.2);
        // make the OU path stateful so the snapshot must carry it
        for ou in live.ous.iter_mut() {
            ou.theta = 0.15;
        }
        let mut out = [0.0f32; 3];
        for i in 0..17 {
            live.sample_all_for_test(&mut out, i % 2);
        }
        let blob = AlgoSampler::save_state(&live);

        let mut restored = DeterministicSampler::new(&scfg, 2, 3, 1 << 33, 0.2);
        for ou in restored.ous.iter_mut() {
            ou.theta = 0.15;
        }
        AlgoSampler::load_state(&mut restored, &blob).unwrap();
        let mut a = [0.0f32; 3];
        let mut b = [0.0f32; 3];
        for i in 0..25 {
            live.sample_all_for_test(&mut a, i % 2);
            restored.sample_all_for_test(&mut b, i % 2);
            assert_eq!(a, b, "noise diverged after restore at draw {i}");
        }

        // wrong shape rejected
        let other = DeterministicSampler::new(&scfg, 1, 3, 1 << 33, 0.2);
        let mut bad = DeterministicSampler::new(&scfg, 2, 3, 1 << 33, 0.2);
        assert!(AlgoSampler::load_state(&mut bad, &AlgoSampler::save_state(&other)).is_err());
    }

    impl DeterministicSampler {
        fn sample_all_for_test(&mut self, out: &mut [f32], i: usize) {
            let ou = &mut self.ous[i];
            ou.sample(&mut self.rngs[i], out);
        }
    }

    #[test]
    fn ou_reset_zeroes_state() {
        let mut rng = Pcg64::new(4);
        let mut ou = OuNoise::new(2, 0.15, 0.3);
        let mut buf = [0.0f32; 2];
        for _ in 0..10 {
            ou.sample(&mut rng, &mut buf);
        }
        ou.reset();
        assert_eq!(ou.state, vec![0.0, 0.0]);
    }
}
