//! DDPG learner core (further-work §6.1): replay-buffer sampling + fused
//! actor/critic/target updates through a `DdpgLearnerBackend`.

use crate::config::DdpgCfg;
use crate::replay::{ReplayBuffer, ReplaySample};
use crate::runtime::{DdpgBatch, DdpgLearnerBackend, DdpgTrainState};
use crate::util::rng::Pcg64;

/// Aggregated statistics for one DDPG update round.
#[derive(Debug, Clone, Copy, Default)]
pub struct DdpgUpdateStats {
    pub q_loss: f32,
    pub pi_loss: f32,
    pub updates: usize,
}

/// Run `cfg.updates_per_iter` gradient updates sampling from the replay
/// buffer (no-op while the buffer is below `warmup_steps`).
pub fn ddpg_update(
    backend: &mut dyn DdpgLearnerBackend,
    state: &mut DdpgTrainState,
    replay: &ReplayBuffer,
    cfg: &DdpgCfg,
    rng: &mut Pcg64,
) -> anyhow::Result<DdpgUpdateStats> {
    if replay.len() < cfg.warmup_steps.max(cfg.batch) {
        return Ok(DdpgUpdateStats::default());
    }
    let batch = match backend.batch_size() {
        0 => cfg.batch,
        b => b,
    };
    let mut sample = ReplaySample::default();
    let mut agg = DdpgUpdateStats::default();
    for _ in 0..cfg.updates_per_iter {
        replay.sample_into(batch, rng, &mut sample);
        let mb = DdpgBatch {
            obs: &sample.obs,
            act: &sample.act,
            rew: &sample.rew,
            next_obs: &sample.next_obs,
            done: &sample.done,
        };
        let (q, pi) = backend.train_step(state, cfg.lr_actor, cfg.lr_critic, &mb)?;
        agg.q_loss += q;
        agg.pi_loss += pi;
        agg.updates += 1;
    }
    if agg.updates > 0 {
        agg.q_loss /= agg.updates as f32;
        agg.pi_loss /= agg.updates as f32;
    }
    Ok(agg)
}

/// Ornstein–Uhlenbeck exploration noise (classic DDPG choice; falls back
/// to plain Gaussian when `theta == 0`).
#[derive(Debug, Clone)]
pub struct OuNoise {
    state: Vec<f32>,
    theta: f32,
    sigma: f32,
}

impl OuNoise {
    pub fn new(dim: usize, theta: f32, sigma: f32) -> Self {
        Self {
            state: vec![0.0; dim],
            theta,
            sigma,
        }
    }

    pub fn gaussian(dim: usize, sigma: f32) -> Self {
        Self::new(dim, 0.0, sigma)
    }

    pub fn reset(&mut self) {
        self.state.fill(0.0);
    }

    /// Sample the next noise vector into `out`.
    pub fn sample(&mut self, rng: &mut Pcg64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.state.len());
        for (s, o) in self.state.iter_mut().zip(out.iter_mut()) {
            if self.theta == 0.0 {
                *o = self.sigma * rng.normal();
            } else {
                *s += -self.theta * *s + self.sigma * rng.normal();
                *o = *s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PpoCfg;
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;

    #[test]
    fn update_noop_before_warmup() {
        let cfg = DdpgCfg {
            warmup_steps: 100,
            batch: 8,
            updates_per_iter: 5,
            ..Default::default()
        };
        let f = NativeFactory::new(2, 1, &[8, 8], PpoCfg::default(), cfg.clone());
        let mut backend = f.make_ddpg_learner().unwrap();
        let (a, c) = f.init_ddpg_params(0);
        let mut st = DdpgTrainState::new(a, c);
        let mut replay = ReplayBuffer::new(1000, 2, 1);
        for i in 0..50 {
            replay.push(&[i as f32, 0.0], &[0.1], 1.0, &[i as f32 + 1.0, 0.0], false);
        }
        let before = st.actor.clone();
        let stats = ddpg_update(backend.as_mut(), &mut st, &replay, &cfg, &mut Pcg64::new(1))
            .unwrap();
        assert_eq!(stats.updates, 0);
        assert_eq!(st.actor, before);
    }

    #[test]
    fn update_runs_after_warmup_and_learns_q() {
        let cfg = DdpgCfg {
            warmup_steps: 10,
            batch: 16,
            updates_per_iter: 50,
            lr_actor: 0.0, // isolate critic learning
            lr_critic: 1e-2,
            gamma: 0.0, // Q target is exactly the reward
            ..Default::default()
        };
        let f = NativeFactory::new(2, 1, &[16, 16], PpoCfg::default(), cfg.clone());
        let mut backend = f.make_ddpg_learner().unwrap();
        let (a, c) = f.init_ddpg_params(1);
        let mut st = DdpgTrainState::new(a, c);
        let mut replay = ReplayBuffer::new(1000, 2, 1);
        let mut rng = Pcg64::new(2);
        for _ in 0..200 {
            let o = [rng.normal(), rng.normal()];
            replay.push(&o, &[rng.uniform(-1.0, 1.0)], 1.0, &o, false);
        }
        let first = ddpg_update(backend.as_mut(), &mut st, &replay, &cfg, &mut rng).unwrap();
        let second = ddpg_update(backend.as_mut(), &mut st, &replay, &cfg, &mut rng).unwrap();
        assert_eq!(first.updates, 50);
        assert!(
            second.q_loss < 0.5 * first.q_loss.max(1e-6) + 0.05,
            "q_loss did not drop: {} -> {}",
            first.q_loss,
            second.q_loss
        );
    }

    #[test]
    fn ou_noise_is_correlated_gaussian_is_not() {
        let mut rng = Pcg64::new(3);
        let mut ou = OuNoise::new(1, 0.15, 0.2);
        let mut buf = [0.0f32];
        let mut xs = Vec::new();
        for _ in 0..2000 {
            ou.sample(&mut rng, &mut buf);
            xs.push(buf[0]);
        }
        // lag-1 autocorrelation of OU must be clearly positive
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let num: f32 = xs.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let den: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        assert!(num / den > 0.5, "OU autocorr {}", num / den);

        let mut g = OuNoise::gaussian(1, 0.2);
        let mut ys = Vec::new();
        for _ in 0..2000 {
            g.sample(&mut rng, &mut buf);
            ys.push(buf[0]);
        }
        let mean = ys.iter().sum::<f32>() / ys.len() as f32;
        let num: f32 = ys.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let den: f32 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
        assert!(num.abs() / den < 0.1, "gaussian autocorr {}", num / den);
    }

    #[test]
    fn ou_reset_zeroes_state() {
        let mut rng = Pcg64::new(4);
        let mut ou = OuNoise::new(2, 0.15, 0.3);
        let mut buf = [0.0f32; 2];
        for _ in 0..10 {
            ou.sample(&mut rng, &mut buf);
        }
        ou.reset();
        assert_eq!(ou.state, vec![0.0, 0.0]);
    }
}
