//! The `Algorithm` trait: one abstraction every pipeline stage is
//! generic over.
//!
//! WALL-E's coordinator used to hard-code its algorithms as duplicated
//! pipelines — `run_ppo_sampler` vs `run_ddpg_sampler`, `serve_ppo` vs
//! `serve_ddpg`, `PpoLearner` vs `DdpgLearner`, and `Algo::` match arms
//! threaded through the orchestrator, eval, and the CLI. Following the
//! factoring argument of "Parallel Actors and Learners" (Zhang et al.,
//! 2021) and Spreeze (Hou et al., 2023), everything algorithm-specific
//! now hangs off ONE trait, and the sampler hot loop, inference-pool
//! serve loop, learner driver, orchestrator, and eval are each written
//! once against it. Adding an algorithm means implementing this trait
//! plus a `config::Algo` variant — see [`crate::algo::td3`] for the
//! worked example (and `docs/API.md` for the full walkthrough).
//!
//! The trait splits along the paper's process topology:
//!
//! * **Actor (sampler) side** — [`Algorithm::make_sampler`] builds the
//!   per-worker [`AlgoSampler`] hooks (exploration-noise streams, lane
//!   recording, chunk-close semantics), and
//!   [`Algorithm::make_local_actor`] the worker-private policy backend.
//!   The generic hot loop in `coordinator::sampler` owns everything
//!   else: lockstep env stepping, chunk windows, sync budgets, policy
//!   refreshes, and the shared-inference epoch cuts.
//! * **Shared inference side** — [`Algorithm::make_server_actor`] builds
//!   the shard's fleet-slice forward
//!   ([`crate::runtime::ServerActor`]); the serve loop batches, cuts,
//!   and scatters without knowing which algorithm it serves.
//! * **Learner side** — [`Algorithm::make_learner`] builds a
//!   [`LearnerDriver`]; the orchestrator drives `publish_initial` + one
//!   `iteration` per training iteration.
//! * **Eval side** — [`Algorithm::make_eval_actor`] builds the SAME
//!   deterministic actor construction training uses (at batch 1), so
//!   `walle eval`, the examples, and the figure harness can never drift
//!   from the train-time forward.
//!
//! The slab schema is algorithm-agnostic: each act response carries an
//! `action` lane plus optional aux lanes (`logp`/`value`/`mean`) that
//! stochastic algorithms fill and deterministic ones leave empty
//! ([`TickLanes`]). Experience flows as the same
//! [`ExperienceChunk`](crate::algo::rollout::ExperienceChunk) for every
//! algorithm; per-algorithm payload conventions (PPO's logp/value rows,
//! DDPG/TD3's trailing s' obs row) live entirely inside the hooks.

use crate::algo::normalizer::NormSnapshot;
use crate::algo::rollout::{ChunkBuf, ChunkEnd, ExperienceChunk};
use crate::config::{Algo, TrainConfig};
use crate::coordinator::metrics::IterationMetrics;
use crate::coordinator::policy_store::PolicyStore;
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::SamplerCfg;
use crate::runtime::{ActorBackend, BackendFactory, ServerActor};
use crate::util::json::Json;

/// One sim tick's policy outputs, viewed as lanes. `action` always holds
/// `m * act_dim` entries (more for fixed-batch local backends — index by
/// row, never by length). `logp`/`value` hold one entry per row for
/// stochastic algorithms and are empty (local) or zero-filled (shared
/// responses) for deterministic ones; hooks that don't fill a lane must
/// not read it.
pub struct TickLanes<'a> {
    pub action: &'a [f32],
    pub logp: &'a [f32],
    pub value: &'a [f32],
}

/// Per-worker sampler behavior + state: exploration-noise streams, lane
/// recording, and chunk-close semantics. Built once per worker by
/// [`Algorithm::make_sampler`]; the generic loop in
/// `coordinator::sampler::run_algo_sampler` calls the hooks in a fixed
/// order each tick, so per-env RNG consumption is deterministic and
/// independent of inference placement.
pub trait AlgoSampler {
    /// Whether each act call consumes a `[rows * act_dim]` lane of
    /// N(0,1) draws (PPO's reparameterized sampling). Deterministic
    /// algorithms submit an empty lane and add exploration noise in
    /// [`AlgoSampler::record_tick`] instead.
    fn uses_policy_noise(&self) -> bool {
        false
    }

    /// Fill this tick's policy-noise lanes (`[m * act_dim]`, one row per
    /// env slot, drawn from per-env streams). Only called when
    /// [`AlgoSampler::uses_policy_noise`] is true.
    fn fill_policy_noise(&mut self, _noise: &mut [f32]) {}

    /// Record env slot `i`'s tick: append the algorithm's lanes
    /// (`act`/`logp`/`value`) to `buf` and write the *executed* action
    /// (post-exploration-noise, clipped) into `exec`
    /// (`[act_dim]`). The loop has already appended the normalized obs
    /// row and raw-obs stats.
    fn record_tick(
        &mut self,
        i: usize,
        lanes: &TickLanes<'_>,
        buf: &mut ChunkBuf,
        exec: &mut [f32],
    );

    /// Whether non-terminal chunk cuts need a V(s') bootstrap forward
    /// (PPO's GAE targets). When false the loop never issues the extra
    /// boundary inference call.
    fn needs_value_bootstrap(&self) -> bool {
        false
    }

    /// Close env slot `i`'s chunk at a cut: optionally mutate the buffer
    /// (DDPG/TD3 append the s' row — `next_obs`, normalized under
    /// `norm`, the snapshot the chunk was collected with) and return the
    /// bootstrap value to record. `value_hint` is V(s') from the
    /// bootstrap forward (boundary cuts) or V(s_t) from this tick's
    /// forward (shared-mode version cuts); algorithms that don't
    /// bootstrap ignore it.
    fn close_chunk(
        &mut self,
        buf: &mut ChunkBuf,
        next_obs: &[f32],
        norm: &NormSnapshot,
        end: ChunkEnd,
        value_hint: f32,
    ) -> f32;

    /// An episode in env slot `i` just ended (reset exploration state;
    /// the env itself is reset by the loop).
    fn on_episode_end(&mut self, _i: usize) {}

    /// Serialize the sampler's exploration state (per-env RNG cursors,
    /// noise-process state) for supervisor snapshots and checkpoints.
    /// Restoring via [`AlgoSampler::load_state`] must continue the
    /// exploration streams bitwise. The default (empty) is only correct
    /// for stateless samplers.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore exploration state captured by [`AlgoSampler::save_state`].
    /// Errors when the blob doesn't match this sampler's shape.
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The learner loop, one instance per run: consume experience chunks,
/// update parameters, publish through the policy store. Built by
/// [`Algorithm::make_learner`]; the orchestrator drives it without
/// knowing the algorithm.
pub trait LearnerDriver {
    /// Publish the initial policy so samplers can start.
    fn publish_initial(&self, store: &PolicyStore);

    /// Run one training iteration (collect → update → publish). Errors
    /// when the experience queue closed.
    ///
    /// Off-policy drivers may fan the per-minibatch gradient computation
    /// over `cfg.learner_threads` workers, but the contract is strict:
    /// the published parameters must be **bitwise identical for every
    /// thread count** (fixed grain decomposition + fixed-order tree
    /// reduction — see `coordinator::learn_pool`), so `--learner-threads`
    /// is a pure wall-clock knob, never a semantics knob.
    fn iteration(
        &mut self,
        iter: usize,
        cfg: &TrainConfig,
        queue: &Channel<ExperienceChunk>,
        store: &PolicyStore,
    ) -> anyhow::Result<IterationMetrics>;

    /// The final policy parameters (what `walle train` checkpoints and
    /// `walle eval` reloads).
    fn final_params(&self) -> Vec<f32>;

    /// The final observation-normalizer snapshot — the transform the
    /// published policy expects its inputs to go through. Surfaced in
    /// `RunResult` so evaluation can apply the SAME normalization
    /// training used (checkpoint files don't carry it).
    fn final_norm(&self) -> NormSnapshot;

    /// Serialize the learner's full training state (parameters, optimizer
    /// moments, update RNG, normalizer, counters) for
    /// `runtime::checkpoint`. Restoring via
    /// [`LearnerDriver::load_state`] must continue updates bitwise for
    /// on-policy learners. The default (empty) opts the learner out of
    /// checkpointing.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore training state captured by [`LearnerDriver::save_state`].
    /// Errors when the blob doesn't match this learner's shape.
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// One RL algorithm, end to end: everything the generic pipeline needs
/// to sample with it, serve it from the shared inference pool, learn it,
/// evaluate it, and describe it. See the module docs for the contract
/// and `docs/API.md` for the add-your-own-algorithm walkthrough.
pub trait Algorithm: Send + Sync {
    /// The config-enum identity (used for spec rendering and registry
    /// round-trips).
    fn id(&self) -> Algo;

    /// CLI/JSON name (`"ppo"`, `"ddpg"`, `"td3"`, `"sac"`).
    fn name(&self) -> &'static str {
        self.id().name()
    }

    /// Per-worker sampler hooks (exploration streams are derived from
    /// `scfg.seed` and the worker's global env slots so trajectories are
    /// pinned to slots, not to worker layout).
    fn make_sampler(&self, scfg: &SamplerCfg, m: usize, act_dim: usize) -> Box<dyn AlgoSampler>;

    /// Worker-private policy backend sized for exactly `rows` rows per
    /// call (local inference mode).
    fn make_local_actor(
        &self,
        factory: &dyn BackendFactory,
        rows: usize,
    ) -> anyhow::Result<Box<dyn ActorBackend>>;

    /// Fleet-slice forward for one shared-inference shard (accepts any
    /// row count 1..=`max_rows`; see
    /// [`BackendFactory::make_actor_shared`]).
    fn make_server_actor(
        &self,
        factory: &dyn BackendFactory,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn ServerActor>>;

    /// Deterministic (mean-action) single-row evaluator — the SAME
    /// construction the training path uses at M = 1, so eval can never
    /// drift from the train-time forward.
    fn make_eval_actor(
        &self,
        factory: &dyn BackendFactory,
    ) -> anyhow::Result<Box<dyn ActorBackend>>;

    /// The learner loop for one run.
    fn make_learner(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> anyhow::Result<Box<dyn LearnerDriver>>;

    /// Flat length of the published policy parameters (checkpoint shape
    /// check for `walle eval`).
    fn policy_param_count(&self, factory: &dyn BackendFactory, cfg: &TrainConfig) -> usize;

    /// Resolved hyper-parameters as JSON (rendered by `walle info` and
    /// embedded in `session::SessionSpec`).
    fn hyperparams(&self, cfg: &TrainConfig) -> Json;

    /// Write this instance's identity + hyper-parameters into a
    /// `TrainConfig` (the `Session` builder's `.algo(...)` path; the
    /// config stays the single source of truth at run time).
    fn apply_to(&self, cfg: &mut TrainConfig);

    /// Algorithm-specific config validation beyond
    /// `TrainConfig::validate` (which already covers cross-algorithm
    /// structural checks).
    fn validate(&self, _cfg: &TrainConfig) -> Result<(), String> {
        Ok(())
    }

    /// Publish-time int8 quantizer for this algorithm's actor (installed
    /// into the `PolicyStore` when `--infer-precision int8`; see
    /// `nn::quant`). `None` (the default) means the algorithm has no
    /// quantized inference path and int8 is rejected at validation.
    fn quantizer(
        &self,
        _factory: &dyn BackendFactory,
        _cfg: &TrainConfig,
    ) -> Option<crate::coordinator::policy_store::Quantizer> {
        None
    }
}

/// The algorithm registry: resolve a run config to its [`Algorithm`]
/// instance. This match is the ONE place an algorithm registers with the
/// pipeline — the sampler loop, inference pool, orchestrator, eval, and
/// CLI all dispatch through the trait object it returns.
pub fn algorithm_from_config(cfg: &TrainConfig) -> Box<dyn Algorithm> {
    match cfg.algo {
        Algo::Ppo => Box::new(crate::algo::ppo::Ppo {
            cfg: cfg.ppo.clone(),
        }),
        Algo::Ddpg => Box::new(crate::algo::ddpg::Ddpg {
            cfg: cfg.ddpg.clone(),
        }),
        Algo::Td3 => Box::new(crate::algo::td3::Td3 {
            cfg: cfg.td3.clone(),
        }),
        Algo::Sac => Box::new(crate::algo::sac::Sac {
            cfg: cfg.sac.clone(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_round_trips_every_algo() {
        for algo in [Algo::Ppo, Algo::Ddpg, Algo::Td3, Algo::Sac] {
            let mut cfg = TrainConfig::preset("pendulum");
            cfg.algo = algo;
            let a = algorithm_from_config(&cfg);
            assert_eq!(a.id(), algo);
            assert_eq!(a.name(), algo.name());
            // apply_to writes the identity back
            let mut cfg2 = TrainConfig::default();
            a.apply_to(&mut cfg2);
            assert_eq!(cfg2.algo, algo);
        }
    }

    #[test]
    fn hyperparams_render_as_json_objects() {
        let cfg = TrainConfig::preset("pendulum");
        for algo in [Algo::Ppo, Algo::Ddpg, Algo::Td3, Algo::Sac] {
            let mut c = cfg.clone();
            c.algo = algo;
            let a = algorithm_from_config(&c);
            let j = a.hyperparams(&c);
            assert!(
                j.as_obj().is_ok(),
                "{} hyperparams must be a JSON object",
                a.name()
            );
        }
    }
}
