//! PPO: the stochastic-policy [`Algorithm`] (its [`Ppo`] registration +
//! sampler hooks) and the learner core — dataset → shuffled minibatch
//! epochs → Adam steps, with optional advantage normalization, LR
//! annealing and data-parallel gradient sharding (further-work §6.2).

use crate::algo::api::{AlgoSampler, Algorithm, LearnerDriver, TickLanes};
use crate::algo::gae::normalize_advantages;
use crate::algo::normalizer::NormSnapshot;
use crate::algo::rollout::{ChunkBuf, ChunkEnd, PpoDataset};
use crate::config::{Algo, PpoCfg, TrainConfig};
use crate::coordinator::sampler::SamplerCfg;
use crate::nn::mlp::PpoStats;
use crate::runtime::{
    ActorBackend, BackendFactory, PpoLearnerBackend, PpoMinibatch, PpoTrainState, ServerActor,
    StochasticServerActor,
};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Stream-id base for PPO action-noise RNGs (the global env index is
/// added). High bases keep noise streams disjoint from env dynamics
/// streams, which the orchestrator numbers from 1, and from the other
/// algorithms' exploration streams.
const PPO_NOISE_STREAM_BASE: u64 = 1 << 32;

/// PPO's [`Algorithm`] registration: Gaussian policy with per-row
/// reparameterized sampling (the noise lanes), logp/value aux lanes, and
/// GAE value bootstraps at chunk cuts.
#[derive(Debug, Clone, Default)]
pub struct Ppo {
    pub cfg: PpoCfg,
}

impl Algorithm for Ppo {
    fn id(&self) -> Algo {
        Algo::Ppo
    }

    fn make_sampler(&self, scfg: &SamplerCfg, m: usize, act_dim: usize) -> Box<dyn AlgoSampler> {
        Box::new(PpoSampler {
            act_dim,
            rngs: (0..m)
                .map(|i| {
                    Pcg64::with_stream(scfg.seed, PPO_NOISE_STREAM_BASE + scfg.global_env(m, i))
                })
                .collect(),
        })
    }

    fn make_local_actor(
        &self,
        factory: &dyn BackendFactory,
        rows: usize,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        factory.make_actor_batched(rows)
    }

    fn make_server_actor(
        &self,
        factory: &dyn BackendFactory,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn ServerActor>> {
        Ok(Box::new(StochasticServerActor(
            factory.make_actor_shared(max_rows)?,
        )))
    }

    fn make_eval_actor(
        &self,
        factory: &dyn BackendFactory,
    ) -> anyhow::Result<Box<dyn ActorBackend>> {
        // the same construction the training path uses at M = 1 (exact
        // one-row forward; zero noise makes action == mean)
        factory.make_actor_batched(1)
    }

    fn make_learner(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> anyhow::Result<Box<dyn LearnerDriver>> {
        let backend = factory.make_ppo_learner()?;
        let shards = if cfg.learner_shards > 1 {
            (0..cfg.learner_shards)
                .map(|_| factory.make_ppo_learner())
                .collect::<anyhow::Result<Vec<_>>>()?
        } else {
            Vec::new()
        };
        Ok(Box::new(crate::coordinator::learner::PpoLearner::new(
            backend,
            shards,
            factory.init_ppo_params(cfg.seed),
            factory.obs_dim(),
            cfg.seed,
        )))
    }

    fn policy_param_count(&self, factory: &dyn BackendFactory, _cfg: &TrainConfig) -> usize {
        factory.ppo_param_count()
    }

    fn hyperparams(&self, cfg: &TrainConfig) -> Json {
        cfg.ppo.to_json()
    }

    fn apply_to(&self, cfg: &mut TrainConfig) {
        cfg.algo = Algo::Ppo;
        cfg.ppo = self.cfg.clone();
    }

    fn quantizer(
        &self,
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
    ) -> Option<crate::coordinator::policy_store::Quantizer> {
        let layout =
            crate::nn::layout::ppo_layout(factory.obs_dim(), factory.act_dim(), &cfg.hidden);
        let shape =
            crate::nn::mlp::NetShape::new(factory.obs_dim(), factory.act_dim(), &cfg.hidden);
        Some(Box::new(move |p| {
            crate::nn::quant::quantize_ppo(&layout, p, &shape)
        }))
    }
}

/// Per-worker PPO sampler hooks: per-env reparameterization-noise
/// streams, pre-clip action + logp/value lane recording, and value
/// bootstraps at chunk cuts.
struct PpoSampler {
    act_dim: usize,
    rngs: Vec<Pcg64>,
}

impl AlgoSampler for PpoSampler {
    fn uses_policy_noise(&self) -> bool {
        true
    }

    fn fill_policy_noise(&mut self, noise: &mut [f32]) {
        let a = self.act_dim;
        for (i, rng) in self.rngs.iter_mut().enumerate() {
            rng.fill_normal(&mut noise[i * a..(i + 1) * a]);
        }
    }

    fn record_tick(
        &mut self,
        i: usize,
        lanes: &TickLanes<'_>,
        buf: &mut ChunkBuf,
        exec: &mut [f32],
    ) {
        let a = self.act_dim;
        let arow = &lanes.action[i * a..(i + 1) * a];
        buf.act.extend_from_slice(arow); // pre-clip action (matches logp)
        buf.logp.push(lanes.logp[i]);
        buf.value.push(lanes.value[i]);
        exec.copy_from_slice(arow);
        crate::env::clip_action(exec);
    }

    fn needs_value_bootstrap(&self) -> bool {
        true
    }

    fn close_chunk(
        &mut self,
        _buf: &mut ChunkBuf,
        _next_obs: &[f32],
        _norm: &NormSnapshot,
        end: ChunkEnd,
        value_hint: f32,
    ) -> f32 {
        match end {
            ChunkEnd::Terminal => 0.0,
            _ => value_hint,
        }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_usize(self.rngs.len());
        for rng in &self.rngs {
            let (state, inc) = rng.raw_state();
            w.put_u128(state);
            w.put_u128(inc);
        }
        w.into_vec()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let n = r.read_usize()?;
        anyhow::ensure!(
            n == self.rngs.len(),
            "ppo sampler state has {n} rng lanes, expected {}",
            self.rngs.len()
        );
        for rng in self.rngs.iter_mut() {
            let state = r.read_u128()?;
            let inc = r.read_u128()?;
            *rng = Pcg64::from_raw(state, inc);
        }
        Ok(())
    }
}

/// Aggregated statistics for one PPO update (averaged over minibatches).
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub stats: PpoStats,
    pub minibatches: usize,
    pub samples: usize,
    pub lr: f32,
}

/// One full PPO update over a dataset: `epochs` passes of shuffled
/// minibatches. The backend dictates the (padded) minibatch row count.
pub fn ppo_update(
    backend: &mut dyn PpoLearnerBackend,
    state: &mut PpoTrainState,
    dataset: &mut PpoDataset,
    cfg: &PpoCfg,
    lr: f32,
    rng: &mut Pcg64,
) -> anyhow::Result<UpdateStats> {
    if cfg.norm_adv {
        normalize_advantages(&mut dataset.adv);
    }
    let rows = match backend.minibatch_size() {
        0 => cfg.minibatch,
        m => m,
    };

    let mut idx: Vec<usize> = (0..dataset.n).collect();
    let mut agg = PpoStats::default();
    let mut count = 0usize;

    // reusable minibatch buffers
    let (mut obs, mut act, mut old_logp, mut adv, mut ret, mut mask) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());

    for _ in 0..cfg.epochs {
        rng.shuffle(&mut idx);
        for mb_idx in idx.chunks(rows) {
            dataset.gather_padded(
                mb_idx, rows, &mut obs, &mut act, &mut old_logp, &mut adv, &mut ret, &mut mask,
            );
            let mb = PpoMinibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
                mask: &mask,
            };
            let s = backend.train_step(state, lr, &mb)?;
            agg.total += s.total;
            agg.pi_loss += s.pi_loss;
            agg.v_loss += s.v_loss;
            agg.entropy += s.entropy;
            agg.approx_kl += s.approx_kl;
            agg.clip_frac += s.clip_frac;
            count += 1;
        }
    }
    if count > 0 {
        let k = count as f32;
        agg.total /= k;
        agg.pi_loss /= k;
        agg.v_loss /= k;
        agg.entropy /= k;
        agg.approx_kl /= k;
        agg.clip_frac /= k;
    }
    Ok(UpdateStats {
        stats: agg,
        minibatches: count,
        samples: dataset.n,
        lr,
    })
}

/// Data-parallel variant (§6.2): split each minibatch into `shards`,
/// compute gradients per shard (sequentially here; the coordinator's
/// sharded learner runs them on threads), weighted-average, apply once.
/// Mathematically identical to `ppo_update` when shards = 1.
pub fn ppo_update_sharded(
    backends: &mut [Box<dyn PpoLearnerBackend>],
    state: &mut PpoTrainState,
    dataset: &mut PpoDataset,
    cfg: &PpoCfg,
    lr: f32,
    rng: &mut Pcg64,
) -> anyhow::Result<UpdateStats> {
    assert!(!backends.is_empty());
    if cfg.norm_adv {
        normalize_advantages(&mut dataset.adv);
    }
    let shard_rows = match backends[0].minibatch_size() {
        0 => cfg.minibatch / backends.len().max(1),
        m => m,
    };
    let shards = backends.len();
    let full = shard_rows * shards;

    let mut idx: Vec<usize> = (0..dataset.n).collect();
    let mut count = 0usize;
    let mut total = 0.0f32;

    let (mut obs, mut act, mut old_logp, mut adv, mut ret, mut mask) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());

    for _ in 0..cfg.epochs {
        rng.shuffle(&mut idx);
        for mb_idx in idx.chunks(full) {
            let mut acc: Vec<f32> = vec![0.0; state.flat.len()];
            let mut weight_sum = 0.0f32;
            for (s, shard_idx) in mb_idx.chunks(shard_rows.max(1)).enumerate() {
                if s >= shards || shard_idx.is_empty() {
                    break;
                }
                dataset.gather_padded(
                    shard_idx, shard_rows, &mut obs, &mut act, &mut old_logp, &mut adv,
                    &mut ret, &mut mask,
                );
                let mb = PpoMinibatch {
                    obs: &obs,
                    act: &act,
                    old_logp: &old_logp,
                    adv: &adv,
                    ret: &ret,
                    mask: &mask,
                };
                let (g, loss, n) = backends[s].grad(&state.flat, &mb)?;
                // masked means are per-shard; weight by valid rows
                for (a, gi) in acc.iter_mut().zip(&g) {
                    *a += gi * n;
                }
                weight_sum += n;
                total += loss;
            }
            if weight_sum > 0.0 {
                for a in acc.iter_mut() {
                    *a /= weight_sum;
                }
                backends[0].apply_grads(state, &acc, lr)?;
                count += 1;
            }
        }
    }
    Ok(UpdateStats {
        stats: PpoStats {
            total: if count > 0 { total / count as f32 } else { 0.0 },
            ..Default::default()
        },
        minibatches: count,
        samples: dataset.n,
        lr,
    })
}

/// Linearly annealed learning rate: `lr * (1 - iter/total)` when enabled.
pub fn annealed_lr(cfg: &PpoCfg, iter: usize, total_iters: usize) -> f32 {
    if cfg.lr_anneal && total_iters > 0 {
        cfg.lr * (1.0 - iter as f32 / total_iters as f32).max(0.05)
    } else {
        cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gae::gae;
    use crate::algo::rollout::{ChunkEnd, ExperienceChunk};
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::runtime::native_backend::NativeFactory;
    use crate::runtime::BackendFactory;

    fn dataset(n: usize, obs_dim: usize, act_dim: usize, seed: u64) -> PpoDataset {
        let mut rng = Pcg64::new(seed);
        let chunk = ExperienceChunk {
            sampler_id: 0,
            env_slot: 0,
            policy_version: 0,
            obs: (0..n * obs_dim).map(|_| rng.normal()).collect(),
            act: (0..n * act_dim).map(|_| rng.normal()).collect(),
            rew: (0..n).map(|_| rng.normal()).collect(),
            logp: (0..n).map(|_| -1.0 - rng.next_f32()).collect(),
            value: (0..n).map(|_| rng.normal()).collect(),
            end: ChunkEnd::Truncated,
            bootstrap_value: 0.1,
            episode_returns: vec![],
            episode_lengths: vec![],
            obs_stats: None,
            busy_secs: 0.0,
        };
        PpoDataset::assemble(&[chunk], obs_dim, act_dim, |r, v, c| {
            Ok(gae(r, v, c, 0.99, 0.95))
        })
        .unwrap()
    }

    #[test]
    fn update_runs_expected_minibatch_count() {
        let f = NativeFactory::new(3, 2, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let mut backend = f.make_ppo_learner().unwrap();
        let mut st = PpoTrainState::new(f.init_ppo_params(0));
        let mut ds = dataset(100, 3, 2, 1);
        let cfg = PpoCfg {
            epochs: 3,
            minibatch: 32,
            ..Default::default()
        };
        let mut rng = Pcg64::new(2);
        let stats = ppo_update(backend.as_mut(), &mut st, &mut ds, &cfg, 1e-3, &mut rng).unwrap();
        // ceil(100/32) = 4 minibatches x 3 epochs
        assert_eq!(stats.minibatches, 12);
        assert_eq!(stats.samples, 100);
        assert_eq!(st.t, 12);
        assert!(stats.stats.total.is_finite());
    }

    #[test]
    fn update_changes_params_and_reduces_kl_reference() {
        let f = NativeFactory::new(3, 2, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let mut backend = f.make_ppo_learner().unwrap();
        let flat0 = f.init_ppo_params(3);
        let mut st = PpoTrainState::new(flat0.clone());
        let mut ds = dataset(200, 3, 2, 4);
        let cfg = PpoCfg {
            epochs: 2,
            minibatch: 64,
            ..Default::default()
        };
        let mut rng = Pcg64::new(5);
        ppo_update(backend.as_mut(), &mut st, &mut ds, &cfg, 1e-3, &mut rng).unwrap();
        assert_ne!(st.flat, flat0);
    }

    #[test]
    fn sharded_with_one_shard_matches_unsharded_aside_from_shuffle() {
        // same rng seed => same shuffle => identical trajectories
        let f = NativeFactory::new(3, 2, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        let cfg = PpoCfg {
            epochs: 1,
            minibatch: 50,
            norm_adv: false,
            ..Default::default()
        };
        let flat = f.init_ppo_params(7);

        let mut b1 = f.make_ppo_learner().unwrap();
        let mut s1 = PpoTrainState::new(flat.clone());
        let mut d1 = dataset(100, 3, 2, 8);
        ppo_update(b1.as_mut(), &mut s1, &mut d1, &cfg, 1e-3, &mut Pcg64::new(9)).unwrap();

        let mut backends: Vec<Box<dyn crate::runtime::PpoLearnerBackend>> =
            vec![f.make_ppo_learner().unwrap()];
        let mut s2 = PpoTrainState::new(flat);
        let mut d2 = dataset(100, 3, 2, 8);
        ppo_update_sharded(&mut backends, &mut s2, &mut d2, &cfg, 1e-3, &mut Pcg64::new(9))
            .unwrap();

        let max_diff = s1
            .flat
            .iter()
            .zip(&s2.flat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "sharded(1) diverged from unsharded: {max_diff}");
    }

    #[test]
    fn sampler_state_round_trip_continues_noise_bitwise() {
        use crate::algo::api::Algorithm;
        use crate::coordinator::sampler::SamplerCfg;
        let scfg = SamplerCfg {
            id: 2,
            seed: 7,
            chunk_steps: 40,
            sync_budget: None,
            reward_scale: 1.0,
        };
        let algo = Ppo::default();
        let mut live = algo.make_sampler(&scfg, 2, 3);
        let mut lane = vec![0.0f32; 2 * 3];
        for _ in 0..19 {
            live.fill_policy_noise(&mut lane);
        }
        let blob = live.save_state();

        let mut restored = algo.make_sampler(&scfg, 2, 3);
        restored.load_state(&blob).unwrap();
        let mut a = vec![0.0f32; 2 * 3];
        let mut b = vec![0.0f32; 2 * 3];
        for i in 0..25 {
            live.fill_policy_noise(&mut a);
            restored.fill_policy_noise(&mut b);
            assert_eq!(a, b, "noise diverged after restore at tick {i}");
        }

        // wrong lane count rejected
        let mut bad = algo.make_sampler(&scfg, 4, 3);
        assert!(bad.load_state(&blob).is_err());
    }

    #[test]
    fn annealed_lr_decays_linearly() {
        let cfg = PpoCfg {
            lr: 1e-3,
            lr_anneal: true,
            ..Default::default()
        };
        assert_eq!(annealed_lr(&cfg, 0, 100), 1e-3);
        let half = annealed_lr(&cfg, 50, 100);
        assert!((half - 5e-4).abs() < 1e-9);
        // floor at 5%
        assert!(annealed_lr(&cfg, 100, 100) >= 0.05 * 1e-3 - 1e-12);
        let no_anneal = PpoCfg {
            lr: 1e-3,
            lr_anneal: false,
            ..Default::default()
        };
        assert_eq!(annealed_lr(&no_anneal, 99, 100), 1e-3);
    }
}
